"""Make `repro` (src layout) and `benchmarks` importable under bare `pytest`,
and provide a minimal `hypothesis` fallback when the real package is absent
(the container does not ship it; tests only use `given` + `settings` +
`st.floats`/`st.integers`). The fallback runs each property test over a
deterministic sample grid — the real hypothesis, when installed, wins.

Also installs a ``threading.excepthook`` so an uncaught exception in a
helper thread FAILS the test that spawned it (the default behavior prints
to stderr and lets join() succeed — a silently half-dead run looks green).
Tests that deliberately crash a bare thread opt out with
``@pytest.mark.allow_thread_exceptions``."""
import os
import random
import sys
import threading
import traceback

import pytest

# (thread name, "Type: msg", formatted traceback) per uncaught exception —
# drained by the autouse fixture below, attributed to the running test.
_THREAD_EXCEPTIONS = []
_ORIG_THREAD_EXCEPTHOOK = threading.excepthook


def _record_thread_exception(args):
    name = args.thread.name if args.thread is not None else "<unknown>"
    _THREAD_EXCEPTIONS.append((
        name,
        f"{args.exc_type.__name__}: {args.exc_value}",
        "".join(traceback.format_exception(
            args.exc_type, args.exc_value, args.exc_traceback)),
    ))
    _ORIG_THREAD_EXCEPTHOOK(args)  # keep the stderr trace for live debugging


threading.excepthook = _record_thread_exception


@pytest.fixture(autouse=True)
def fail_on_thread_exceptions(request):
    """Any exception that escapes a helper thread during a test fails THAT
    test. Attribution is by time window (threads report to the test that was
    running when they died), which is exact for the join-before-assert style
    every threaded suite here uses."""
    start = len(_THREAD_EXCEPTIONS)
    yield
    leaked = _THREAD_EXCEPTIONS[start:]
    del _THREAD_EXCEPTIONS[start:]
    if not leaked:
        return
    if request.node.get_closest_marker("allow_thread_exceptions"):
        return
    detail = "\n".join(
        f"--- thread {name!r}: {head}\n{tb}" for name, head, tb in leaked)
    pytest.fail(
        f"{len(leaked)} uncaught exception(s) in helper threads:\n{detail}",
        pytrace=False,
    )


def pytest_configure(config):
    # The threaded suites pin per-test wall ceilings with
    # ``pytest.mark.timeout`` so a deadlocked barrier/join fails fast
    # instead of eating the whole CI job timeout. pytest-timeout (pinned in
    # requirements-ci.txt) enforces them; when it is absent the marker must
    # still be registered or ``--strict-markers``/warnings choke on it.
    config.addinivalue_line(
        "markers",
        "timeout(seconds): per-test wall-clock ceiling "
        "(enforced by pytest-timeout when installed)",
    )
    config.addinivalue_line(
        "markers",
        "allow_thread_exceptions: this test deliberately crashes a helper "
        "thread; the thread-excepthook guard must not fail it",
    )

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import inspect
    import itertools
    import types

    class _Floats:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def sample(self, rng, k):
            edge = [self.lo, self.hi]
            return edge + [rng.uniform(self.lo, self.hi) for _ in range(max(k - 2, 0))]

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def sample(self, rng, k):
            edge = [self.lo, self.hi]
            return edge + [rng.randint(self.lo, self.hi) for _ in range(max(k - 2, 0))]

    def _given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 10)
                n = min(n, 10)  # keep the fallback grid cheap
                rng = random.Random(fn.__qualname__)
                names = sorted(strategies)
                columns = [strategies[name].sample(rng, n) for name in names]
                for row in itertools.islice(zip(*columns), n):
                    fn(*args, **dict(zip(names, row)), **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy params so pytest doesn't look for fixtures
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strategies
            ])
            return wrapper
        return deco

    def _settings(**kw):
        def deco(fn):
            if "max_examples" in kw:
                fn._stub_max_examples = kw["max_examples"]
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _Floats
    _st.integers = _Integers
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

"""Pluggable SyncAlgorithm API (core/algorithms.py): registry semantics, a
toy algorithm registered in-test running end-to-end on every substrate with
ZERO runner edits, the gossip algorithm family, and the BMUF threaded-shadow
regression (the pre-registry runner silently ran MA for algo="bmuf")."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core import algorithms, spmd
from repro.core import sync as S
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)


jax.config.update("jax_platform_name", "cpu")

CFG = dlrm_ctr.tiny()
TOL = dict(rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtins_registered(self):
        assert {"easgd", "ma", "bmuf", "gossip"} <= set(algorithms.names())

    def test_get_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown sync algorithm"):
            algorithms.get("nope")

    def test_register_requires_name(self):
        class NoName(algorithms.SyncAlgorithm):
            pass

        with pytest.raises(ValueError, match="non-empty"):
            algorithms.register(NoName())

    def test_register_duplicate_raises_unless_override(self):
        class Dup(algorithms.SyncAlgorithm):
            name = "ma"

        with pytest.raises(ValueError, match="already registered"):
            algorithms.register(Dup())
        original = algorithms.get("ma")
        try:
            algorithms.register(Dup(), override=True)
            assert isinstance(algorithms.get("ma"), Dup)
        finally:
            algorithms.register(original, override=True)

    def test_sync_config_validates_against_registry(self):
        with pytest.raises(ValueError, match="unknown sync algo"):
            SyncConfig(algo="nope").validate()
        for name in algorithms.names():
            assert SyncConfig(algo=name).validate().algo == name

    def test_centralized_metadata_drives_config(self):
        assert SyncConfig(algo="easgd").centralized()
        assert not SyncConfig(algo="ma").centralized()
        assert not SyncConfig(algo="gossip").centralized()


# ---------------------------------------------------------------------------
# Genericity: a toy algorithm defined HERE runs on every substrate
# ---------------------------------------------------------------------------

class ScaledMA(algorithms.SyncAlgorithm):
    """Pull every replica toward a damped replica mean. Implements ONLY the
    pytree oracle — the flat engine, the threaded shadow round, and the SPMD
    sync step all come from the base-class fallbacks."""

    name = "scaled_ma"
    beta = 0.95

    def land(self, stack, state, snap, mask, cfg):
        src = stack if snap is None else snap
        mean = S.replica_mean(src)
        target = jax.tree.map(
            lambda g, x: jnp.broadcast_to((self.beta * g).astype(x.dtype), x.shape),
            mean, stack)
        return S.lerp(stack, target, cfg.alpha), state


@pytest.fixture
def scaled_ma():
    algo = ScaledMA()
    algorithms.register(algo)
    try:
        yield algo
    finally:
        algorithms.unregister("scaled_ma")


def _run_sim(algo, engine, iters=10, mode="shadow", gap=4):
    sim = HogwildSim(
        CFG, SyncConfig(algo=algo, mode=mode, gap=gap, alpha=0.5, delay=1,
                        engine=engine),
        n_trainers=3, n_threads=2, batch_size=32,
        optimizer=optim.adagrad(0.02), seed=0)
    out = sim.run(iters)
    return out


class TestToyAlgorithmEndToEnd:
    def test_hogwild_both_engines_parity(self, scaled_ma):
        """The in-test algorithm trains in HogwildSim on BOTH engines and the
        generic flat fallback matches the pytree oracle exactly."""
        out_f = _run_sim("scaled_ma", "flat")
        out_p = _run_sim("scaled_ma", "pytree")
        assert out_f["sync_count"] == out_p["sync_count"] > 0
        assert all(np.isfinite(l) for l in out_f["train_loss"])
        np.testing.assert_allclose(out_f["train_loss"], out_p["train_loss"], **TOL)

    def test_hogwild_fixed_rate(self, scaled_ma):
        out_f = _run_sim("scaled_ma", "flat", mode="fixed_rate")
        out_p = _run_sim("scaled_ma", "pytree", mode="fixed_rate")
        np.testing.assert_allclose(out_f["train_loss"], out_p["train_loss"], **TOL)

    def test_threaded_runner(self, scaled_ma):
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="scaled_ma", alpha=0.5), n_trainers=2,
            batch_size=32, optimizer=optim.adagrad(0.02), sync_sleep_s=0.002)
        out = r.run(8)
        assert out["sync_count"] > 0
        assert all(np.isfinite(l) for l in out["train_loss"])

    def test_spmd_sync_step(self, scaled_ma):
        sc = SyncConfig(algo="scaled_ma", alpha=1.0)
        step = jax.jit(spmd.make_sync_step(None, sc))
        stack = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 6))}
        state = algorithms.get("scaled_ma").init_state({"w": stack["w"][0]}, sc)
        new, _ = step(stack, state)
        np.testing.assert_allclose(
            np.asarray(new["w"]),
            np.broadcast_to(0.95 * np.asarray(stack["w"]).mean(0), (4, 6)),
            rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Gossip: pairing, oracle semantics, kernel parity, substrates
# ---------------------------------------------------------------------------

class TestGossipPairing:
    def test_all_ids_pair_and_rotate(self):
        p0 = np.asarray(algorithms._ring_partner(4, jnp.int32(0)))
        np.testing.assert_array_equal(p0, [1, 0, 3, 2])
        p1 = np.asarray(algorithms._ring_partner(4, jnp.int32(1)))
        assert not np.array_equal(p0, p1)
        # every matching is an involution: partner[partner[i]] == i
        for p in (p0, p1):
            np.testing.assert_array_equal(p[p], np.arange(4))
        # the union of pair edges over rounds connects the ring
        edges = {frozenset((i, int(p[i]))) for p in (p0, p1)
                 for i in range(4) if p[i] != i}
        assert len(edges) == 4

    def test_odd_count_sits_one_out(self):
        sat_out = set()
        for shift in range(5):
            p = np.asarray(algorithms._ring_partner(5, jnp.int32(shift)))
            np.testing.assert_array_equal(p[p], np.arange(5))
            selfs = np.flatnonzero(p == np.arange(5))
            assert selfs.size == 1  # exactly one replica sits out
            sat_out.add(int(selfs[0]))
        assert len(sat_out) > 1  # the sit-out rotates across rounds

    def test_singleton_fire_still_syncs(self):
        """Regression: a round where ONE shadow clock fired must still land a
        pair — the initiator pulls in its passive ring partner (ADPSGD). The
        staggered HogwildSim schedule fires exactly one replica per round
        whenever R <= gap, so rank-pairing of same-round firers would make
        gossip a silent no-op there."""
        mask = np.asarray([False, False, True, False])
        rows, self_pos, partner_pos = algorithms._gossip_participants_np(
            mask, 4, 0)
        assert rows == [2, 3]  # initiator 2 + passive partner 3
        assert [rows[p] for p in partner_pos] == [3, 2]

    def test_inactive_pairs_cost_nothing(self):
        mask = np.asarray([False, False, True, True, False, False])
        rows, _, _ = algorithms._gossip_participants_np(mask, 6, 0)
        assert rows == [2, 3]  # pair (0,1) and (4,5) never gathered

    def test_host_mirror_matches_jnp(self):
        for R, shift in [(4, 0), (4, 3), (5, 2), (7, 11), (8, 5)]:
            pj = np.asarray(algorithms._ring_partner(R, jnp.int32(shift)))
            pn = algorithms._ring_partner_np(R, shift)
            np.testing.assert_array_equal(pj, pn)
            rng = np.random.RandomState(R * 31 + shift)
            mask = rng.rand(R) > 0.4
            mask[rng.randint(R)] = True
            rows, self_pos, partner_pos = algorithms._gossip_participants_np(
                mask, R, shift)
            # rows == exactly the members of active pairs, in id order
            expect = sorted(i for i in range(R)
                            if pn[i] != i and (mask[i] or mask[pn[i]]))
            assert rows == expect
            for k, rid in enumerate(rows):
                assert self_pos[k] == k
                assert rows[partner_pos[k]] == pn[rid]


class TestGossipOracle:
    def test_pair_becomes_mean_at_alpha_one(self):
        algo = algorithms.get("gossip")
        stack = {"w": jnp.asarray([[2.0], [4.0]])}
        cfg = SyncConfig(algo="gossip", alpha=1.0)
        new, state = algo.land(stack, jnp.int32(0), None, None, cfg)
        np.testing.assert_allclose(np.asarray(new["w"]), [[3.0], [3.0]])
        assert int(state) == 1

    def test_landing_uses_snapshot_mix_on_current(self):
        """Pair mix comes from the LAUNCH snapshot; the elastic pull-back
        lands on the current (moved-on) replicas — paper §3.3."""
        algo = algorithms.get("gossip")
        stack = {"w": jnp.asarray([[10.0], [20.0]])}
        snap = {"w": jnp.asarray([[0.0], [2.0]])}
        cfg = SyncConfig(algo="gossip", alpha=0.5)
        new, _ = algo.land(stack, jnp.int32(0), snap, None, cfg)
        # mix = 1.0 for both; w0' = 0.5*10 + 0.5*1 = 5.5 ; w1' = 10.5
        np.testing.assert_allclose(np.asarray(new["w"]), [[5.5], [10.5]])

    def test_inactive_pair_untouched_passive_partner_lands(self):
        algo = algorithms.get("gossip")
        key = jax.random.PRNGKey(0)
        stack = {"w": jax.random.normal(key, (4, 3))}
        # shift 0 pairs (0,1) and (2,3); only replica 2 fired
        mask = jnp.asarray([False, False, True, False])
        cfg = SyncConfig(algo="gossip", alpha=0.7)
        new, _ = algo.land(stack, jnp.int32(0), None, mask, cfg)
        for i in (0, 1):  # inactive pair: bit-identical
            np.testing.assert_array_equal(np.asarray(new["w"][i]),
                                          np.asarray(stack["w"][i]))
        for i in (2, 3):  # initiator AND its passive partner both moved
            assert float(jnp.abs(new["w"][i] - stack["w"][i]).max()) > 1e-6

    def test_preserves_pair_mean(self):
        """Pairwise elastic averaging never moves the global replica mean when
        every replica lands (even R, all fired)."""
        stack = {"w": jax.random.normal(jax.random.PRNGKey(3), (6, 5))}
        algo = algorithms.get("gossip")
        cfg = SyncConfig(algo="gossip", alpha=0.6)
        new, _ = algo.land(stack, jnp.int32(2), None, None, cfg)
        np.testing.assert_allclose(np.asarray(new["w"].mean(0)),
                                   np.asarray(stack["w"].mean(0)), atol=1e-5)


class TestGossipKernelParity:
    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("fired", [(0, 1, 2, 3), (0, 2, 3), (1,), (2,)])
    def test_round_op_vs_oracle(self, fired, use_pallas):
        from repro.core.flatspace import LANE
        from repro.kernels.gossip_update.ops import gossip_round_op

        key = jax.random.PRNGKey(9)
        stack = jax.random.normal(key, (4, 256, LANE), jnp.float32)
        snap_full = jax.random.normal(jax.random.fold_in(key, 1),
                                      (4, 256, LANE), jnp.float32)
        mask = np.asarray([i in fired for i in range(4)])
        shift = 1
        rows, self_pos, partner_pos = algorithms._gossip_participants_np(
            mask, 4, shift)
        new = gossip_round_op(
            stack.copy(),  # the op donates stack
            snap_full[np.asarray(rows)], jnp.asarray(rows, jnp.int32),
            jnp.asarray(self_pos, jnp.int32), jnp.asarray(partner_pos, jnp.int32),
            0.3, use_pallas=use_pallas)
        oracle, _ = algorithms.get("gossip").land(
            {"w": stack}, jnp.int32(shift), {"w": snap_full},
            jnp.asarray(mask), SyncConfig(algo="gossip", alpha=0.3))
        np.testing.assert_allclose(np.asarray(new), np.asarray(oracle["w"]),
                                   rtol=1e-5, atol=1e-6)
        for i in range(4):
            if i not in rows:
                assert np.array_equal(np.asarray(new[i]), np.asarray(stack[i]))

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_pair_op_symmetric(self, use_pallas):
        from repro.core.flatspace import LANE
        from repro.kernels.gossip_update.ops import gossip_pair_flat_op

        key = jax.random.PRNGKey(4)
        a = jax.random.normal(key, (256, LANE), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, 1), (256, LANE), jnp.float32)
        na, nb = gossip_pair_flat_op(a, b, 1.0, use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(na), np.asarray(nb), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(na), np.asarray(0.5 * (a + b)),
                                   rtol=1e-6)


class TestGossipSubstrates:
    def test_shadow_mode_actually_syncs_when_r_below_gap(self):
        """Regression: with R <= gap the staggered shadow schedule fires ONE
        replica per round; gossip landings must still move weights (vs a
        never-syncing run) — pairing only same-round firers silently no-ops
        here while sync_count keeps climbing."""
        out_sync = _run_sim("gossip", "flat", iters=14)
        out_none = _run_sim("gossip", "flat", iters=14, gap=10 ** 9)
        assert out_sync["sync_count"] > 0
        w_sync = np.asarray(out_sync["state"].w_stack)
        w_none = np.asarray(out_none["state"].w_stack)
        assert float(np.abs(w_sync - w_none).max()) > 1e-6

    def test_threaded_runner(self):
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="gossip", alpha=0.5), n_trainers=2,
            batch_size=32, optimizer=optim.adagrad(0.02), sync_sleep_s=0.002)
        out = r.run(10)
        assert out["sync_count"] > 0
        assert all(np.isfinite(l) for l in out["train_loss"])

    def test_spmd_sync_step_mixes_replicas(self):
        sc = SyncConfig(algo="gossip", alpha=1.0)
        step = jax.jit(spmd.make_sync_step(None, sc))
        stack = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))}
        state = algorithms.get("gossip").init_state(None, sc)

        def disp(s):
            x = s["w"]
            return float(((x - x.mean(0)) ** 2).sum())

        d0 = disp(stack)
        for _ in range(6):
            stack, state = step(stack, state)
        assert int(state) == 6
        assert disp(stack) < 0.2 * d0  # rotation connects the gossip graph


# ---------------------------------------------------------------------------
# BMUF threaded-shadow regression: real block momentum in the background
# ---------------------------------------------------------------------------

class TestBMUFThreadedRegression:
    """The pre-registry ThreadedShadowRunner ran MA for algo="bmuf" on the
    flat path ("bmuf analogous, ma used here"). The registry port must land
    BMUF with the real block-momentum global step, on both engines."""

    @pytest.mark.parametrize("engine", ["flat", "pytree"])
    def test_shadow_round_matches_bmuf_oracle(self, engine):
        from repro.models import dlrm

        sc = SyncConfig(algo="bmuf", alpha=0.5, eta=0.9, block_momentum=0.8,
                        engine=engine)
        r = ThreadedShadowRunner(CFG, sc, n_trainers=3, batch_size=16,
                                 optimizer=optim.adagrad(0.02))
        keys = jax.random.split(jax.random.PRNGKey(7), 3)
        trees = [dlrm.init_dense(CFG, k) for k in keys]
        if engine == "flat":
            ws = [r.flat.pack(t) for t in trees]
            state = r.algo.init_state_flat(r.flat.pack(trees[0]), sc, r.flat)
        else:
            ws = [jax.tree.map(jnp.copy, t) for t in trees]
            state = r.algo.init_state(trees[0], sc)
        # oracle: two BMUF rounds over the same stack (no concurrent training,
        # so the threaded round == bmuf_round against the current stack)
        o_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        o_state = S.BMUFState.init(trees[0])
        for _ in range(2):
            state, n = r._shadow_round(ws, state)
            assert n == 1
            o_stack, o_state = S.bmuf_round(o_stack, o_state, sc.alpha,
                                            eta=sc.eta,
                                            block_momentum=sc.block_momentum)
        got = [r.flat.unpack(p) for p in ws] if engine == "flat" else ws
        for i in range(3):
            for a, b in zip(jax.tree.leaves(got[i]),
                            jax.tree.leaves(S.tree_slice(o_stack, i))):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
        # momentum state actually accumulated
        vel_norm = sum(float(jnp.abs(v).sum())
                       for v in jax.tree.leaves(state.velocity))
        assert vel_norm > 0

    def test_block_momentum_changes_landing(self):
        """With momentum, round 2 must differ from the momentum-free landing —
        the regression (MA instead of BMUF) would make these identical."""
        from repro.models import dlrm

        def two_rounds(bm):
            sc = SyncConfig(algo="bmuf", alpha=0.5, eta=1.0, block_momentum=bm,
                            engine="flat")
            r = ThreadedShadowRunner(CFG, sc, n_trainers=2, batch_size=16,
                                     optimizer=optim.adagrad(0.02))
            keys = jax.random.split(jax.random.PRNGKey(3), 2)
            ws = [r.flat.pack(dlrm.init_dense(CFG, k)) for k in keys]
            state = r.algo.init_state_flat(ws[0], sc, r.flat)
            for _ in range(2):
                state, _ = r._shadow_round(ws, state)
            return ws[0]

        p_no = two_rounds(0.0)
        p_bm = two_rounds(0.9)
        assert float(jnp.abs(p_no - p_bm).max()) > 1e-5

    def test_threaded_runner_bmuf_end_to_end(self):
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="bmuf", alpha=0.5, block_momentum=0.5),
            n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
            sync_sleep_s=0.002)
        out = r.run(10)
        assert out["sync_count"] > 0
        assert all(np.isfinite(l) for l in out["train_loss"])

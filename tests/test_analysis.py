"""Static concurrency-contract analyzer: directive grammar, each contract
class positive + negative, and the integration guarantee that the shipped
tree is clean (DESIGN.md §12).

The per-contract tests feed small synthetic classes through
``check_source`` — each asserts BOTH that the bad shape is flagged and
that the annotated / locked shape is not, so a change that silences a
pass cannot slip through as "fewer false positives".
"""
import os

import pytest

from repro.analysis.contracts import (
    CODES,
    SHARED_CLASSES,
    WAIVER_JUSTIFICATIONS,
    FieldContract,
    parse_directives,
)
from repro.analysis.static_check import check_path, check_source

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TREE = os.path.join(_REPO, "src", "repro")


def codes(src: str) -> set:
    return {v.code for v in check_source(src, "<test>")}


# ---------------------------------------------------------------------------
# Directive grammar
# ---------------------------------------------------------------------------
class TestDirectiveParsing:
    def test_trailing_vs_standalone(self):
        src = (
            "x = 1  # guarded-by: _lock\n"
            "# swap-published\n"
            "y = 2\n"
        )
        ds = parse_directives(src)
        by_kind = {d.kind: d for d in ds}
        assert by_kind["guarded-by"].trailing is True
        assert by_kind["guarded-by"].lock == "_lock"
        assert by_kind["swap-published"].trailing is False

    def test_semicolon_splits_multiple_directives(self):
        ds = parse_directives("# swap-published: elements; guarded-by-writes: _lock\n")
        assert {(d.kind, d.arg) for d in ds} == {
            ("swap-published", "elements"),
            ("guarded-by-writes", "_lock"),
        }
        assert len({d.line for d in ds}) == 1

    def test_reason_extraction_em_and_double_dash(self):
        em = parse_directives("# hogwild-race: ok — slot-owned cells\n")[0]
        dd = parse_directives("# lock-blocking: ok -- bounded scatters\n")[0]
        assert em.is_ok() and em.reason == "slot-owned cells"
        assert dd.is_ok() and dd.reason == "bounded scatters"
        assert not parse_directives("# hogwild-race: maybe\n")[0].is_ok()

    def test_string_literals_are_not_directives(self):
        ds = parse_directives('msg = "# guarded-by: _lock"\n')
        assert ds == []

    def test_non_directive_comment_fragments_skipped(self):
        # prose after a second ';' must not turn into a bogus directive
        ds = parse_directives("# holds-lock: _lock; lock-blocking: ok — a; b stays prose\n")
        assert {d.kind for d in ds} == {"holds-lock", "lock-blocking"}

    def test_plain_comments_yield_nothing(self):
        assert parse_directives("# the usual prose comment\nx = 1\n") == []


class TestFieldContract:
    def test_conflicting_locks_report(self):
        fc = FieldContract("f")
        d1, d2 = parse_directives("# guarded-by: a\n# guarded-by: b\n")
        assert fc.merge(d1) is None
        assert "conflicting" in fc.merge(d2)

    def test_swap_published_elements(self):
        fc = FieldContract("f")
        (d,) = parse_directives("# swap-published: elements\n")
        assert fc.merge(d) is None
        assert fc.swap_published and fc.swap_elements and fc.annotated

    def test_bad_swap_argument_and_bad_ok(self):
        fc = FieldContract("f")
        (d,) = parse_directives("# swap-published: wholesale\n")
        assert "elements" in fc.merge(d)
        (d,) = parse_directives("# hogwild-race: maybe\n")
        assert "ok" in FieldContract("g").merge(d)

    def test_scope_directive_rejected_on_field(self):
        (d,) = parse_directives("# holds-lock: _lock\n")
        assert "cannot annotate a field" in FieldContract("f").merge(d)


# ---------------------------------------------------------------------------
# GB01 — guarded-by
# ---------------------------------------------------------------------------
_GB = """
import threading

class Meter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0  # guarded-by: _lock

    def add(self, n):
        {add_body}
"""


class TestGuardedBy:
    def test_store_outside_lock_flagged(self):
        assert "GB01" in codes(_GB.format(add_body="self.total += n"))

    def test_with_lock_discharges(self):
        body = "with self._lock:\n            self.total += n"
        assert "GB01" not in codes(_GB.format(add_body=body))

    def test_manual_acquire_release_discharges(self):
        body = (
            "self._lock.acquire()\n"
            "        self.total += n\n"
            "        self._lock.release()"
        )
        assert "GB01" not in codes(_GB.format(add_body=body))

    def test_holds_lock_def_discharges(self):
        src = _GB.format(add_body="self._locked_add(n)") + (
            "\n"
            "    # holds-lock: _lock\n"
            "    def _locked_add(self, n):\n"
            "        self.total += n\n"
        )
        # the annotated callee is clean; the caller not holding the lock is
        # an interprocedural gap the lockdep harness covers at runtime
        flagged = [v for v in check_source(src, "<t>") if v.code == "GB01"]
        assert not any("_locked_add" in v.message or v.line >= 13 for v in flagged)

    def test_statement_waiver(self):
        body = "self.total += n  # hogwild-race: ok — test-only waiver"
        assert "GB01" not in codes(_GB.format(add_body=body))

    def test_init_scope_exempt(self):
        # constructor writes happen before the object is published
        src = _GB.format(add_body="pass").replace(
            "self.total = 0  # guarded-by: _lock",
            "self.total = 0  # guarded-by: _lock\n        self.total += 1",
        )
        assert "GB01" not in codes(src)

    def test_guarded_writes_allows_lockfree_reads(self):
        src = """
import threading

class Log:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded-by-writes: _lock

    def bump(self):
        with self._lock:
            self.n += 1

    def peek(self):
        return self.n
"""
        assert "GB01" not in codes(src)
        bad = src.replace("with self._lock:\n            self.n += 1", "self.n += 1")
        assert "GB01" in codes(bad)


# ---------------------------------------------------------------------------
# SP01 — swap-publish
# ---------------------------------------------------------------------------
_SP = """
import threading

class Table:
    def __init__(self):
        self._lock = threading.Lock()
        # {directive}
        self.state = {{"v": 0}}

    def touch(self):
        {touch_body}
"""


class TestSwapPublish:
    def test_rebind_is_legal(self):
        src = _SP.format(directive="swap-published", touch_body='self.state = {"v": 1}')
        assert "SP01" not in codes(src)

    def test_element_write_flagged(self):
        src = _SP.format(directive="swap-published", touch_body='self.state["v"] = 1')
        assert "SP01" in codes(src)

    def test_mutator_method_flagged(self):
        src = _SP.format(directive="swap-published", touch_body='self.state.update(v=1)')
        assert "SP01" in codes(src)

    def test_elements_variant_allows_element_rebind(self):
        src = _SP.format(
            directive="swap-published: elements", touch_body='self.state["v"] = 1'
        )
        assert "SP01" not in codes(src)

    def test_hogwild_combo_still_enforces_swap(self):
        # `swap-published; hogwild-race: ok` waives the LOCK check only —
        # in-place mutation through the field must still be flagged
        src = _SP.format(
            directive="swap-published; hogwild-race: ok — lock-free by design",
            touch_body='self.state.update(v=1)',
        )
        assert "SP01" in codes(src)


# ---------------------------------------------------------------------------
# BL01 — no blocking under a lock
# ---------------------------------------------------------------------------
_BL = """
import threading
import time

class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition()

    def step(self):
        {step_body}
"""


class TestBlockingUnderLock:
    def test_sleep_under_lock(self):
        body = "with self._lock:\n            time.sleep(0.1)"
        assert "BL01" in codes(_BL.format(step_body=body))

    def test_join_under_lock(self):
        body = (
            "t = threading.Thread(target=self.step)\n"
            "        with self._lock:\n"
            "            t.join()"
        )
        assert "BL01" in codes(_BL.format(step_body=body))

    def test_kernel_dispatch_under_lock(self):
        # `prefetch` is registered in KERNEL_CALLS: device work under a lock
        body = "with self._lock:\n            self.store.prefetch([1, 2])"
        assert "BL01" in codes(_BL.format(step_body=body))

    def test_wait_on_held_condition_is_legal(self):
        body = "with self._cond:\n            self._cond.wait(0.1)"
        assert "BL01" not in codes(_BL.format(step_body=body))

    def test_str_join_is_not_thread_join(self):
        body = 'with self._lock:\n            x = ", ".join(["a", "b"])'
        assert "BL01" not in codes(_BL.format(step_body=body))

    def test_waiver_on_statement(self):
        body = (
            "with self._lock:\n"
            "            time.sleep(0.1)  # lock-blocking: ok — test waiver"
        )
        assert "BL01" not in codes(_BL.format(step_body=body))

    def test_outside_lock_is_fine(self):
        assert "BL01" not in codes(_BL.format(step_body="time.sleep(0.1)"))


# ---------------------------------------------------------------------------
# SH01 — unannotated shared state
# ---------------------------------------------------------------------------
_SH = """
import threading

class Runner:
    def __init__(self):
        {decl}

    def start(self):
        t = threading.Thread(target=self.body)
        t.start()

    def body(self):
        self.count += 1

    def read(self):
        self.count += 1
        return self.count
"""


class TestUnannotatedShared:
    def test_unannotated_flagged(self):
        assert "SH01" in codes(_SH.format(decl="self.count = 0"))

    def test_annotation_discharges(self):
        src = _SH.format(decl="self.count = 0  # hogwild-race: ok — test-only")
        assert "SH01" not in codes(src)

    def test_registered_shared_class_needs_annotations(self):
        # SlotEPS is in SHARED_CLASSES: >= 2 public methods touching a
        # mutable attribute make it shared even with no Thread() in sight
        src = """
class SlotEPS:
    def __init__(self):
        self.cells = []

    def tick(self, x):
        self.cells.append(x)

    def eps(self):
        return len(self.cells)
"""
        assert "SH01" in codes(src)

    def test_unregistered_class_single_thread_is_fine(self):
        src = _SH.format(decl="self.count = 0").replace(
            "t = threading.Thread(target=self.body)\n        t.start()", "self.body()"
        )
        assert "SH01" not in codes(src)


# ---------------------------------------------------------------------------
# CT01 — malformed annotations
# ---------------------------------------------------------------------------
class TestAnnotationErrors:
    def test_bad_hogwild_argument(self):
        src = """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.v = 0  # hogwild-race: maybe
"""
        assert "CT01" in codes(src)

    def test_conflicting_guards(self):
        src = """
import threading

class Box:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.v = 0  # guarded-by: _a
        self.v = 1  # guarded-by: _b
"""
        assert "CT01" in codes(src)


# ---------------------------------------------------------------------------
# Integration: the shipped tree and its waiver ledger
# ---------------------------------------------------------------------------
class TestTreeIsClean:
    def test_src_repro_has_no_violations(self):
        violations = check_path(_TREE)
        assert violations == [], "\n".join(v.render() for v in violations)

    def test_every_waiver_in_tree_carries_a_reason(self):
        """`ok` without a `— why` is an unaccountable waiver; the grammar
        makes the reason mandatory and this test makes it enforced."""
        missing = []
        for dirpath, dirnames, filenames in os.walk(_TREE):
            # the analysis toolkit documents the grammar in prose comments
            # and is outside the checked stack (same exclusion as check_path)
            dirnames[:] = [d for d in dirnames if d not in ("__pycache__", "analysis")]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8") as f:
                    src = f.read()
                for d in parse_directives(src, path):
                    if d.kind in ("hogwild-race", "lock-blocking") and not d.reason:
                        missing.append(f"{path}:{d.line}: {d.kind}: {d.arg}")
        assert missing == [], "waivers without a reason:\n" + "\n".join(missing)

    def test_waiver_ledger_is_well_formed(self):
        for key, why in WAIVER_JUSTIFICATIONS.items():
            assert why.strip(), f"empty justification for {key}"
            assert "." in key, f"ledger key {key!r} is not module-qualified"

    def test_shared_class_registry_matches_tree(self):
        """Every registered shared class must still exist in the tree —
        a rename that orphans its registration silently un-shares it."""
        import re

        defined = set()
        for dirpath, dirnames, filenames in os.walk(_TREE):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    with open(os.path.join(dirpath, fn), encoding="utf-8") as f:
                        defined.update(re.findall(r"^class\s+(\w+)", f.read(), re.M))
        orphaned = set(SHARED_CLASSES) - defined
        assert orphaned == set(), f"registered but undefined: {orphaned}"

    def test_violation_codes_have_legends(self):
        assert set(CODES) == {"GB01", "SP01", "BL01", "SH01", "CT01"}

    def test_self_test_script_passes(self):
        import subprocess
        import sys

        script = os.path.join(_REPO, "scripts", "check_concurrency.py")
        out = subprocess.run(
            [sys.executable, script, "--self-test"],
            capture_output=True, text=True, timeout=120,
        )
        assert out.returncode == 0, out.stdout + out.stderr

    @pytest.mark.parametrize("code", ["GB01", "SP01", "BL01", "SH01", "CT01"])
    def test_each_seeded_violation_detected(self, code):
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "check_concurrency", os.path.join(_REPO, "scripts", "check_concurrency.py")
        )
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        got = {v.code for v in check_source(mod._SEEDED[code], f"<{code}>")}
        assert code in got, f"seeded {code} violation not detected (got {got})"

"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models import transformer as T
from repro.models import whisper as W
from repro.roofline.params import active_param_count, param_count

SEQ = 64
BATCH = 2


def make_inputs(cfg, key):
    batch = {"tokens": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            key, (BATCH, cfg.frontend.n_tokens, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (BATCH, cfg.encoder.n_ctx, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced variant of each assigned arch: one forward + one train step on CPU;
    output shapes correct, loss finite, params updated, no NaNs."""
    from repro import optim
    from repro.core import spmd

    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = spmd.init_params(cfg, key)
    batch = make_inputs(cfg, key)

    if cfg.family == "audio":
        loss = W.loss_fn(params, cfg, batch)
    else:
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                prefix_embeds=batch.get("prefix_embeds"))
        n_prefix = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
        assert logits.shape == (BATCH, SEQ + n_prefix, T.padded_vocab(cfg))
        assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
        loss = T.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))

    opt = optim.adam(1e-3)
    step = jax.jit(spmd.make_train_step(cfg, opt, "syncdp"))
    p2, _, loss2 = step(params, opt.init(params), batch)
    assert np.isfinite(float(loss2))
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved, "train step did not update parameters"
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-base"])
def test_decode_matches_forward(arch):
    """serve_step (1 token + cache) reproduces full-sequence logits — attention,
    SSM state, hybrid, MoE, and VLM caches all round-trip."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (BATCH, 32), 0, cfg.vocab_size)
    pe = None
    n_prefix = 0
    if cfg.family == "vlm":
        pe = jax.random.normal(key, (BATCH, cfg.frontend.n_tokens, cfg.d_model)) * 0.1
        n_prefix = cfg.frontend.n_tokens
    logits, _ = T.forward(params, cfg, tokens, prefix_embeds=pe)
    if cfg.family == "vlm":
        # decode path: prefill the image+prompt, then decode token-by-token
        last, cache = T.prefill(params, cfg, tokens[:, :16], 32 + n_prefix, prefix_embeds=pe)
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits[:, n_prefix + 15, :]), atol=2e-3)
        return
    cache = T.init_cache(cfg, BATCH, 32)
    step = jax.jit(lambda c, tok, pos: T.decode_step(params, cfg, c, tok, pos))
    outs = []
    for t in range(32):
        lg, cache = step(cache, tokens[:, t], jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits[..., : cfg.vocab_size]), atol=5e-4)


def test_whisper_decode_matches_full():
    cfg = reduced(get_config("whisper-base"))
    key = jax.random.PRNGKey(2)
    params = W.init_params(cfg, key)
    frames = jax.random.normal(key, (BATCH, cfg.encoder.n_ctx, cfg.d_model)) * 0.1
    tokens = jax.random.randint(key, (BATCH, 16), 0, cfg.vocab_size)
    enc = W.encode(params, cfg, frames)
    full = W.decode_full(params, cfg, tokens, enc)
    cache = W.init_cache(cfg, BATCH, 16)
    cache = {"self": cache["self"], "cross": W.build_cross_cache(params, cfg, enc)}
    step = jax.jit(lambda c, tok, pos: W.decode_step(params, cfg, c, tok, pos))
    outs = []
    for t in range(16):
        lg, cache = step(cache, tokens[:, t], jnp.asarray(t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)),
        np.asarray(full[..., : cfg.vocab_size]), atol=5e-4)


def test_prefill_handoff_matches_decode():
    """prefill(cache) then decode continues exactly like pure decode."""
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    tokens = jax.random.randint(key, (BATCH, 24), 0, cfg.vocab_size)
    # ground truth: full forward
    logits, _ = T.forward(params, cfg, tokens)
    # prefill the first 16, decode the rest
    last, cache = T.prefill(params, cfg, tokens[:, :16], 24)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, 15, :]), atol=5e-4)
    step = jax.jit(lambda c, tok, pos: T.decode_step(params, cfg, c, tok, pos))
    for t in range(16, 24):
        lg, cache = step(cache, tokens[:, t], jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits[:, t, : cfg.vocab_size]), atol=5e-4)


def test_sliding_window_masks_history():
    """Sliding-window attention ignores tokens beyond the window."""
    import dataclasses

    cfg = dataclasses.replace(reduced(get_config("phi3-medium-14b")), sliding_window=8)
    key = jax.random.PRNGKey(4)
    params = T.init_params(cfg, key)
    t1 = jax.random.randint(key, (1, 32), 0, cfg.vocab_size)
    t2 = t1.at[:, :8].set((t1[:, :8] + 7) % cfg.vocab_size)  # perturb distant history
    l1, _ = T.forward(params, cfg, t1)
    l2, _ = T.forward(params, cfg, t2)
    # Influence of tokens 0..7 propagates at most n_layers*(window-1) positions
    # through the stack: unaffected beyond 7 + 2*7 = 21.
    horizon = 7 + cfg.n_layers * (cfg.sliding_window - 1) + 1
    np.testing.assert_allclose(
        np.asarray(l1[:, horizon:]), np.asarray(l2[:, horizon:]), atol=1e-4)
    assert float(jnp.max(jnp.abs(l1[:, :8] - l2[:, :8]))) > 1e-3


def test_mamba2_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive O(L) recurrence (the state-space duality)."""
    from repro.models import mamba2

    cfg = reduced(get_config("mamba2-780m"))
    key = jax.random.PRNGKey(5)
    p = mamba2.mamba2_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, cfg.d_model)) * 0.5
    y_chunked = mamba2.mamba2_apply(p, x, cfg)
    # sequential: run decode steps feeding the same inputs
    cache = mamba2.init_mamba_cache(cfg, 1, jnp.float32)
    step = jax.jit(lambda c, xt: mamba2.mamba2_decode(p, xt, cfg, c))
    ys = []
    for t in range(64):
        yt, cache = step(cache, x[:, t : t + 1, :])
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)


def test_moe_load_balance_loss_positive_and_bounded():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    from repro.models.moe import moe_apply, moe_init

    key = jax.random.PRNGKey(6)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert 0.0 < float(aux) < 10.0 * cfg.moe.load_balance_coef * cfg.moe.n_experts


def test_param_counts_match_eval_shape():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    total = param_count(cfg)
    active = active_param_count(cfg)
    assert 30e9 < total < 60e9, total / 1e9  # ~42B
    assert active < total
    assert 4e9 < active < 12e9, active / 1e9  # ~6.6B active


def test_vocab_padding_masked():
    cfg = reduced(get_config("minicpm-2b"))  # vocab 512 in reduced... force odd
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab_size=300)
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    tokens = jax.random.randint(jax.random.PRNGKey(8), (1, 16), 0, 300)
    logits, _ = T.forward(params, cfg, tokens)
    assert logits.shape[-1] == 512  # padded to 256-multiple
    assert bool(jnp.all(logits[..., 300:] < -1e29))

"""The assigned architecture table, verified field by field."""
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config, reduced

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table.
EXACT = {
    "mamba2-780m": (48, 1536, None, None, 0, 50280),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
}

MOE = {
    "jamba-1.5-large-398b": (16, 2),
    "kimi-k2-1t-a32b": (384, 8),
    "phi3.5-moe-42b-a6.6b": (16, 2),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_sizes(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXACT[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", list(MOE))
def test_moe_sizes(arch):
    cfg = get_config(arch)
    assert (cfg.moe.n_experts, cfg.moe.top_k) == MOE[arch]


def test_mamba2_is_attention_free():
    cfg = get_config("mamba2-780m")
    assert set(cfg.layer_kinds()) == {"M"}
    assert cfg.ssm.d_state == 128  # ssm_state=128 per assignment


def test_jamba_interleave_1_to_7():
    kinds = get_config("jamba-1.5-large-398b").layer_kinds()
    assert len(kinds) == 72
    assert kinds.count("A") == 9 and kinds.count("M") == 63  # 1:7


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_within_limits(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


def test_long_context_support_flags():
    assert get_config("mamba2-780m").supports_long_context()
    assert get_config("jamba-1.5-large-398b").supports_long_context()
    assert not get_config("granite-34b").supports_long_context()
    from repro.configs.phi3_medium_14b import CONFIG_SWA

    assert CONFIG_SWA.supports_long_context()


def test_dryrun_skip_rules():
    from repro.launch.dryrun import should_skip

    assert should_skip("granite-34b", "long_500k") is not None
    assert should_skip("mamba2-780m", "long_500k") is None
    assert should_skip("phi3-medium-14b", "long_500k") is None  # SWA variant
    assert should_skip("whisper-base", "long_500k") is not None
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert should_skip(arch, shape) is None

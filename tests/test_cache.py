"""Tiered embedding cache (embeddings/cache.py, DESIGN.md §11).

Three layers:

* **Store unit layer** — ``CacheConfig`` validation, routing-table
  invariants (every row routed to exactly one (tier, slot)) across a
  migration-heavy stream, LFU eviction never dropping a row with a pending
  Adagrad update (writeback-before-reuse), the counted synchronous stall
  path at ``lookahead=0``, and the hot-tier-too-small config error.

* **Bitwise-parity layer** — the cache is a PURE placement optimization:
  hot-tier kernel launches and ``merged()`` reconstruction are bitwise-
  identical to the same stream through the full-table kernels, at the
  store, at ``EmbeddingShards`` (``cached_lookup``/``cached_update`` vs
  ``shard_lookup``/``shard_update``), and through a whole ``HogwildSim``
  run (cache-on trajectory == cache-off trajectory, flat and pytree
  engines, elastic included).

* **Composition layer** — PR 6's failure domain with the cache on: fail ->
  snapshot-fallback lookups -> recover rebuilds the store from the
  canonical snapshot; plus the uncached fail->recover round-trip parity
  pin (the rehydration path itself). Threaded smoke: a real-thread run
  with per-PS caches, live prefetch, and an injected PS failure completes
  and returns canonical packed state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.membership import FaultSpec
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.supervision import SupervisorConfig
from repro.core.sync import SyncConfig
from repro.data import ctr
from repro.embeddings import table as emb
from repro.embeddings.cache import (
    CacheConfig,
    CachedStore,
    LookaheadPrefetcher,
)
from repro.embeddings.shards import (
    EmbeddingShards,
    _route_np,
    packed_state,
    plan_shards,
    shard_lookup,
    shard_update,
)
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.timeout(300)

CFG = dlrm_ctr.tiny()


def _store(n=128, d=8, hot=32, lookahead=2, seed=0, **kw):
    key = jax.random.PRNGKey(seed)
    state = {
        "table": jax.random.normal(key, (n, d), jnp.float32),
        "acc": jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                         (n, d))) * 0.1,
    }
    cfg = CacheConfig(hot_rows=hot, lookahead=lookahead, **kw)
    return CachedStore(state, cfg), state


def _zipf_batch(i, n, B=16, m=4):
    r = np.random.default_rng(i)
    u = r.random((B, m))
    return np.minimum((u * u * n).astype(np.int64), n - 1)  # skewed stream


# ---------------------------------------------------------------------------
# CacheConfig
# ---------------------------------------------------------------------------

def test_config_exactly_one_budget():
    with pytest.raises(ValueError, match="exactly one"):
        CacheConfig().validate()
    with pytest.raises(ValueError, match="exactly one"):
        CacheConfig(hot_rows=8, hot_frac=0.5).validate()
    assert CacheConfig(hot_rows=8).validate().hot_rows == 8
    assert CacheConfig(hot_frac=0.25).validate().hot_frac == 0.25


@pytest.mark.parametrize("kw", [dict(hot_rows=0), dict(hot_frac=0.0),
                                dict(hot_frac=1.5),
                                dict(hot_rows=4, lookahead=-1),
                                dict(hot_rows=4, decay=0.0),
                                dict(hot_rows=4, update_retries=-1)])
def test_config_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        CacheConfig(**kw).validate()


def test_config_resolves_hot_rows():
    assert CacheConfig(hot_frac=0.25).resolve_hot_rows(1000) == 250
    assert CacheConfig(hot_rows=4000).resolve_hot_rows(1000) == 1000  # clamp
    assert CacheConfig(hot_frac=1e-9).resolve_hot_rows(1000) == 1  # floor


# ---------------------------------------------------------------------------
# Routing invariants + migration
# ---------------------------------------------------------------------------

def test_routing_invariants_across_migrations():
    """Every row routed to exactly one (tier, slot) through a stream that
    forces promotions, evictions, and sync stalls."""
    store, _ = _store(n=96, hot=24, lookahead=1)
    store.check_invariants()
    for it in range(12):
        idx = _zipf_batch(it, 96, B=5)  # working set <= 20 rows < 24 slots
        store.prefetch([np.unique(_zipf_batch(it + 1, 96, B=5))])
        store.check_invariants()
        store.lookup(idx)
        store.check_invariants()
        store.update(idx.reshape(-1, 4), jnp.ones((idx.size // 4, 8)) * 0.01,
                     0.05)
        store.check_invariants()
    r = store.state.routing
    hot_rows = np.flatnonzero(r.slot >= 0)
    assert len(hot_rows) <= store.hot_budget
    # the inverse map agrees row-for-row (exactly one slot per hot row)
    assert np.array_equal(np.sort(r.hot_row[r.hot_row >= 0]),
                          np.sort(hot_rows))


def test_hot_tier_too_small_is_a_config_error():
    store, _ = _store(n=64, hot=4)
    idx = np.arange(16).reshape(1, 16)  # 16 unique rows > 4 slots
    with pytest.raises(ValueError, match="hot tier too small"):
        store.lookup(idx)


def test_stall_path_counted_at_zero_lookahead():
    """lookahead=0: no prefetch — cold rows pay the counted synchronous
    promotion and the result is still exact."""
    store, state = _store(n=64, hot=16, lookahead=0)
    idx = np.asarray([[60, 61], [62, 63]])  # all cold under initial placement
    out = store.lookup(idx)
    ref = embedding_bag_op(state["table"], jnp.asarray(idx))
    assert (np.asarray(out) == np.asarray(ref)).all()
    assert store.stats.stall_lookups == 1
    assert store.stats.miss_rows == 4
    pf = LookaheadPrefetcher(store, lambda j: np.asarray([0, 1]))
    assert pf.step() == {"promoted": 0}  # lookahead=0 never prefetches


def test_eviction_writes_back_pending_updates():
    """A hot row carrying an un-drained Adagrad update is written back
    (table AND acc) before its slot is reused — never dropped."""
    store, state = _store(n=64, hot=8, lookahead=1)
    hot0 = np.asarray([[0, 1, 2, 3]])
    g = jnp.full((1, 8), 0.25)
    assert store.update(hot0.reshape(-1, 4), g, lr=0.1)
    # force rows 0..3 out of the tier: prefetch 8 disjoint cold rows
    store.prefetch([np.arange(40, 48)])
    assert store.state.routing.slot[0] < 0  # actually evicted
    ref_t, ref_a = sparse_adagrad_op(
        state["table"], state["acc"], jnp.asarray(hot0.reshape(-1, 4)), g,
        lr=0.1)
    merged = store.merged()
    assert (np.asarray(merged["table"]) == np.asarray(ref_t)).all()
    assert (np.asarray(merged["acc"]) == np.asarray(ref_a)).all()
    assert store.stats.writeback_rows >= 4


def test_store_stream_bitwise_vs_full_table():
    """The headline contract: 20 skewed batches of lookup+update through a
    25%-budget store are BITWISE the full-table kernel stream, with the
    prefetcher actively migrating rows throughout."""
    n = 256
    store, state = _store(n=n, hot=n // 4, lookahead=2)
    ref_t, ref_a = state["table"], state["acc"]
    key = jax.random.PRNGKey(7)
    for it in range(20):
        idx = _zipf_batch(it, n)
        pf = LookaheadPrefetcher(store, lambda j, it=it: _zipf_batch(it + j, n))
        pf.step()
        got = store.lookup(idx)
        want = embedding_bag_op(ref_t, jnp.asarray(idx))
        assert (np.asarray(got) == np.asarray(want)).all(), f"lookup iter {it}"
        g = jax.random.normal(jax.random.fold_in(key, it), (idx.shape[0], 8))
        ref_t, ref_a = sparse_adagrad_op(ref_t, ref_a, jnp.asarray(idx), g,
                                         lr=0.05)
        assert store.update(idx, g, 0.05)
        store.check_invariants()
    merged = store.merged()
    assert (np.asarray(merged["table"]) == np.asarray(ref_t)).all()
    assert (np.asarray(merged["acc"]) == np.asarray(ref_a)).all()
    s = store.stats
    assert s.prefetch_rows > 0 and s.evict_rows > 0  # migration really ran
    hit_rate = s.hit_rows / (s.hit_rows + s.miss_rows)
    assert hit_rate > 0.5  # lookahead=1+ should make most rows resident


# ---------------------------------------------------------------------------
# EmbeddingShards cached mode
# ---------------------------------------------------------------------------

def _mk_shards(cache=None, seed=3, n_shards=3):
    spec = emb.spec_from_config(CFG)
    plan = plan_shards(spec, n_shards, 64)
    return plan, EmbeddingShards.init(plan, jax.random.PRNGKey(seed),
                                      cache=cache)


def test_cached_shards_bitwise_vs_uncached():
    plan, un = _mk_shards()
    _, ca = _mk_shards(cache=CacheConfig(hot_frac=0.25, lookahead=2))
    teacher = ctr.make_teacher(CFG, seed=5)
    key = jax.random.PRNGKey(11)
    for t in range(6):
        idx = np.asarray(ctr.gen_batch(CFG, teacher, 0, t, 16)["sparse"])
        for s in range(plan.n_shards):
            ca.stores[s].prefetch([_route_np(plan, s, np.asarray(
                ctr.gen_batch(CFG, teacher, 0, t + j, 16)["sparse"]))
                for j in range(2)])
        p_un = shard_lookup(plan, un.tables(), jnp.asarray(idx))
        p_ca = ca.cached_lookup(idx)
        assert (np.asarray(p_un) == np.asarray(p_ca)).all(), f"iter {t}"
        g = jax.random.normal(jax.random.fold_in(key, t),
                              (16, CFG.n_sparse_features, CFG.embedding_dim))
        for s in range(plan.n_shards):
            assert un.try_update(
                s, lambda st, *a: shard_update(plan, s, st, *a),
                jnp.asarray(idx), g, 0.05)
            assert ca.cached_update(s, idx, g, 0.05)
            ca.stores[s].check_invariants()
    pu, pc = un.to_packed(), ca.to_packed()
    assert (np.asarray(pu["table"]) == np.asarray(pc["table"])).all()
    assert (np.asarray(pu["acc"]) == np.asarray(pc["acc"])).all()


def test_cached_mode_guards_uncached_hot_path():
    _, ca = _mk_shards(cache=CacheConfig(hot_frac=0.5))
    with pytest.raises(RuntimeError, match="cached_lookup"):
        ca.tables()
    with pytest.raises(RuntimeError, match="cached_update"):
        ca.try_update(0, lambda st: st)
    _, un = _mk_shards()
    with pytest.raises(RuntimeError, match="cache="):
        un.cached_lookup(np.zeros((1, CFG.n_sparse_features, CFG.multi_hot),
                                  np.int64))
    with pytest.raises(RuntimeError, match="cache="):
        un.cached_update(0, np.zeros((1, CFG.n_sparse_features,
                                      CFG.multi_hot), np.int64),
                         jnp.zeros((1, CFG.n_sparse_features,
                                    CFG.embedding_dim)), 0.05)


def test_cached_shards_fail_recover_composition():
    """PR 6 x PR 7: fail a cached shard -> snapshot-fallback lookups and
    dropped updates while down -> recover rebuilds the tiered store from
    the canonical snapshot, packed view bitwise-preserved."""
    plan, ca = _mk_shards(cache=CacheConfig(hot_frac=0.25, lookahead=1))
    teacher = ctr.make_teacher(CFG, seed=9)
    idx = np.asarray(ctr.gen_batch(CFG, teacher, 0, 0, 16)["sparse"])
    g = jnp.ones((16, CFG.n_sparse_features, CFG.embedding_dim)) * 0.01
    for s in range(plan.n_shards):
        ca.cached_update(s, idx, g, 0.05)
    ca.snapshot_all()
    ref = ca.to_packed()
    ca.fail_shard(1, "chaos")
    assert ca.stores[1] is None
    out = ca.cached_lookup(idx)  # shard 1 answers from its snapshot
    assert np.isfinite(np.asarray(out)).all()
    assert ca.stale_lookups[1] >= 1
    assert not ca.cached_update(1, idx, g, 0.05)  # retry ladder -> drop
    assert ca.dropped_updates[1] >= 1
    ca.recover_shard(1)
    assert ca.stores[1] is not None
    got = ca.to_packed()
    assert (np.asarray(got["table"]) == np.asarray(ref["table"])).all()
    assert (np.asarray(got["acc"]) == np.asarray(ref["acc"])).all()
    ca.stores[1].check_invariants()
    # the recovered store is live again: updates land
    assert ca.cached_update(1, idx, g, 0.05)


def test_uncached_fail_recover_round_trip_parity():
    """PR 6 rehydration pin (no cache): after fail_shard + recover_shard,
    to_packed() equals the snapshot-rehydrated tables BITWISE — including
    live updates landed on the surviving shards while the victim was down."""
    plan, shards = _mk_shards()
    teacher = ctr.make_teacher(CFG, seed=13)
    idx = jnp.asarray(ctr.gen_batch(CFG, teacher, 0, 0, 16)["sparse"])
    g = jnp.ones((16, CFG.n_sparse_features, CFG.embedding_dim)) * 0.01
    for s in range(plan.n_shards):
        shards.try_update(s, lambda st, *a: shard_update(plan, s, st, *a),
                          idx, g, 0.05)
    shards.snapshot_all()
    victim = 1
    shards.fail_shard(victim, "injected")
    # survivors keep landing updates while the victim is down
    for s in range(plan.n_shards):
        shards.try_update(s, lambda st, *a: shard_update(plan, s, st, *a),
                          idx, g, 0.05)
    shards.recover_shard(victim)
    got = shards.to_packed()
    expect = packed_state(plan, [
        shards.snapshots[s] if s == victim else shards.states[s]
        for s in range(plan.n_shards)])
    assert (np.asarray(got["table"]) == np.asarray(expect["table"])).all()
    assert (np.asarray(got["acc"]) == np.asarray(expect["acc"])).all()
    # and the recovered state IS the snapshot (bitwise), not a re-init
    assert (np.asarray(shards.states[victim]["table"]) ==
            np.asarray(shards.snapshots[victim]["table"])).all()


# ---------------------------------------------------------------------------
# HogwildSim: cache-on == cache-off, bitwise
# ---------------------------------------------------------------------------

def _sim(cache, engine="flat", seed=1, **kw):
    return HogwildSim(
        CFG, SyncConfig(algo="easgd", gap=4, delay=1, engine=engine),
        n_trainers=2, n_threads=2, batch_size=8,
        optimizer=optim.make("adagrad", 0.02), seed=seed, cache=cache, **kw)


@pytest.mark.parametrize("engine", ["flat", "pytree"])
def test_sim_trajectory_bitwise_cache_on_off(engine):
    out_u = _sim(None, engine).run(8)
    out_c = _sim(CacheConfig(hot_frac=0.25, lookahead=2), engine).run(8)
    assert out_u["train_loss"] == out_c["train_loss"]
    eu, ec = out_u["state"].emb_state, out_c["state"].emb_state
    assert (np.asarray(eu["table"]) == np.asarray(ec["table"])).all()
    assert (np.asarray(eu["acc"]) == np.asarray(ec["acc"])).all()
    wu = np.asarray(jax.tree.leaves(out_u["state"].w_stack)[0])
    wc = np.asarray(jax.tree.leaves(out_c["state"].w_stack)[0])
    assert (wu == wc).all()
    cs = out_c["cache_stats"]
    assert cs["prefetch_rows"] > 0 and cs["stall_lookups"] == 0


def test_sim_trajectory_bitwise_zero_lookahead():
    """The stall path is exact too: lookahead=0 promotes synchronously on
    every cold hit yet the trajectory stays bitwise-identical."""
    out_u = _sim(None).run(5)
    out_c = _sim(CacheConfig(hot_frac=0.3, lookahead=0)).run(5)
    assert out_u["train_loss"] == out_c["train_loss"]
    assert out_c["cache_stats"]["stall_lookups"] > 0  # really took stalls


def test_sim_elastic_trajectory_bitwise():
    sched = [(2, "leave", 1), (4, "join", 1)]
    o_u = _sim(None, schedule=sched, seed=6).run(6)
    o_c = _sim(CacheConfig(hot_frac=0.3, lookahead=1),
               schedule=sched, seed=6).run(6)
    assert np.array_equal(o_u["replica_losses"], o_c["replica_losses"])
    assert (np.asarray(o_u["state"].emb_state["table"]) ==
            np.asarray(o_c["state"].emb_state["table"])).all()


def test_sim_cached_state_roundtrip():
    """merged() restores the canonical emb_state at run end: save/resume
    across a cached run matches an uncached run resumed the same way."""
    sim_u, sim_c = _sim(None, seed=4), _sim(
        CacheConfig(hot_frac=0.25, lookahead=1), seed=4)
    st_u = sim_u.run(4)["state"]
    st_c = sim_c.run(4)["state"]
    out_u = sim_u.run(3, state=st_u)
    out_c = sim_c.run(3, state=st_c)
    assert out_u["train_loss"] == out_c["train_loss"]
    assert (np.asarray(out_u["state"].emb_state["table"]) ==
            np.asarray(out_c["state"].emb_state["table"])).all()


# ---------------------------------------------------------------------------
# ThreadedShadowRunner composition
# ---------------------------------------------------------------------------

def _runner(cache, fault=None, **kw):
    sup = (SupervisorConfig(heartbeat_deadline_s=1.0, check_interval_s=0.01,
                            backoff_s=0.05, max_restarts=3)
           if fault is not None else None)
    return ThreadedShadowRunner(
        CFG, SyncConfig(algo="easgd", gap=2, engine="flat"),
        n_trainers=2, batch_size=16, optimizer=optim.make("adagrad", 0.02),
        seed=2, cache=cache, fault_spec=fault, supervisor_config=sup, **kw)


def test_threaded_cached_smoke():
    r = _runner(CacheConfig(hot_frac=0.25, lookahead=2))
    r.warmup()
    out = r.run(6)
    assert all(np.isfinite(out["train_loss"]))
    assert out["iter_count"] == [6, 6]
    assert out["cache_stats"]["lookups"] > 0
    # a store-level optimistic-swap conflict may exhaust its retries (the
    # shard ladder then retries the whole call) — but with every shard
    # healthy no update may be LOST at the shard level
    assert out["dropped_updates"] == [0] * len(out["dropped_updates"])
    # the packed view is canonical: full table shape, all rows finite
    packed = out["emb_state"]
    assert packed["table"].shape == (CFG.n_embedding_rows, CFG.embedding_dim)
    assert np.isfinite(np.asarray(packed["table"])).all()


def test_threaded_cached_ps_fail_recover():
    """Cache x failure domain in the real-thread runner: a PS dies mid-run
    (both tiers lost), serves snapshot reads, recovers by rebuilding its
    tiered store — the run completes with canonical packed output."""
    fault = FaultSpec(ps_fail_at={0: 2}, ps_recover_after_s=0.2)
    r = _runner(CacheConfig(hot_frac=0.3, lookahead=1), fault=fault)
    r.warmup()
    out = r.run(8)
    kinds = [e.kind for e in out["shard_events"]]
    assert "ps_fail" in kinds and "ps_recover" in kinds
    assert all(np.isfinite(out["train_loss"]))
    assert np.isfinite(np.asarray(out["emb_state"]["table"])).all()
    # the store behind every healthy shard satisfies the routing invariants
    for s, store in enumerate(r.emb.stores):
        if store is not None:
            store.check_invariants()

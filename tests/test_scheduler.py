"""Closed-loop straggler scheduling (DESIGN.md §9): EPSMeter/SlotEPS edge
cases the controller depends on, the StragglerPolicy state machine
(healthy -> suspect -> demoted -> probation, hysteresis, quorum), the
deterministic StragglerSchedule, and end-to-end demote/re-admit through both
runners."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.elp import EPSMeter, SlotEPS, median_eps
from repro.core.membership import FaultSpec
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.scheduler import (
    DEMOTED, HEALTHY, PROBATION, SUSPECT,
    PolicyAction, PolicyConfig, StragglerPolicy, StragglerSchedule,
)
from repro.core.sync import SyncConfig

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)


CFG = dlrm_ctr.tiny()


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# EPSMeter edge cases (satellite): the controller trusts these exactly
# ---------------------------------------------------------------------------

class TestEPSMeterEdges:
    def test_empty_window_is_zero(self):
        """All buckets aged out: the rate must be 0, not a stale positive."""
        clk = FakeClock()
        m = EPSMeter(window_s=1.0, clock=clk)
        clk.t += 0.5
        m.add(500)
        clk.t += 100.0
        assert m.eps == 0.0

    def test_single_bucket(self):
        clk = FakeClock()
        m = EPSMeter(window_s=4.0, clock=clk)
        clk.t += 2.0
        m.add(100)  # partial window: rate over elapsed time, not window_s
        assert m.eps == pytest.approx(50.0)

    def test_eviction_exactness_at_cutoff(self):
        """A bucket EXACTLY window_s old is kept (strictly-older evicts);
        one epsilon older is gone. The controller's breach decisions sit
        right on this boundary."""
        clk = FakeClock()
        m = EPSMeter(window_s=2.0, clock=clk)
        clk.t = 110.0
        m.add(10)
        clk.t = 112.0  # bucket age == window_s exactly
        assert m.eps == pytest.approx(10 / 2.0)
        clk.t = 112.0000001
        assert m.eps == 0.0

    def test_eps_read_does_not_mutate(self):
        """The controller reads concurrently with the trainer's add():
        eps must be a pure read — expired buckets are filtered, not
        evicted, so a racing reader can never drop a live bucket."""
        clk = FakeClock()
        m = EPSMeter(window_s=1.0, clock=clk)
        clk.t += 0.5
        m.add(10)
        clk.t += 100.0
        assert m.eps == 0.0
        assert len(m._buckets) == 1  # still there; only add() evicts
        m.add(20)
        assert len(m._buckets) == 1  # add() evicted the stale one

    @settings(max_examples=20)
    @given(n=st.integers(min_value=1, max_value=10_000),
           dt=st.floats(min_value=0.01, max_value=0.5))
    def test_steady_rate_recovered(self, n, dt):
        clk = FakeClock()
        m = EPSMeter(window_s=2.0, clock=clk)
        for _ in range(int(np.ceil(2.0 / dt)) + 5):
            clk.t += dt
            m.add(n)
        # bucket quantization: at most one extra bucket rides the exact
        # window edge, so the error bound is dt/window_s (<= 25% here)
        assert m.eps == pytest.approx(n / dt, rel=0.3)


class TestSlotEPS:
    def test_busy_clock_isolates_barrier_waits(self):
        """Two slots process the same examples; slot 1's busy clock
        advances 4x slower (it spends the rest blocked). Busy-time EPS
        must report slot 1 at 4x the rate — the wall is not its fault."""
        bank = SlotEPS(2, window_s=10.0)
        for _ in range(10):
            bank.tick(0, 0.4)
            bank.add(0, 40)
            bank.tick(1, 0.1)
            bank.add(1, 40)
        assert bank.eps(0) == pytest.approx(100.0)
        assert bank.eps(1) == pytest.approx(400.0)

    def test_median_of_live_slots_excludes_dead(self):
        """Dead slots (rate 0) must not drag the median the living are
        judged against — the controller's base-set rule, stated on the
        meter bank it reads."""
        bank = SlotEPS(4, window_s=10.0)
        for slot, rate in ((0, 100), (1, 120), (2, 48)):
            bank.tick(slot, 1.0)
            bank.add(slot, rate)
        eps = bank.eps_by_slot()  # slot 3 is dead: never ticked, rate 0
        live = [0, 1, 2]
        assert median_eps(eps[i] for i in live) == pytest.approx(100.0)
        # a naive median over all four would be dragged down to 74
        assert median_eps(eps.values()) == pytest.approx(74.0)
        # ...and the policy indeed excludes the dead slot from its base:
        # 48 breaches 0.5 x 100 (live median) but would pass 0.5 x 74
        p = _policy(n=4, min_active=1)
        p.observe(0.0, eps, [True, True, True, False])
        assert p.state(2) == SUSPECT
        assert p.state(3) == HEALTHY  # dead slot never judged

    @settings(max_examples=20)
    @given(a=st.floats(min_value=0.0, max_value=1e6),
           b=st.floats(min_value=0.0, max_value=1e6),
           c=st.floats(min_value=0.0, max_value=1e6))
    def test_median_is_the_middle(self, a, b, c):
        vals = [a, b, c]
        assert median_eps(vals) == sorted(vals)[1]

    def test_median_even_and_empty(self):
        assert median_eps([]) == 0.0
        assert median_eps([4.0]) == 4.0
        assert median_eps([1.0, 3.0]) == 2.0


# ---------------------------------------------------------------------------
# StragglerPolicy state machine
# ---------------------------------------------------------------------------

def _policy(n=3, **kw):
    cfg = dict(eps_floor_frac=0.5, readmit_frac=0.75, window_s=2.0,
               probation_s=2.0, min_active=2)
    cfg.update(kw)
    return StragglerPolicy(PolicyConfig(**cfg), n_slots=n)


ACTIVE3 = [True, True, True]


class TestPolicyConfig:
    def test_validation(self):
        PolicyConfig().validate()
        with pytest.raises(ValueError, match="eps_floor_frac"):
            PolicyConfig(eps_floor_frac=0.0).validate()
        with pytest.raises(ValueError, match="hysteresis"):
            PolicyConfig(eps_floor_frac=0.6, readmit_frac=0.5).validate()
        with pytest.raises(ValueError, match="window_s"):
            PolicyConfig(window_s=0.0).validate()
        with pytest.raises(ValueError, match="min_active"):
            PolicyConfig(min_active=0).validate()
        with pytest.raises(ValueError, match="n_slots"):
            StragglerPolicy(PolicyConfig(), n_slots=0)

    def test_runner_rejects_slot_mismatch(self):
        with pytest.raises(ValueError, match="slots"):
            ThreadedShadowRunner(
                CFG, SyncConfig(), n_trainers=3, batch_size=8,
                optimizer=optim.adagrad(0.02), straggler_policy=_policy(n=2))


class TestStragglerPolicy:
    def test_single_dip_never_demotes(self):
        p = _policy()
        assert p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3) == []
        assert p.state(2) == SUSPECT
        # recovery clears the suspicion
        assert p.observe(1.0, {0: 100, 1: 100, 2: 90}, ACTIVE3) == []
        assert p.state(2) == HEALTHY

    def test_sustained_breach_demotes_with_provenance(self):
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        assert p.observe(1.0, {0: 100, 1: 100, 2: 10}, ACTIVE3) == []
        acts = p.observe(2.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        assert acts == [PolicyAction("demote", 2, acts[0].reason)]
        assert "straggler" in acts[0].reason and "median" in acts[0].reason
        assert p.state(2) == DEMOTED

    def test_never_acts_blind(self):
        p = _policy()
        for t in range(10):
            assert p.observe(float(t), {0: 0.0, 1: 0.0, 2: 0.0}, ACTIVE3) == []
        assert p.state(2) == HEALTHY

    def test_quorum_floor(self):
        """min_active=2 with a 2-slot cohort: the controller must tolerate
        the straggler rather than demote below quorum."""
        p = _policy(n=2)
        active = [True, True]
        for t in range(10):
            assert p.observe(float(t), {0: 100, 1: 1}, active) == []
        assert p.state(1) == SUSPECT  # watched, but never demoted

    def test_readmit_after_probation(self):
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        p.observe(2.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        assert p.state(2) == DEMOTED
        down = [True, True, False]
        # still slow: stays demoted
        p.observe(3.0, {0: 100, 1: 100, 2: 20}, down)
        assert p.state(2) == DEMOTED
        # healthy probes start the probation clock
        p.observe(4.0, {0: 100, 1: 100, 2: 95}, down)
        assert p.state(2) == PROBATION
        acts = p.observe(6.0, {0: 100, 1: 100, 2: 95}, down)
        assert [(a.kind, a.slot) for a in acts] == [("readmit", 2)]
        assert "probation" in acts[0].reason
        assert p.state(2) == HEALTHY

    def test_probation_resets_on_relapse(self):
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        p.observe(2.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        down = [True, True, False]
        p.observe(3.0, {0: 100, 1: 100, 2: 95}, down)
        assert p.state(2) == PROBATION
        p.observe(4.0, {0: 100, 1: 100, 2: 10}, down)  # relapse
        assert p.state(2) == DEMOTED
        p.observe(5.0, {0: 100, 1: 100, 2: 95}, down)
        # probation restarted: 2s from t=5, not from t=3
        assert p.observe(6.0, {0: 100, 1: 100, 2: 95}, down) == []
        acts = p.observe(7.0, {0: 100, 1: 100, 2: 95}, down)
        assert [(a.kind, a.slot) for a in acts] == [("readmit", 2)]

    def test_hysteresis_parks_borderline_slot(self):
        """A slot at 60% of median is above the demotion floor (50%) but
        below the re-admission bar (75%): once demoted it must PARK, not
        flap through leave/join cycles."""
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        p.observe(2.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        down = [True, True, False]
        for t in range(3, 30):
            assert p.observe(float(t), {0: 100, 1: 100, 2: 60}, down) == []
        assert p.state(2) == DEMOTED

    def test_crashed_slot_is_not_ours_to_readmit(self):
        """A slot that died outside the policy (crash/leave) must never be
        re-admitted by it — the fault harness owns that lifecycle."""
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 100}, ACTIVE3)
        crashed = [True, True, False]  # slot 2 crashed, policy never demoted
        for t in range(1, 10):
            assert p.observe(float(t), {0: 100, 1: 100, 2: 500}, crashed) == []
        assert p.state(2) == HEALTHY

    def test_lone_demoted_slot_judged_against_demotion_reference(self):
        """When every other eligible slot is gone, the median degenerates to
        the demoted slot's own rate — re-admission must fall back to the
        median it was demoted against, so a still-degraded slot can never
        pass probation by being compared to itself."""
        p = _policy()
        p.observe(0.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        p.observe(2.0, {0: 100, 1: 100, 2: 10}, ACTIVE3)
        assert p.state(2) == DEMOTED
        down = [True, True, False]
        gone = [False, False, True]  # slots 0/1 finished: only 2 eligible
        for t in range(3, 10):  # still degraded: must NOT pass probation
            assert p.observe(float(t), {2: 10.0}, down, gone) == []
        assert p.state(2) == DEMOTED
        # genuinely recovered vs the demotion-time median (100): re-admitted
        p.observe(10.0, {2: 95.0}, down, gone)
        assert p.state(2) == PROBATION
        acts = p.observe(12.0, {2: 95.0}, down, gone)
        assert [(a.kind, a.slot) for a in acts] == [("readmit", 2)]
        assert "reference median" in acts[0].reason

    def test_finished_slot_is_not_a_straggler(self):
        """eligible=False (thread exited): its decayed-to-zero rate must
        not read as degradation."""
        p = _policy()
        eligible = [True, True, False]
        for t in range(10):
            acts = p.observe(float(t), {0: 100, 1: 100, 2: 0.0}, ACTIVE3,
                             eligible)
            assert acts == []
        assert p.state(2) == HEALTHY

    def test_multiple_stragglers_stop_at_quorum(self):
        p = _policy(n=4, min_active=2)
        active = [True] * 4
        eps = {0: 100, 1: 100, 2: 5, 3: 5}
        p.observe(0.0, eps, active)
        acts = p.observe(2.0, eps, active)
        # both breached a full window, but only TWO may leave... n_live=4,
        # min_active=2 -> exactly 2 demotions, never a third
        assert [a.kind for a in acts] == ["demote", "demote"]
        p2 = _policy(n=4, min_active=3)
        p2.observe(0.0, eps, active)
        acts2 = p2.observe(2.0, eps, active)
        assert len(acts2) == 1  # quorum 3: only one slot may leave


# ---------------------------------------------------------------------------
# StragglerSchedule: the deterministic sim-side event source
# ---------------------------------------------------------------------------

def _rates(t, s):
    if s == 2 and t < 12:
        return 20.0
    return 100.0


def _sched(**kw):
    pol = _policy(window_s=3, probation_s=2, **kw)
    return StragglerSchedule(pol, _rates)


class TestStragglerSchedule:
    def test_emits_leave_then_join_with_provenance(self):
        s = _sched()
        stream = {t: s.events_at(t) for t in range(20)}
        emitted = [(t, kind, slot) for t, evs in stream.items()
                   for kind, slot, _ in evs]
        assert emitted == [(3, "leave", 2), (14, "join", 2)]
        assert "straggler" in stream[3][0][2]
        assert "probation" in stream[14][0][2]

    def test_deterministic_replay(self):
        a, b = _sched(), _sched()
        ev_a = [a.events_at(t) for t in range(20)]
        ev_b = [b.events_at(t) for t in range(20)]
        assert ev_a == ev_b
        # re-reading an earlier iteration replays the cache, not the policy
        assert a.events_at(3) == ev_a[3]
        assert len(a) == 2

    def test_skipped_iterations_are_still_evaluated(self):
        """A resumed run jumps events_at from 0 to t: every intermediate
        iteration must be fed to the policy exactly once."""
        s = _sched()
        assert s.events_at(19) == []  # evaluates 0..19 internally
        assert [kind for _, kind, _ in s] == ["leave", "join"]

    def test_start_active_length_checked(self):
        with pytest.raises(ValueError, match="slots"):
            StragglerSchedule(_policy(), _rates, start_active=[True])


# ---------------------------------------------------------------------------
# HogwildSim integration: closed loop, reproducible, engine-agnostic
# ---------------------------------------------------------------------------

_SIM_RUNS = {}


class TestSimClosedLoop:
    def _run(self, engine):
        if engine not in _SIM_RUNS:
            sched = _sched()
            sim = HogwildSim(
                CFG, SyncConfig(algo="easgd", alpha=0.5, gap=3, engine=engine),
                n_trainers=3, n_threads=2, batch_size=16,
                optimizer=optim.adagrad(0.02), schedule=sched)
            _SIM_RUNS[engine] = sim.run(20)
        return _SIM_RUNS[engine]

    @pytest.mark.parametrize("engine", ["flat", "pytree"])
    def test_demote_readmit_cycle(self, engine):
        out = self._run(engine)
        evs = [(e.kind, e.slot) for e in out["membership_events"]]
        assert evs == [("leave", 2), ("join", 2), ("activate", 2)]
        leave = out["membership_events"][0]
        assert "straggler" in leave.reason  # demotion provenance
        assert np.isfinite(out["train_loss"][-1])

    def test_flat_pytree_parity_under_policy(self):
        """The controller's membership churn must not open a gap between
        the fused-kernel landing and the pytree oracle."""
        a, b = self._run("flat"), self._run("pytree")
        assert [(e.kind, e.slot) for e in a["membership_events"]] == \
               [(e.kind, e.slot) for e in b["membership_events"]]
        np.testing.assert_allclose(a["train_loss"], b["train_loss"],
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# ThreadedShadowRunner integration: the real-time loop
# ---------------------------------------------------------------------------

def _threaded_auto(mode, iters=300, sleep=0.5, until=4):
    # margins matter on a loaded box: the straggler must straggle long
    # enough to be demoted (sleep dominates compute), then run long enough
    # after recovery for its meter to refill (eps_window_s of BUSY time) and
    # the probation to pass BEFORE it exhausts its iteration budget
    policy = StragglerPolicy(
        PolicyConfig(eps_floor_frac=0.5, readmit_frac=0.75, window_s=0.2,
                     probation_s=0.1, min_active=2), n_slots=3)
    runner = ThreadedShadowRunner(
        CFG, SyncConfig(algo="easgd", alpha=0.5, mode=mode, gap=3),
        n_trainers=3, batch_size=32, optimizer=optim.adagrad(0.02),
        sync_sleep_s=0.01, eps_window_s=0.25,
        fault_spec=FaultSpec(straggler_sleep_s={2: sleep},
                             straggler_until={2: until}),
        straggler_policy=policy)
    runner.warmup()  # keep tracing out of the controller's detection window
    return runner.run(iters)


class TestThreadedClosedLoop:
    @pytest.mark.parametrize("mode", ["shadow", "fixed_rate"])
    def test_demote_readmit_cycle(self, mode):
        """The controller must demote the transient straggler (sleep
        dominates compute by construction) and re-admit it once the
        degradation ends — the run completes every iteration either way."""
        out = _threaded_auto(mode)
        assert out["iter_count"] == [300, 300, 300]
        kinds = [(e.kind, e.slot) for e in out["membership_events"]]
        assert kinds[:3] == [("leave", 2), ("join", 2), ("activate", 2)]
        leave = out["membership_events"][0]
        assert "straggler" in leave.reason
        assert all(np.isfinite(loss) for loss in out["train_loss"])
        # busy-clock meters: the straggler's intrinsic pace reads below the
        # healthy slots' even in fixed_rate, where WALL pace equalizes at
        # the barrier (the slept prefix is in its busy time)
        busy = out["per_trainer_eps_busy"]
        assert busy[2] < min(busy[0], busy[1])

    def test_straggler_until_restores_pace(self):
        """FaultSpec.straggler_until alone (no policy): the sleep stops at
        the bound. The slot's extra busy time over its healthy peer is the
        slept prefix (~until x sleep), nowhere near a run-long sleep."""
        runner = ThreadedShadowRunner(
            CFG, SyncConfig(algo="easgd", alpha=0.5, mode="shadow", gap=3),
            n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
            fault_spec=FaultSpec(straggler_sleep_s={1: 0.3},
                                 straggler_until={1: 3}))
        runner.warmup()
        out = runner.run(20)
        assert out["iter_count"] == [20, 20]
        slept = runner.slot_eps.busy(1) - runner.slot_eps.busy(0)
        assert 3 * 0.3 * 0.8 <= slept <= 3 * 0.3 + 2.0  # 20 x 0.3 would be 6s

    def test_straggler_until_requires_sleep(self):
        with pytest.raises(ValueError, match="straggler_until"):
            FaultSpec(straggler_until={1: 3}).validate(2)

"""Correctness of the beyond-paper §Perf variants: they must change the
communication schedule, never the math (up to float reassociation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config, reduced
from repro.core import spmd
from repro.models import transformer as T


def _batch(cfg, key, b=2, s=32):
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}


def test_save_comm_remat_matches_full_remat():
    """Remat policy changes what is saved, not what is computed."""
    cfg = reduced(get_config("granite-20b"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    g_full = jax.grad(lambda p: T.loss_fn(p, cfg, batch, remat=True))(params)
    g_comm = jax.grad(lambda p: T.loss_fn(p, cfg, batch, remat=True,
                                          remat_policy="save_comm"))(params)
    for a, b_ in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_comm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-6)


def test_parallel_block_trains():
    """PaLM-style parallel block: different function (by design), still learns."""
    cfg = dataclasses.replace(reduced(get_config("phi3.5-moe-42b-a6.6b")),
                              parallel_block=True)
    key = jax.random.PRNGKey(1)
    params = spmd.init_params(cfg, key)
    opt = optim.adam(2e-3)
    step = jax.jit(spmd.make_train_step(cfg, opt, "syncdp"))
    st = opt.init(params)
    batch = _batch(cfg, key)
    losses = []
    for _ in range(8):
        params, st, loss = step(params, st, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_microbatch_accumulation_matches_full_batch():
    """K-way grad accumulation == single big batch (same data, fp32 accum)."""
    cfg = reduced(get_config("minicpm-2b"))
    key = jax.random.PRNGKey(2)
    params = spmd.init_params(cfg, key)
    opt = optim.sgd(1e-2)
    batch = _batch(cfg, key, b=8, s=32)
    s1 = jax.jit(spmd.make_train_step(cfg, opt, "syncdp", n_microbatches=1))
    s4 = jax.jit(spmd.make_train_step(cfg, opt, "syncdp", n_microbatches=4))
    p1, _, l1 = s1(params, opt.init(params), batch)
    p4, _, l4 = s4(params, opt.init(params), batch)
    # CE is a mean over tokens; microbatch mean-of-means equals the full mean
    # here because every microbatch has identical token counts.
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32), rtol=2e-4, atol=2e-5)


def test_shadow_step_per_replica_losses():
    """Shadow train_step returns one loss per replica, un-reduced."""
    cfg = reduced(get_config("minicpm-2b"))
    key = jax.random.PRNGKey(3)
    params = spmd.init_params(cfg, key)
    R = 2
    stack = jax.tree.map(jnp.copy, spmd.stack_replicas(params, R))
    opt = optim.sgd(1e-2)
    opt_stack = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(),
                             opt.init(params))
    b = _batch(cfg, key, b=4, s=32)
    batch = jax.tree.map(lambda x: x.reshape(R, 2, *x.shape[1:]), b)
    step = jax.jit(spmd.make_train_step(cfg, opt, "shadow"))
    _, _, loss = step(stack, opt_stack, batch)
    assert loss.shape == (R,)

"""Runtime lockdep harness: cycle detection, blocking-under-lock, and the
PR 5 demote-mid-wait barrier regression (DESIGN.md §12).

The point of the stall detector is proven the honest way: the PRE-fix
fixed_rate barrier (an arrival COUNTER, the exact shape the PR 5 bug had)
is replayed under the harness and the harness reports the wedged cohort;
the per-slot-flag barrier that replaced it runs the same schedule clean.
"""
import threading
import time

import pytest

from repro.analysis import lockdep
from repro.analysis.lockdep import (
    BlockedUnderLockError,
    DepCondition,
    DepLock,
    LockGraph,
    LockOrderError,
    instrument,
)

pytestmark = pytest.mark.timeout(120)


# ---------------------------------------------------------------------------
# Lock-order cycles
# ---------------------------------------------------------------------------
class TestLockOrder:
    def test_inversion_detected_without_deadlock(self):
        """A->B then B->A raises in ONE thread, no hung interleaving needed."""
        g = LockGraph()
        a = DepLock(g, site="a")
        b = DepLock(g, site="b")
        with a:
            with b:
                pass
        with pytest.raises(LockOrderError, match="cycle"):
            with b:
                with a:
                    pass
        assert g.violations

    def test_consistent_order_is_clean(self):
        g = LockGraph()
        a = DepLock(g, site="a")
        b = DepLock(g, site="b")
        for _ in range(3):
            with a:
                with b:
                    pass
        g.assert_clean()
        g.assert_acyclic()

    def test_cross_thread_inversion(self):
        """t1 takes A->B, the main thread B->A: the cycle closes across
        threads even though no actual deadlock occurs (the edges are what
        matter, not the unlucky interleaving)."""
        g = LockGraph()
        a = DepLock(g, site="a")
        b = DepLock(g, site="b")

        def t1():
            with a:
                with b:
                    pass

        th = threading.Thread(target=t1)
        th.start()
        th.join()
        with pytest.raises(LockOrderError):
            with b:
                with a:
                    pass

    def test_same_creation_site_instances_collapse(self):
        """Per-instance locks of N stores share a creation site, so an
        inversion between two *instances* is still a cycle."""
        g = LockGraph()

        class Store:
            def __init__(self):
                self.lock = DepLock(g, site="store.py:1")

        s1, s2 = Store(), Store()
        other = DepLock(g, site="other")
        with s1.lock:
            with other:
                pass
        with pytest.raises(LockOrderError):
            with other:
                with s2.lock:
                    pass


# ---------------------------------------------------------------------------
# Blocking under a held lock
# ---------------------------------------------------------------------------
class TestBlockedUnderLock:
    def test_sleep_under_lock_raises(self):
        with instrument() as g:
            lk = threading.Lock()
            with pytest.raises(BlockedUnderLockError):
                with lk:
                    time.sleep(0.01)
        assert g.violations

    def test_join_under_lock_raises(self):
        with instrument() as g:
            lk = threading.Lock()
            th = threading.Thread(target=lambda: None)
            th.start()
            with pytest.raises(BlockedUnderLockError):
                with lk:
                    th.join()
            th.join()
        g2 = LockGraph()  # the join-violation is recorded on g
        assert g.violations and not g2.violations

    def test_sleep_outside_lock_is_fine(self):
        with instrument() as g:
            lk = threading.Lock()
            with lk:
                pass
            time.sleep(0.001)
        g.assert_clean()

    def test_wait_on_held_condition_is_legal(self):
        """Condition.wait releases its own lock — never a blocking call."""
        with instrument() as g:
            cond = threading.Condition()
            with cond:
                cond.wait(timeout=0.01)
        g.assert_clean()


# ---------------------------------------------------------------------------
# The PR 5 regression: demote-mid-wait under a fixed_rate barrier
# ---------------------------------------------------------------------------
class _BuggyCounterBarrier:
    """The PRE-PR5 barrier shape: a party count + arrival counter. Readiness
    is evaluated only on ARRIVAL, so shrinking the cohort while waiters are
    parked (a policy demotion of a straggler mid-round) leaves everyone
    waiting on a predicate nothing will ever satisfy."""

    def __init__(self, parties: int):
        self.cond = threading.Condition()  # DepCondition under instrument()
        self.parties = parties
        self.arrived = 0
        self.gen = 0

    def remove_party(self) -> None:
        with self.cond:
            self.parties -= 1
            self.cond.notify_all()  # wakes waiters; they re-check gen only

    def wait(self) -> None:
        with self.cond:
            gen = self.gen
            self.arrived += 1
            if self.arrived >= self.parties:
                self.arrived = 0
                self.gen += 1
                self.cond.notify_all()
                return
            while self.gen == gen:
                self.cond.wait(timeout=0.05)


class _FixedFlagBarrier:
    """The shape that replaced it (core/runners.py _fr_sync_point):
    per-slot registration + arrival flags, readiness re-evaluated by every
    waiter on every wake over the slots that REMAIN registered."""

    def __init__(self, n: int):
        self.cond = threading.Condition()
        self.registered = [True] * n
        self.arrived = [False] * n
        self.gen = 0

    def _ready(self) -> bool:
        regs = [j for j, r in enumerate(self.registered) if r]
        return bool(regs) and all(self.arrived[j] for j in regs)

    def deregister(self, i: int) -> None:
        with self.cond:
            self.registered[i] = False
            self.cond.notify_all()

    def wait(self, i: int) -> None:
        with self.cond:
            if not self.registered[i]:
                return
            gen = self.gen
            self.arrived[i] = True
            while self.gen == gen and self.registered[i] and not self._ready():
                self.cond.wait(timeout=0.05)
            if self.gen == gen and not self.registered[i]:
                self.arrived[i] = False
                self.cond.notify_all()
                return
            if self.gen == gen:
                for j in range(len(self.arrived)):
                    self.arrived[j] = False
                self.gen += 1
                self.cond.notify_all()


class TestDemoteMidWaitRegression:
    def test_harness_catches_the_original_bug(self):
        """Replay: 3 registered slots, 2 arrive and park, the 3rd is demoted
        before arriving. arrived(2) >= parties(2) holds from that moment on,
        but the counter barrier only checks on arrival — the cohort is
        wedged. stalled() must see it despite the 50 ms timed re-waits."""
        with instrument(patch_blocking=False) as g:
            barrier = _BuggyCounterBarrier(parties=3)
            threads = [
                threading.Thread(target=barrier.wait, name=f"trainer-{i}")
                for i in range(2)
            ]
            for t in threads:
                t.start()
            time.sleep(0.3)  # both waiters parked
            barrier.remove_party()  # the PR 5 demotion, mid-wait
            time.sleep(0.9)
            stuck = g.stalled(min_seconds=0.8)
            names = {name for name, _, _ in stuck}
            assert {"trainer-0", "trainer-1"} <= names, (
                f"harness missed the wedged cohort: {stuck}")
            # un-wedge so the test itself exits cleanly
            with barrier.cond:
                barrier.gen += 1
                barrier.cond.notify_all()
            for t in threads:
                t.join(timeout=5)
            assert not any(t.is_alive() for t in threads)
        # after release the wait epochs are gone — no residual stall
        assert g.stalled(min_seconds=0.1) == []

    def test_fixed_barrier_survives_the_same_schedule(self):
        """The per-slot-flag barrier re-evaluates readiness on every wake:
        the identical demote-mid-wait schedule completes, and the harness
        reports nothing."""
        with instrument(patch_blocking=False) as g:
            barrier = _FixedFlagBarrier(3)
            threads = [
                threading.Thread(target=barrier.wait, args=(i,),
                                 name=f"trainer-{i}")
                for i in range(2)
            ]
            for t in threads:
                t.start()
            time.sleep(0.3)
            barrier.deregister(2)  # same mid-wait demotion
            for t in threads:
                t.join(timeout=5)
            assert not any(t.is_alive() for t in threads)
            assert g.stalled(min_seconds=0.8) == []
        g.assert_clean()


# ---------------------------------------------------------------------------
# The real stack under the harness
# ---------------------------------------------------------------------------
class TestInstrumentedStack:
    def test_threaded_runner_smoke_under_lockdep(self):
        """A small fixed_rate run with the full lock set (_fr_cond,
        _state_lock, _sync_lock, ex_lock, shard/cache locks) instrumented:
        no ordering cycle, no blocking-under-lock, no stalls left behind."""
        from repro import optim
        from repro.configs import dlrm_ctr
        from repro.core.runners import ThreadedShadowRunner
        from repro.core.sync import SyncConfig

        with instrument(patch_blocking=False) as g:
            r = ThreadedShadowRunner(
                dlrm_ctr.tiny(), SyncConfig(algo="easgd", alpha=0.5,
                                            mode="fixed_rate", gap=5),
                n_trainers=2, batch_size=32,
                optimizer=optim.adagrad(0.02), sync_sleep_s=0.002)
            out = r.run(15)
        assert out["sync_count"] > 0
        g.assert_clean()
        g.assert_acyclic()
        assert g.stalled(min_seconds=0.1) == []

    def test_instrument_restores_primitives(self):
        orig_lock, orig_cond = threading.Lock, threading.Condition
        with instrument():
            assert threading.Lock is not orig_lock
        assert threading.Lock is orig_lock
        assert threading.Condition is orig_cond

    def test_nested_real_primitives_stay_real(self):
        """Event/Queue internals must not be instrumented (recursion +
        graph noise) — an Event constructed under instrument() works and
        contributes no sites."""
        with instrument() as g:
            ev = threading.Event()
            ev.set()
            assert ev.wait(timeout=0.1)
        assert g.sites == set()


class TestLockdepSelfConsistency:
    def test_dep_lock_is_context_manager_and_lockable(self):
        g = LockGraph()
        lk = DepLock(g, site="x")
        assert not lk.locked()
        with lk:
            assert lk.locked()
        assert not lk.locked()

    def test_dep_condition_notify_roundtrip(self):
        g = LockGraph()
        cond = DepCondition(graph=g)
        hits = []

        def waiter():
            with cond:
                while not hits:
                    cond.wait(timeout=0.05)

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.1)
        with cond:
            hits.append(1)
            cond.notify_all()
        t.join(timeout=5)
        assert not t.is_alive()
        g.assert_clean()

    def test_nonblocking_acquire_failure_not_counted_blocked(self):
        g = LockGraph()
        lk = DepLock(g, site="gate")
        with lk:
            # second non-blocking acquire fails; must not linger as blocked
            assert lk.acquire(blocking=False) is False
        assert g.snapshot_blocked() == []

    def test_lockdep_module_exports(self):
        for name in lockdep.__all__:
            assert hasattr(lockdep, name)

"""Elastic replica membership (DESIGN.md §8): the membership table, the
windowed EPS meter, active-mask kernel semantics, flat-vs-pytree parity under
membership schedules, join bootstrap/convergence, elastic checkpointing, and
the ThreadedShadowRunner fault-injection harness."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core import algorithms
from repro.core import sync as S
from repro.core.elp import EPSMeter
from repro.core.flatspace import LANE
from repro.core.membership import (
    FaultSpec, Membership, MembershipSchedule)
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)


jax.config.update("jax_platform_name", "cpu")

CFG = dlrm_ctr.tiny()
TOL = dict(rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Membership table
# ---------------------------------------------------------------------------

class TestMembershipTable:
    def test_initial_state_and_capacity(self):
        m = Membership(3, R_max=5)
        np.testing.assert_array_equal(m.active_mask(),
                                      [True, True, True, False, False])
        assert m.n_active == 3 and m.R_max == 5 and m.epoch == 0
        assert m.status(0) == "active" and m.status(4) == "dead"

    def test_join_lifecycle_and_epoch(self):
        m = Membership(2, R_max=3)
        m.join(2)
        assert m.status(2) == "joining"
        # a joining slot is NOT yet in the active mask (bootstrap in flight)
        np.testing.assert_array_equal(m.active_mask(), [True, True, False])
        m.activate(2)
        assert m.status(2) == "active" and m.n_active == 3
        assert m.epoch == 2
        assert [(e.kind, e.slot) for e in m.events] == [("join", 2),
                                                        ("activate", 2)]

    def test_fail_and_leave(self):
        m = Membership(3)
        m.fail(1)
        assert m.status(1) == "dead" and m.n_active == 2
        m.leave(2)
        assert m.n_active == 1
        np.testing.assert_array_equal(m.active_ids(), [0])

    def test_invalid_transitions_raise(self):
        m = Membership(2, R_max=3)
        with pytest.raises(ValueError, match="cannot join"):
            m.join(0)  # already active
        with pytest.raises(ValueError, match="cannot activate"):
            m.activate(2)  # dead, not joining
        with pytest.raises(ValueError, match="cannot fail"):
            m.fail(2)  # already dead
        with pytest.raises(ValueError, match="out of range"):
            m.fail(7)

    def test_from_mask_arbitrary_pattern(self):
        m = Membership.from_mask([True, False, True, False])
        np.testing.assert_array_equal(m.active_ids(), [0, 2])
        with pytest.raises(ValueError, match="at least one"):
            Membership.from_mask([False, False])

    def test_mask_is_a_copy(self):
        m = Membership(2)
        a = m.active_mask()
        a[0] = False
        assert m.n_active == 2

    def test_schedule_validation_and_lookup(self):
        s = MembershipSchedule([(6, "fail", 2), (10, "join", 2), (6, "leave", 0)])
        assert s.events_at(6) == [("fail", 2), ("leave", 0)]
        assert s.events_at(7) == []
        assert s.max_slot() == 2
        with pytest.raises(ValueError, match="unknown schedule event"):
            MembershipSchedule([(1, "explode", 0)])

    def test_fault_spec_validation(self):
        FaultSpec(crash_at={1: 5}, straggler_sleep_s={0: 0.1}).validate(3)
        with pytest.raises(ValueError, match="out of range"):
            FaultSpec(crash_at={4: 5}).validate(3)


# ---------------------------------------------------------------------------
# EPSMeter: a real sliding window (satellite — the old meter was cumulative)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


class TestEPSMeter:
    def test_steady_rate(self):
        clk = FakeClock()
        m = EPSMeter(window_s=5.0, clock=clk)
        for _ in range(10):
            clk.t += 0.5
            m.add(50)  # 100 eps
        assert m.eps == pytest.approx(100.0)

    def test_old_buckets_evicted(self):
        """A cumulative meter never forgets; the window must. After a burst
        followed by silence, the rate decays to zero."""
        clk = FakeClock()
        m = EPSMeter(window_s=2.0, clock=clk)
        clk.t += 0.1
        m.add(1000)
        clk.t += 10.0
        assert m.eps == 0.0

    def test_rate_recovers_to_survivor_pace(self):
        """The elasticity use case: 2 trainers at 100 eps each, one dies;
        the windowed rate converges to 100, not the diluted cumulative."""
        clk = FakeClock()
        m = EPSMeter(window_s=2.0, clock=clk)
        for _ in range(20):  # both alive: 200 eps
            clk.t += 0.1
            m.add(10)
            m.add(10)
        assert m.eps == pytest.approx(200.0, rel=0.1)
        for _ in range(40):  # one crashed: 100 eps
            clk.t += 0.1
            m.add(10)
        assert m.eps == pytest.approx(100.0, rel=0.1)

    def test_partial_window_uses_elapsed_time(self):
        clk = FakeClock()
        m = EPSMeter(window_s=10.0, clock=clk)
        clk.t += 1.0
        m.add(100)
        assert m.eps == pytest.approx(100.0)

    def test_zero_elapsed_is_zero(self):
        m = EPSMeter(window_s=5.0, clock=FakeClock())
        assert m.eps == 0.0


# ---------------------------------------------------------------------------
# Active-mask (rows) kernels vs oracles: dead slots bit-identical, live mean
# ---------------------------------------------------------------------------

class TestRowsKernels:
    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("rows", [(0, 2, 4), (1,), (0, 1, 2, 3, 4)])
    def test_masked_mean_and_pullback(self, rows, use_pallas):
        from repro.kernels.ma_update.ops import (
            ma_sync_rows_op, replica_mean_rows_op)

        key = jax.random.PRNGKey(0)
        stack = jax.random.normal(key, (5, 256, LANE), jnp.float32)
        rows_arr = jnp.asarray(rows, jnp.int32)
        mean = replica_mean_rows_op(stack, rows_arr, use_pallas=use_pallas)
        # the mean divides by the LIVE count, not R
        np.testing.assert_allclose(
            np.asarray(mean), np.asarray(jnp.mean(stack[rows_arr], axis=0)),
            **TOL)
        new = ma_sync_rows_op(stack.copy(), mean, rows_arr, 0.4,
                              use_pallas=use_pallas)
        oracle = S.ma_round(
            {"w": stack}, 0.4,
            active=jnp.asarray([i in rows for i in range(5)]))
        np.testing.assert_allclose(np.asarray(new), np.asarray(oracle["w"]),
                                   **TOL)
        for i in range(5):
            if i not in rows:  # dead slots bit-identical
                assert np.array_equal(np.asarray(new[i]), np.asarray(stack[i]))

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_bmuf_rows_vs_masked_oracle(self, use_pallas):
        from repro.kernels.bmuf_update.ops import bmuf_sync_rows_op
        from repro.kernels.ma_update.ops import replica_mean_rows_op

        key = jax.random.PRNGKey(3)
        stack = jax.random.normal(key, (4, 256, LANE), jnp.float32)
        active = jnp.asarray([True, False, True, True])
        rows = jnp.asarray([0, 2, 3], jnp.int32)
        wg = jnp.mean(stack, axis=0)
        vel = jnp.zeros_like(wg)
        mean = replica_mean_rows_op(stack, rows, use_pallas=use_pallas)
        new, nwg, nvel = bmuf_sync_rows_op(
            stack.copy(), mean, wg.copy(), vel.copy(), rows, 0.5, eta=0.9,
            block_momentum=0.8, nesterov=True, use_pallas=use_pallas)
        o_stack, o_state = S.bmuf_round(
            {"w": stack}, S.BMUFState(w_global={"w": wg}, velocity={"w": vel}),
            0.5, eta=0.9, block_momentum=0.8, nesterov=True, active=active)
        np.testing.assert_allclose(np.asarray(new), np.asarray(o_stack["w"]), **TOL)
        np.testing.assert_allclose(np.asarray(nwg),
                                   np.asarray(o_state.w_global["w"]), **TOL)
        np.testing.assert_allclose(np.asarray(nvel),
                                   np.asarray(o_state.velocity["w"]), **TOL)
        assert np.array_equal(np.asarray(new[1]), np.asarray(stack[1]))

    def test_gossip_ring_drawn_over_active_only(self):
        active = np.asarray([True, False, True, True, False, True])
        partner = algorithms._ring_partner_active_np(active, 0)
        # dead slots are their own partner; live partners are live
        for i in range(6):
            if not active[i]:
                assert partner[i] == i
            else:
                assert active[partner[i]]
        # involution over the live subset
        for i in np.flatnonzero(active):
            assert partner[partner[i]] == i
        rows, _, pp = algorithms._gossip_participants_np(
            np.asarray([False, False, True, False, False, False]), 6, 0,
            active=active)
        assert all(active[r] for r in rows)


# ---------------------------------------------------------------------------
# Flat-vs-pytree parity under a NON-TRIVIAL membership schedule, every algo
# ---------------------------------------------------------------------------

# fail slot 1, re-join it, then grow capacity with a brand-new slot 3 —
# exercises masked training, masked landing, live-count means, join
# bootstrap, and a sync in flight across a membership change (delay=1).
SCHED = ((5, "fail", 1), (9, "join", 1), (11, "join", 3))


@functools.lru_cache(maxsize=None)
def _run_elastic(algo, engine, mode="shadow", iters=16):
    sim = HogwildSim(
        CFG, SyncConfig(algo=algo, mode=mode, gap=4, alpha=0.5, delay=1,
                        engine=engine),
        n_trainers=3, n_threads=2, batch_size=32,
        optimizer=optim.adagrad(0.02), seed=0, schedule=list(SCHED))
    out = sim.run(iters)
    return (tuple(out["train_loss"]), out["sync_count"],
            out["replica_losses"], sim, out)


@pytest.mark.parametrize("algo", algorithms.names())
def test_elastic_flat_matches_pytree_shadow(algo):
    loss_f, n_f, _, _, _ = _run_elastic(algo, "flat")
    loss_p, n_p, _, _, _ = _run_elastic(algo, "pytree")
    assert n_f == n_p > 0
    np.testing.assert_allclose(loss_f, loss_p, **TOL)


@pytest.mark.parametrize("algo", algorithms.names())
def test_elastic_flat_matches_pytree_fixed_rate(algo):
    loss_f, _, _, _, _ = _run_elastic(algo, "flat", mode="fixed_rate")
    loss_p, _, _, _, _ = _run_elastic(algo, "pytree", mode="fixed_rate")
    np.testing.assert_allclose(loss_f, loss_p, **TOL)


def test_dead_slot_frozen_while_dead():
    """After fail(1)@5 the dead slot's replica must be bit-frozen: no
    training update, no sync landing."""
    sim = HogwildSim(
        CFG, SyncConfig(algo="ma", mode="shadow", gap=4, alpha=0.5, delay=1,
                        engine="flat"),
        n_trainers=3, n_threads=2, batch_size=32,
        optimizer=optim.adagrad(0.02), seed=0, schedule=[(5, "fail", 1)])
    st = sim.init_state()
    frozen = {}

    def watch(t, _loss):
        if t in (5, 7):  # during the dead window (fail applied at start of 5)
            frozen[t] = (sim.membership.active_mask().copy(),
                         np.asarray(st.w_stack[1]))

    sim.run(8, state=st, on_iter=watch)
    m5, w5 = frozen[5]
    m7, w7 = frozen[7]
    assert not m5[1] and not m7[1]
    assert np.array_equal(w5, w7)  # bit-identical through the dead window


@pytest.mark.parametrize("mode", ["shadow", "fixed_rate"])
def test_all_dead_cohort_survives(mode):
    """Killing every slot mid-run must not crash the masked kernels (empty
    row sets) — training becomes a no-op, losses go nan, syncs stop."""
    sim = HogwildSim(
        CFG, SyncConfig(algo="ma", mode=mode, gap=2, alpha=0.5, delay=1,
                        engine="flat"),
        n_trainers=2, n_threads=2, batch_size=32,
        optimizer=optim.adagrad(0.02), seed=0,
        schedule=[(3, "fail", 0), (3, "fail", 1)])
    out = sim.run(7)
    assert np.isfinite(out["train_loss"][:3]).all()
    assert np.isnan(out["train_loss"][3:]).all()
    # dead-window iterations train nothing
    assert out["examples"] == 3 * 2 * 2 * 32


def test_avg_sync_gap_counts_live_iterations_only():
    """With half the cohort dead most of the run, the gap metric must divide
    by replica-iterations actually trained, not n_iters * R_max."""
    sim = HogwildSim(
        CFG, SyncConfig(algo="ma", mode="fixed_rate", gap=2, engine="flat"),
        n_trainers=2, n_threads=1, batch_size=32,
        optimizer=optim.adagrad(0.02), seed=0, schedule=[(2, "fail", 1)])
    out = sim.run(10)
    live_iters = out["examples"] // 32  # M=1, B=32
    assert live_iters == 2 * 2 + 8 * 1
    assert out["avg_sync_gap"] == pytest.approx(
        live_iters / out["sync_count"])


def test_capacity_padding_no_reallocation():
    """Capacity R_max is allocated once; join of a spare slot must not change
    the buffer object shape (no reallocation, no retrace)."""
    sim = HogwildSim(
        CFG, SyncConfig(algo="ma", engine="flat"), n_trainers=2, n_threads=2,
        batch_size=32, optimizer=optim.adagrad(0.02), seed=0,
        schedule=[(3, "join", 2)])
    assert sim.R == 3  # capacity includes the scheduled spare slot
    st = sim.init_state()
    assert st.w_stack.shape[0] == 3
    out = sim.run(6, state=st)
    assert out["state"].w_stack.shape[0] == 3
    assert sim.membership.n_active == 3


# ---------------------------------------------------------------------------
# Join bootstrap (on_join) + convergence of the joined replica
# ---------------------------------------------------------------------------

class TestJoinBootstrap:
    def test_default_on_join_is_live_mean_both_engines(self):
        algo = algorithms.get("ma")
        sc = SyncConfig(algo="ma")
        key = jax.random.PRNGKey(0)
        stack = {"w": jax.random.normal(key, (4, 6, 3))}
        active = np.asarray([True, True, False, False])
        new, _ = algo.on_join(stack, 3, None, jnp.asarray(active), sc)
        np.testing.assert_allclose(
            np.asarray(new["w"][3]),
            np.asarray(0.5 * (stack["w"][0] + stack["w"][1])), **TOL)
        # flat engine agrees
        from repro.core.flatspace import FlatSpace
        fs = FlatSpace.from_tree({"w": stack["w"][0]}, block=8)
        buf = fs.pack_stack(stack)
        buf2, _ = algo.on_join_flat(buf, 3, None, active, sc, fs)
        np.testing.assert_allclose(np.asarray(fs.unpack(buf2[3])["w"]),
                                   np.asarray(new["w"][3]), **TOL)

    def test_easgd_on_join_adopts_ps(self):
        algo = algorithms.get("easgd")
        sc = SyncConfig(algo="easgd")
        stack = {"w": jnp.ones((3, 4))}
        ps = {"w": jnp.full((4,), 7.0)}
        new, _ = algo.on_join(stack, 2, ps, jnp.asarray([True, True, False]), sc)
        np.testing.assert_allclose(np.asarray(new["w"][2]), 7.0)

    @pytest.mark.parametrize("algo", ["easgd", "ma"])
    def test_joined_replica_converges_to_cohort(self, algo):
        """Acceptance: a mid-run join bootstraps via on_join and the joined
        replica's loss converges to the cohort's."""
        sim = HogwildSim(
            CFG, SyncConfig(algo=algo, mode="shadow", gap=3, alpha=0.5,
                            delay=1, engine="flat"),
            n_trainers=3, n_threads=2, batch_size=64,
            optimizer=optim.adagrad(0.02), seed=1,
            schedule=[(10, "join", 3)])
        out = sim.run(24)
        rl = out["replica_losses"]  # (T, R_max)
        # joined replica's first loss is already near the cohort (bootstrap
        # from the live mean / PS, not from the stale init)
        joined_first = rl[10, 3]
        cohort_at_join = rl[10, :3].mean()
        init_loss = rl[0, :3].mean()
        assert abs(joined_first - cohort_at_join) < 0.5 * abs(
            init_loss - cohort_at_join)
        # and it tracks the cohort at the end
        assert abs(rl[-1, 3] - rl[-1, :3].mean()) < 0.1


# ---------------------------------------------------------------------------
# Elastic checkpoint: save at R=4, restore and TRAIN at R=6 (and shrink)
# ---------------------------------------------------------------------------

class TestElasticCheckpointRestore:
    def _mk(self, n, algo="easgd", engine="flat"):
        return HogwildSim(
            CFG, SyncConfig(algo=algo, gap=4, alpha=0.5, engine=engine),
            n_trainers=n, n_threads=2, batch_size=32,
            optimizer=optim.adagrad(0.02), seed=0)

    @pytest.mark.parametrize("algo", ["easgd", "bmuf"])
    def test_grow_r4_to_r6_and_train(self, tmp_path, algo):
        path = os.path.join(tmp_path, "ck")
        sim4 = self._mk(4, algo=algo)
        out4 = sim4.run(8)
        sim4.save_state(path, out4["state"])
        sim6 = self._mk(6, algo=algo)
        st6 = sim6.load_state(path)
        assert st6.w_stack.shape[0] == 6
        # restored cohort rows are bit-equal to the saved ones
        np.testing.assert_allclose(np.asarray(st6.w_stack[:4]),
                                   np.asarray(out4["state"].w_stack),
                                   rtol=1e-6, atol=1e-7)
        if algo == "easgd":
            # new slots bootstrapped from the sync-PS copy via on_join
            np.testing.assert_allclose(np.asarray(st6.w_stack[4]),
                                       np.asarray(st6.algo_state),
                                       rtol=1e-6)
        out6 = sim6.run(6, state=st6)
        assert all(np.isfinite(l) for l in out6["train_loss"])
        # the grown cohort trains onward, not from scratch
        assert out6["train_loss"][0] < out4["train_loss"][0]

    def test_shrink_r4_to_r2(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        sim4 = self._mk(4)
        out4 = sim4.run(6)
        sim4.save_state(path, out4["state"])
        sim2 = self._mk(2)
        st2 = sim2.load_state(path)
        assert st2.w_stack.shape[0] == 2
        out2 = sim2.run(3, state=st2)
        assert all(np.isfinite(l) for l in out2["train_loss"])

    def test_dead_at_save_slot_is_bootstrapped_not_resurrected(self, tmp_path):
        """A slot that was dead when the checkpoint was written holds stale
        weights; a sim that wants it active on resume must re-bootstrap it
        via on_join, not silently resurrect the stale row."""
        path = os.path.join(tmp_path, "ck")
        sim_a = HogwildSim(
            CFG, SyncConfig(algo="easgd", gap=4, alpha=0.5, engine="flat"),
            n_trainers=3, n_threads=2, batch_size=32,
            optimizer=optim.adagrad(0.02), seed=0, schedule=[(2, "fail", 1)])
        out = sim_a.run(6)
        stale_row = np.asarray(out["state"].w_stack[1])
        sim_a.save_state(path, out["state"])
        sim_b = self._mk(3)  # wants all 3 slots active
        st = sim_b.load_state(path)
        # slot 1 re-bootstrapped from the PS (easgd's on_join), not stale
        np.testing.assert_allclose(np.asarray(st.w_stack[1]),
                                   np.asarray(st.algo_state), rtol=1e-6)
        assert not np.allclose(np.asarray(st.w_stack[1]), stale_row)

    def test_engine_mismatch_raises_clearly(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        sim_f = self._mk(3, engine="flat")
        out = sim_f.run(3)
        sim_f.save_state(path, out["state"])
        sim_p = self._mk(3, engine="pytree")
        with pytest.raises(ValueError, match="engine"):
            sim_p.load_state(path)

    def test_metadata_round_trips(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        sim = self._mk(3)
        out = sim.run(4)
        sim.save_state(path, out["state"], metadata={"note": "x"})
        from repro import checkpoint as ckpt
        _, meta = ckpt.restore(path, sim._state_tree(out["state"]))
        assert meta["R"] == 3 and meta["step"] == 4 and meta["note"] == "x"
        assert meta["algo"] == "easgd" and meta["engine"] == "flat"


# ---------------------------------------------------------------------------
# ThreadedShadowRunner fault injection (acceptance a)
# ---------------------------------------------------------------------------

def _threaded(mode, fault=None, iters=12, algo="easgd", **kw):
    r = ThreadedShadowRunner(
        CFG, SyncConfig(algo=algo, alpha=0.5, mode=mode, gap=3),
        n_trainers=3, batch_size=32, optimizer=optim.adagrad(0.02),
        sync_sleep_s=0.01, fault_spec=fault, **kw)
    return r.run(iters)


class TestThreadedFaults:
    @pytest.fixture(scope="class", autouse=True)
    def warmup(self):
        # compile both modes' programs so timing comparisons are clean
        _threaded("shadow", iters=2)
        _threaded("fixed_rate", iters=2)

    def test_crash_completes_and_survivors_keep_pace(self):
        """One crashed trainer: the run completes, survivors finish all their
        iterations, and their EPS stays within 20% of the no-fault run."""
        base = _threaded("shadow", iters=12)
        out = _threaded("shadow", FaultSpec(crash_at={2: 3}), iters=12)
        assert out["iter_count"] == [12, 12, 3]
        assert [e.kind for e in out["membership_events"]] == ["fail"]
        surv = np.mean([out["per_trainer_eps"][i] for i in (0, 1)])
        ref = np.mean([base["per_trainer_eps"][i] for i in (0, 1)])
        assert surv >= 0.8 * ref, (surv, ref)
        assert all(np.isfinite(out["train_loss"][i]) for i in (0, 1))

    def test_fixed_rate_degrades_to_straggler_pace(self):
        """The foreground baseline blocks at every sync point, so one
        straggler drags the WHOLE cohort; background shadow sync leaves the
        healthy trainers at full speed."""
        # the sleep must dominate per-iteration compute on a loaded CI box
        # (untraced first iterations here cost ~0.2-0.5 s), or CPU
        # contention blurs the shadow-vs-foreground contrast
        sleep = 0.6
        fault = FaultSpec(straggler_sleep_s={2: sleep})
        iters = 9
        sh = _threaded("shadow", fault, iters=iters)
        fr = _threaded("fixed_rate", fault, iters=iters)
        surv_sh = np.mean([sh["per_trainer_eps"][i] for i in (0, 1)])
        surv_fr = np.mean([fr["per_trainer_eps"][i] for i in (0, 1)])
        # fixed-rate survivors are held near the straggler's pace
        assert surv_fr < 0.6 * surv_sh, (surv_fr, surv_sh)
        # the straggler's sleep is a hard floor on the fixed-rate wall
        assert fr["wall_s"] >= iters * sleep

    def test_threaded_join_bootstraps_and_trains(self):
        out = _threaded("shadow", FaultSpec(join_at={2: 4}), iters=10)
        kinds = [(e.kind, e.slot) for e in out["membership_events"]]
        assert ("join", 2) in kinds and ("activate", 2) in kinds
        assert out["iter_count"][2] > 0
        assert np.isfinite(out["train_loss"][2])

    def test_fixed_rate_crash_does_not_deadlock(self):
        out = _threaded("fixed_rate", FaultSpec(crash_at={1: 4}), iters=9)
        assert out["iter_count"][0] == 9 and out["iter_count"][2] == 9
        assert out["iter_count"][1] == 4
        assert out["sync_count"] > 0

    def test_join_after_whole_cohort_crashed_does_not_hang(self):
        """If every initially-active trainer crashes before a join_at
        target, the joiner must bail out instead of spinning forever on a
        frozen progress counter (run() would never return)."""
        out = _threaded("shadow",
                        FaultSpec(crash_at={0: 2, 1: 2}, join_at={2: 50}),
                        iters=8)
        assert out["iter_count"] == [2, 2, 0]
        assert [e.kind for e in out["membership_events"]] == ["fail", "fail"]

    def test_sync_count_consistent_under_threads(self):
        """The counter satellite: with the lock in place the total must equal
        the sum of per-round increments (no lost updates observable as a
        negative or absurd value)."""
        out = _threaded("shadow", iters=8)
        assert 0 < out["sync_count"] < 10_000_000
        assert out["avg_sync_gap"] > 0

"""core/supervision + the runner's failure domains (DESIGN.md §10).

Unit layer: the ``Supervisor`` state machine driven deterministically — an
injected clock and public ``check_once`` replace the background watch loop,
so death/stall detection, exponential backoff, restart budgets, generation
fencing, and the give-up escalation are all asserted without sleeping.

Integration layer: real ``ThreadedShadowRunner`` chaos runs — the shadow
thread crashing and stalling mid-run (restarted against live membership,
sync_count strictly increasing afterwards), the restart budget exhausting
into the degradation ladder (final foreground sync at shutdown), an
embedding PS failing and rehydrating from its background snapshot (stale
reads + dropped writes counted, trainers never blocked), injected trainer
exceptions re-raised with slot provenance, and overlapping fault events
(crash + join + auto-demotion in one window) resolving without deadlock.
"""
import threading
import time
import warnings

import jax
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.membership import FaultSpec
from repro.core.runners import ThreadedShadowRunner
from repro.core.scheduler import PolicyConfig, StragglerPolicy
from repro.core.supervision import (
    SupervisionEvent,
    Supervisor,
    SupervisorConfig,
)
from repro.core.sync import SyncConfig

jax.config.update("jax_platform_name", "cpu")

# threaded chaos tests must never wedge CI: pytest-timeout enforces this
# ceiling when installed (requirements-ci.txt); locally it is a no-op marker
pytestmark = pytest.mark.timeout(120)

CFG = dlrm_ctr.tiny()


# ---------------------------------------------------------------------------
# Unit: the Supervisor state machine, deterministically
# ---------------------------------------------------------------------------

class _FakeThread:
    """Stands in for threading.Thread: only ``is_alive`` is consulted."""

    def __init__(self, alive=True):
        self.alive = alive

    def is_alive(self):
        return self.alive


class _Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def _mk_sup(clock, **kw):
    cfg = dict(heartbeat_deadline_s=1.0, check_interval_s=0.01,
               max_restarts=2, backoff_s=0.5, backoff_factor=2.0)
    cfg.update(kw)
    return Supervisor(SupervisorConfig(**cfg), clock=clock)


class TestSupervisorUnit:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="heartbeat_deadline_s"):
            SupervisorConfig(heartbeat_deadline_s=0).validate()
        with pytest.raises(ValueError, match="check_interval_s"):
            SupervisorConfig(check_interval_s=-1).validate()
        with pytest.raises(ValueError, match="max_restarts"):
            SupervisorConfig(max_restarts=-1).validate()
        with pytest.raises(ValueError, match="backoff"):
            SupervisorConfig(backoff_factor=0.5).validate()

    def test_healthy_thread_emits_nothing(self):
        clk = _Clock()
        sup = _mk_sup(clk)
        sup.register("w", _FakeThread())
        clk.t = 0.9
        sup.beat("w")
        clk.t = 1.8  # beat is only 0.9s old — inside the deadline
        assert sup.check_once() == []
        assert sup.events == []

    def test_death_detected_then_restarted_after_backoff(self):
        clk = _Clock()
        sup = _mk_sup(clk)
        spawned = []

        def restart():
            t = _FakeThread()
            spawned.append(t)
            return t

        dead = _FakeThread(alive=False)
        sup.register("w", dead, restart=restart)
        clk.t = 0.1
        evs = sup.check_once()
        assert [e.kind for e in evs] == ["death"]
        assert spawned == []  # backoff (0.5s) not yet elapsed
        clk.t = 0.3
        assert sup.check_once() == []  # still pending, still waiting
        clk.t = 0.65  # past failed_at + backoff_s
        evs = sup.check_once()
        assert [e.kind for e in evs] == ["restart"]
        assert len(spawned) == 1 and sup.thread("w") is spawned[0]
        assert sup.restarts("w") == 1
        assert not sup.is_degraded("w")

    def test_stall_detected_via_stale_heartbeat(self):
        clk = _Clock()
        sup = _mk_sup(clk)
        sup.register("w", _FakeThread(alive=True),
                     restart=lambda: _FakeThread())
        clk.t = 0.5
        sup.beat("w")
        clk.t = 1.4
        assert sup.check_once() == []  # 0.9s stale < 1.0s deadline
        clk.t = 1.6
        evs = sup.check_once()
        assert [e.kind for e in evs] == ["stall"]
        assert "stale" in evs[0].reason

    def test_beats_prevent_stall_forever(self):
        clk = _Clock()
        sup = _mk_sup(clk)
        sup.register("w", _FakeThread(alive=True))
        for i in range(50):
            clk.t += 0.9
            sup.beat("w")
            assert sup.check_once() == []

    def test_generation_bumps_on_restart_fencing_zombies(self):
        clk = _Clock()
        sup = _mk_sup(clk, backoff_s=0.0)
        sup.register("w", _FakeThread(alive=True),
                     restart=lambda: _FakeThread())
        gen0 = sup.generation("w")
        clk.t = 2.0  # heartbeat stale
        evs = sup.check_once()
        assert [e.kind for e in evs] == ["stall", "restart"]
        # the zombie (still alive!) sees itself superseded via the token
        assert sup.generation("w") == gen0 + 1

    def test_budget_exhausts_into_single_give_up(self):
        clk = _Clock()
        gave_up = []
        sup = _mk_sup(clk, max_restarts=2, backoff_s=0.0, backoff_factor=1.0)
        sup.register("w", _FakeThread(alive=False),
                     restart=lambda: _FakeThread(alive=False),
                     on_give_up=gave_up.append)
        kinds = []
        for _ in range(10):
            clk.t += 1.0
            kinds += [e.kind for e in sup.check_once()]
        # 2 restart attempts, then exactly one degraded escalation, then quiet
        assert kinds.count("restart") == 2
        assert kinds.count("degraded") == 1
        assert gave_up == ["w"]
        assert sup.is_degraded("w")
        assert sup.degraded_names() == ["w"]

    def test_watch_only_death_degrades_without_restart(self):
        clk = _Clock()
        gave_up = []
        sup = _mk_sup(clk)
        sup.register("w", _FakeThread(alive=False), on_give_up=gave_up.append)
        clk.t = 0.1
        evs = sup.check_once()
        assert [e.kind for e in evs] == ["death", "degraded"]
        assert "watch-only" in evs[1].reason
        assert gave_up == ["w"]

    def test_deregister_stops_watching(self):
        clk = _Clock()
        sup = _mk_sup(clk)
        sup.register("w", _FakeThread(alive=False))
        sup.deregister("w")
        clk.t = 5.0
        assert sup.check_once() == []
        assert sup.thread("w") is None

    def test_duplicate_registration_rejected(self):
        sup = _mk_sup(_Clock())
        sup.register("w", _FakeThread())
        with pytest.raises(ValueError, match="already supervised"):
            sup.register("w", _FakeThread())

    def test_exponential_backoff_schedule(self):
        clk = _Clock()
        spawned = []

        def restart():
            t = _FakeThread(alive=False)  # crash-loop: replacement dies too
            spawned.append(clk.t)
            return t

        sup = _mk_sup(clk, max_restarts=3, backoff_s=1.0, backoff_factor=2.0)
        sup.register("w", _FakeThread(alive=False), restart=restart)
        clk.t = 0.0
        sup.check_once()  # death at t=0 (failed_at anchors here)
        for t in np.arange(0.1, 12.0, 0.1):
            clk.t = float(t)
            sup.check_once()
        # attempt k waits backoff_s * factor**(restarts): 1s, then the
        # replacement's death re-anchors and waits 2s, then 4s
        assert len(spawned) == 3
        assert spawned[0] == pytest.approx(1.0, abs=0.11)
        gaps = np.diff([0.0] + spawned)
        assert gaps[1] >= 2.0 and gaps[2] >= 4.0

    def test_watch_loop_runs_tick_and_detects(self):
        """The real background loop (no injected clock): a registered thread
        that exits is detected and the tick callback keeps firing."""
        ticks = []
        sup = Supervisor(SupervisorConfig(heartbeat_deadline_s=5.0,
                                          check_interval_s=0.005),
                         tick=lambda: ticks.append(1))
        t = threading.Thread(target=lambda: None)
        t.start()
        t.join()
        sup.register("gone", t)
        sup.start()
        with pytest.raises(RuntimeError, match="already started"):
            sup.start()
        deadline = time.perf_counter() + 2.0
        while (not any(e.kind == "death" for e in sup.events)
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        sup.stop()
        assert any(e.kind == "death" for e in sup.events)
        assert any(e.kind == "degraded" for e in sup.events)  # watch-only
        assert len(ticks) >= 1

    def test_event_record_shape(self):
        ev = SupervisionEvent("stall", "shadow", 1.5, "why")
        assert (ev.kind, ev.name, ev.t, ev.reason) == ("stall", "shadow",
                                                       1.5, "why")


# ---------------------------------------------------------------------------
# Integration: ThreadedShadowRunner chaos
# ---------------------------------------------------------------------------

SNAPPY = SupervisorConfig(heartbeat_deadline_s=0.5, check_interval_s=0.01,
                          backoff_s=0.05, max_restarts=3)


def _runner(mode="shadow", fault=None, sup_cfg=SNAPPY, **kw):
    r = ThreadedShadowRunner(
        CFG, SyncConfig(algo="easgd", alpha=0.5, mode=mode, gap=3),
        n_trainers=3, batch_size=32, optimizer=optim.adagrad(0.02),
        sync_sleep_s=0.01, fault_spec=fault, supervisor_config=sup_cfg, **kw)
    r.warmup()
    return r


class TestRunnerChaos:
    @pytest.fixture(scope="class", autouse=True)
    def warmup(self):
        _runner().run(2)

    def test_sync_crash_restart_resumes_syncing(self):
        """The tentpole acceptance: the sync thread dies, the supervisor
        restarts it against live membership, and sync_count STRICTLY
        increases post-restart."""
        out = _runner(fault=FaultSpec(sync_crash_at=2)).run(40)
        assert out["sync_restarts"] >= 1
        assert out["sync_count_at_restart"], "restart bookkeeping missing"
        assert out["sync_count"] > out["sync_count_at_restart"][0]
        kinds = [e.kind for e in out["supervision_events"]]
        assert "death" in kinds and "restart" in kinds
        assert not out["sync_degraded"]
        # provenance reached the membership log too
        assert any(e.kind == "sync_restart" for e in out["membership_events"])
        # and the cohort trained to completion regardless
        assert out["iter_count"] == [40, 40, 40]

    def test_sync_stall_fenced_and_restarted(self):
        """A stalled-but-alive shadow thread: detected via stale heartbeat,
        a replacement spawned, the zombie fenced out by its generation.
        Trainers carry a per-iteration sleep so the run comfortably outlives
        the 0.5s heartbeat deadline the detection needs to expire."""
        out = _runner(fault=FaultSpec(
            sync_stall_at=2, sync_stall_s=1.5,
            straggler_sleep_s={i: 0.03 for i in range(3)})).run(40)
        kinds = [e.kind for e in out["supervision_events"]]
        assert "stall" in kinds and "restart" in kinds
        assert out["sync_restarts"] >= 1
        assert out["sync_count"] > out["sync_count_at_restart"][0]
        assert out["iter_count"] == [40, 40, 40]

    def test_restart_budget_exhausted_degrades_with_final_sync(self):
        """Degradation ladder: budget 0 means the first death escalates —
        training continues locally, the membership log records ``degraded``
        with provenance, and shutdown forces one foreground sync."""
        cfg = SupervisorConfig(heartbeat_deadline_s=0.5,
                               check_interval_s=0.01, backoff_s=0.02,
                               max_restarts=0)
        out = _runner(fault=FaultSpec(sync_crash_at=1), sup_cfg=cfg).run(24)
        assert out["sync_degraded"]
        assert out["sync_restarts"] == 0
        assert out["final_foreground_sync"]
        deg = [e for e in out["membership_events"] if e.kind == "degraded"]
        assert deg and "restart budget exhausted" in deg[0].reason
        assert out["iter_count"] == [24, 24, 24]  # training never blocked
        assert out["sync_count"] >= 1  # the forced shutdown sync landed

    def test_ps_fail_serves_snapshot_and_rehydrates(self):
        """PS failure domain: lookups fall back to the background snapshot
        (counted), writes retry then drop (counted), recovery rehydrates
        within the provisioning delay, training never blocks."""
        out = _runner(fault=FaultSpec(ps_fail_at={0: 4},
                                      ps_recover_after_s=0.2)).run(40)
        kinds = [(e.kind, e.shard) for e in out["shard_events"]]
        assert ("ps_fail", 0) in kinds and ("ps_recover", 0) in kinds
        assert out["stale_lookups"][0] >= 1  # snapshot reads happened
        assert out["dropped_updates"][0] >= 1  # bounded-staleness cost paid
        # only the failed shard paid it
        assert sum(out["dropped_updates"][1:]) == 0
        assert out["iter_count"] == [40, 40, 40]
        notes = [e.kind for e in out["membership_events"]]
        assert "ps_fail" in notes and "ps_recover" in notes
        # the returned packed state reflects a healthy (rehydrated) substrate
        assert out["emb_state"]["table"].shape[0] > 0

    def test_ps_fail_in_fixed_rate_mode(self):
        """No shadow thread to take snapshots: the supervisor's watch loop
        takes them, and the same fail/recover cycle holds at the barrier."""
        out = _runner(mode="fixed_rate",
                      fault=FaultSpec(ps_fail_at={0: 4},
                                      ps_recover_after_s=0.2)).run(24)
        kinds = [(e.kind, e.shard) for e in out["shard_events"]]
        assert ("ps_fail", 0) in kinds and ("ps_recover", 0) in kinds
        assert out["iter_count"] == [24, 24, 24]
        assert out["sync_count"] >= 3  # the barrier kept firing throughout

    def test_trainer_exception_reraised_with_slot_provenance(self):
        """Satellite: a dying trainer thread is no longer silent — the run
        raises with the slot named, and membership recorded the failure."""
        r = _runner(fault=FaultSpec(raise_at={1: 3}))
        with pytest.raises(RuntimeError, match=r"slot 1.*injected trainer"):
            r.run(20)
        fails = [e for e in r.membership.events if e.kind == "fail"]
        assert fails and fails[0].slot == 1
        assert "RuntimeError" in fails[0].reason

    def test_survivors_unaffected_by_trainer_exception(self):
        r = _runner(fault=FaultSpec(raise_at={2: 2}))
        with pytest.raises(RuntimeError, match="slot 2"):
            r.run(16)
        # survivors trained to completion before the re-raise
        assert r.iter_count[0] == 16 and r.iter_count[1] == 16

    def test_chaos_without_supervision_rejected(self):
        with pytest.raises(ValueError, match="supervise"):
            ThreadedShadowRunner(
                CFG, SyncConfig(algo="easgd", mode="shadow", gap=3),
                n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
                fault_spec=FaultSpec(sync_crash_at=1), supervise=False)

    def test_sync_chaos_in_fixed_rate_rejected(self):
        with pytest.raises(ValueError, match="fixed_rate"):
            ThreadedShadowRunner(
                CFG, SyncConfig(algo="easgd", mode="fixed_rate", gap=3),
                n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
                fault_spec=FaultSpec(sync_crash_at=1))

    def test_bad_ps_shard_id_rejected(self):
        with pytest.raises(ValueError, match="shard"):
            ThreadedShadowRunner(
                CFG, SyncConfig(algo="easgd", mode="shadow", gap=3),
                n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
                n_emb_shards=2, fault_spec=FaultSpec(ps_fail_at={9: 1}))


class TestOverlappingFaults:
    """Satellite: concurrent fault events in the same round/window must
    resolve without deadlock or double-bookkeeping."""

    @pytest.fixture(scope="class", autouse=True)
    def warmup(self):
        _runner().run(2)

    @pytest.mark.parametrize("mode", ["shadow", "fixed_rate"])
    def test_crash_join_autodemote_same_window(self, mode):
        """Slot 0 crashes, slot 2 joins, and the policy demotes the slot-1
        straggler — all inside one short window. The run must complete with
        a consistent event log and exact per-slot accounting."""
        policy = StragglerPolicy(PolicyConfig(
            eps_floor_frac=0.5, readmit_frac=0.75, window_s=0.15,
            probation_s=0.2, min_active=1), n_slots=3)
        fault = FaultSpec(crash_at={0: 6}, join_at={2: 4},
                          straggler_sleep_s={1: 0.25},
                          straggler_until={1: 8})
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="easgd", alpha=0.5, mode=mode, gap=3),
            n_trainers=3, batch_size=32, optimizer=optim.adagrad(0.02),
            sync_sleep_s=0.01, fault_spec=fault, straggler_policy=policy,
            eps_window_s=0.25, supervisor_config=SNAPPY)
        r.warmup()
        out = r.run(30)  # would hang forever on any barrier/join bug
        ev_kinds = [e.kind for e in out["membership_events"]]
        assert "fail" in ev_kinds     # the crash
        assert "activate" in ev_kinds  # the join completed its bootstrap
        assert out["iter_count"][0] == 6   # crashed exactly at its fault
        assert out["iter_count"][2] >= 1   # the joiner actually trained
        # no double-decrement / resurrection: each slot has at most one
        # terminal fail event, and the final mask is internally consistent
        fails = [e for e in out["membership_events"] if e.kind == "fail"]
        assert len([e for e in fails if e.slot == 0]) == 1
        assert out["sync_count"] >= 1
        if any(e.kind == "leave" for e in out["membership_events"]):
            # when the demotion landed, it carried straggler provenance
            leaves = [e for e in out["membership_events"]
                      if e.kind == "leave"]
            assert any("straggler" in e.reason for e in leaves)

    def test_shadow_join_timeout_warns_instead_of_hanging(self):
        """Satellite: a wedged sync engine at shutdown produces a VISIBLE
        warning after the bounded join, not a silent eternal hang. A 30s
        sync_sleep (which ignores ``done``) wedges the shadow loop;
        supervision is off so nothing restarts it."""
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="easgd", alpha=0.5, mode="shadow", gap=3),
            n_trainers=2, batch_size=32, optimizer=optim.adagrad(0.02),
            sync_sleep_s=30.0, supervise=False)
        r.warmup()
        t0 = time.perf_counter()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = r.run(3)
        wall = time.perf_counter() - t0
        msgs = [str(x.message) for x in w]
        assert any("shadow thread failed to exit" in m for m in msgs), msgs
        assert wall < 25.0  # bounded: the 5s join timeout, not the 30s sleep
        assert out["iter_count"] == [3, 3]

"""NestPipe-style step pipelining (core/pipeline.py, DESIGN.md §13).

Three layers:

* **Unit layer** — ``PipelineConfig`` validation and the ``StepPipeline``
  state machine driven with synthetic prepare/stage functions: hazard-free
  streams overlap, colliding streams serialize (counted), ``drain()`` and
  epoch/shard-token mismatches drop staged values, a raising stage worker
  degrades the pipeline to serial instead of crashing the run.

* **Bitwise-parity layer** — pipelining is a PURE scheduling optimization:
  the pipelined trajectory equals the serial one bit for bit, for flat and
  pytree engines, cached and uncached, at depth 2 and 3, through elastic
  membership events (which drain in-flight stages), and in the
  all-indices-identical worst case where EVERY step hazards and the
  pipeline degenerates to counted serialization.

* **Composition layer** — real-thread runner smoke: per-trainer pipelines
  overlap against the shared Hogwild/cached embedding state, and a PS
  failure mid-run (shard incarnation bump) completes cleanly.
"""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.membership import FaultSpec
from repro.core.pipeline import PipelineConfig, PipelineStats, StepPipeline
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig
from repro.embeddings.cache import CacheConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.timeout(300)

CFG = dlrm_ctr.tiny()
# Wide row space: consecutive batches rarely touch the same rows, so the
# hazard check actually admits overlap. (The tiny config's small tables
# collide nearly every step — that stream is the worst-case test below.)
BIG = dataclasses.replace(
    CFG, table_sizes=(50_000,) * 4, n_sparse_features=4, multi_hot=2)
# Degenerate single-row tables: every batch reads row 0 of every table, so
# every staged step hazards against the one in flight — pure serialization.
ONE = dataclasses.replace(CFG, table_sizes=(1,) * 8)


# ---------------------------------------------------------------------------
# unit layer
# ---------------------------------------------------------------------------

def test_config_validation():
    assert PipelineConfig().depth == 2
    PipelineConfig(depth=1).validate()
    PipelineConfig(depth=3).validate()
    with pytest.raises(ValueError):
        PipelineConfig(depth=0).validate()
    with pytest.raises(ValueError):
        PipelineConfig(depth=-2).validate()


def _drive(pipe, n):
    """Serial-consume/stage loop (the runner's shape)."""
    got = []
    for t in range(n):
        vals, prep = pipe.consume(t)
        got.append(vals)
        pipe.stage(t)
    pipe.close()
    return got


def test_unit_disjoint_stream_overlaps():
    pipe = StepPipeline(
        PipelineConfig(depth=2), 1,
        prepare=lambda it: {"rows": [np.array([it], dtype=np.int64)]},
        stage_fn=lambda s, it, prep, ctx: f"v{it}")
    got = _drive(pipe, 5)
    # step 0 has nothing staged; steps 1..4 consume the staged value
    assert got[0] == [None]
    assert [g[0] for g in got[1:]] == ["v1", "v2", "v3", "v4"]
    st = pipe.stats
    assert (st.steps, st.shard_steps) == (5, 5)
    assert st.overlapped == 4 and st.hazard_serialized == 0
    assert st.worker_errors == 0 and pipe.error is None
    assert st.overlap_rate == pytest.approx(0.8)


def test_unit_colliding_stream_serializes():
    pipe = StepPipeline(
        PipelineConfig(depth=2), 1,
        prepare=lambda it: {"rows": [np.array([7], dtype=np.int64)]},
        stage_fn=lambda s, it, prep, ctx: f"v{it}", end=5)
    got = _drive(pipe, 5)
    assert all(g == [None] for g in got)  # nothing ever staged
    st = pipe.stats
    assert st.overlapped == 0 and st.hazard_serialized == 4
    assert st.overlap_rate == 0.0


def test_unit_depth_one_is_serial():
    pipe = StepPipeline(
        PipelineConfig(depth=1), 2,
        prepare=lambda it: {"rows": [np.array([it])] * 2},
        stage_fn=lambda s, it, prep, ctx: "never")
    got = _drive(pipe, 3)
    assert all(g == [None, None] for g in got)
    st = pipe.stats
    assert st.overlapped == 0 and st.hazard_serialized == 0
    assert st.shard_steps == 6


def test_unit_drain_drops_in_flight():
    pipe = StepPipeline(
        PipelineConfig(depth=3), 1,
        prepare=lambda it: {"rows": [np.array([it])]},
        stage_fn=lambda s, it, prep, ctx: f"v{it}")
    pipe.stage(0)  # stages steps 1 and 2
    pipe.drain()   # membership event: both dropped before consumption
    vals, _ = pipe.consume(1)
    assert vals == [None]
    st = pipe.stats
    assert st.drains == 2 and st.overlapped == 0
    pipe.close()


def test_unit_epoch_mismatch_drains_at_consume():
    epoch = [0]
    pipe = StepPipeline(
        PipelineConfig(depth=2), 1,
        prepare=lambda it: {"rows": [np.array([it])]},
        stage_fn=lambda s, it, prep, ctx: f"v{it}",
        epoch=lambda: epoch[0])
    pipe.stage(0)
    epoch[0] += 1  # membership epoch advances while step 1 is staged
    vals, _ = pipe.consume(1)
    assert vals == [None]
    assert pipe.stats.drains == 1
    pipe.close()


def test_unit_shard_token_mismatch_drains_that_shard():
    tok = [0, 0]
    pipe = StepPipeline(
        PipelineConfig(depth=2), 2,
        prepare=lambda it: {"rows": [np.array([it]), np.array([100 + it])]},
        stage_fn=lambda s, it, prep, ctx: f"v{s}:{it}",
        shard_token=lambda s: tok[s])
    pipe.stage(0)
    assert pipe._buf[1].done.wait(5.0)  # let the stager publish step 1
    tok[1] += 1  # PS 1 fails/recovers between staging and consumption
    vals, _ = pipe.consume(1)
    assert vals[0] == "v0:1" and vals[1] is None
    st = pipe.stats
    assert st.drains == 1 and st.overlapped == 1
    pipe.close()


def test_unit_worker_error_degrades_to_serial():
    def boom(s, it, prep, ctx):
        raise ValueError("injected stage failure")

    pipe = StepPipeline(
        PipelineConfig(depth=2), 1,
        prepare=lambda it: {"rows": [np.array([it])]},
        stage_fn=boom)
    got = _drive(pipe, 4)
    assert all(g == [None] for g in got)  # every consume fell back serial
    st = pipe.stats
    assert st.worker_errors >= 1 and st.overlapped == 0
    assert isinstance(pipe.error, ValueError)


def test_unit_close_is_idempotent_and_stops_worker():
    pipe = StepPipeline(
        PipelineConfig(depth=2), 1,
        prepare=lambda it: {"rows": [np.array([it])]},
        stage_fn=lambda s, it, prep, ctx: it)
    worker = pipe._worker
    assert worker is not None and worker.is_alive()
    pipe.close()
    pipe.close()
    assert not worker.is_alive()
    assert threading.active_count() >= 1  # no deadlock, main still here


# ---------------------------------------------------------------------------
# bitwise-parity layer (HogwildSim)
# ---------------------------------------------------------------------------

def _sim(pipeline, cfg=BIG, cache=None, engine="flat", seed=0, **kw):
    return HogwildSim(
        cfg, SyncConfig(algo="easgd", mode="shadow", gap=5, engine=engine),
        n_trainers=2, n_threads=1, batch_size=4,
        optimizer=optim.make("adagrad", 0.02), seed=seed,
        cache=cache, pipeline=pipeline, **kw)


def _assert_bitwise(out_s, out_p):
    assert out_s["train_loss"] == out_p["train_loss"]
    es, ep = out_s["state"].emb_state, out_p["state"].emb_state
    assert (np.asarray(es["table"]) == np.asarray(ep["table"])).all()
    assert (np.asarray(es["acc"]) == np.asarray(ep["acc"])).all()
    ws = np.asarray(jax.tree.leaves(out_s["state"].w_stack)[0])
    wp = np.asarray(jax.tree.leaves(out_p["state"].w_stack)[0])
    assert (ws == wp).all()


@pytest.mark.parametrize("engine", ["flat", "pytree"])
def test_sim_bitwise_uncached(engine):
    out_s = _sim(None, engine=engine).run(15)
    out_p = _sim(PipelineConfig(depth=2), engine=engine).run(15)
    _assert_bitwise(out_s, out_p)
    ps = out_p["pipeline_stats"]
    assert ps["overlapped"] > 0  # the wide stream genuinely overlapped
    assert ps["worker_errors"] == 0


def test_sim_bitwise_cached():
    cache = CacheConfig(hot_rows=2048, lookahead=2)
    out_s = _sim(None, cache=cache).run(15)
    out_p = _sim(PipelineConfig(depth=2), cache=cache).run(15)
    _assert_bitwise(out_s, out_p)
    ps = out_p["pipeline_stats"]
    assert ps["overlapped"] > 0
    # staged lookups really went through the hot-tier staged entry point
    assert out_p["cache_stats"]["staged_lookups"] > 0
    # and the cache itself stayed a pure placement optimization
    assert out_s["cache_stats"]["hit_rows"] == out_p["cache_stats"]["hit_rows"]


def test_sim_bitwise_depth_three():
    out_s = _sim(None).run(12)
    out_p = _sim(PipelineConfig(depth=3)).run(12)
    _assert_bitwise(out_s, out_p)
    assert out_p["pipeline_stats"]["overlapped"] > 0


def test_sim_all_identical_indices_pure_serialization():
    """Worst case: single-row tables make every batch read the same rows,
    so every staged step hazards — the pipeline degenerates to counted
    serialization and the trajectory is STILL bitwise-identical."""
    out_s = _sim(None, cfg=ONE).run(8)
    out_p = _sim(PipelineConfig(depth=2), cfg=ONE).run(8)
    _assert_bitwise(out_s, out_p)
    ps = out_p["pipeline_stats"]
    assert ps["overlapped"] == 0 and ps["overlap_rate"] == 0.0
    assert ps["hazard_serialized"] > 0


def test_sim_elastic_events_drain_bitwise():
    """Membership events drain in-flight stages before the epoch advances;
    the drained lookups rerun serially — the elastic trajectory matches."""
    sched = [(4, "fail", 1), (8, "join", 1)]
    out_s = _sim(None, schedule=sched, seed=3).run(12)
    out_p = _sim(PipelineConfig(depth=2), schedule=sched, seed=3).run(12)
    assert np.array_equal(out_s["replica_losses"], out_p["replica_losses"])
    assert (np.asarray(out_s["state"].emb_state["table"]) ==
            np.asarray(out_p["state"].emb_state["table"])).all()
    ps = out_p["pipeline_stats"]
    assert ps["drains"] >= 1  # the fail and join each dropped a staged step
    assert ps["overlapped"] > 0  # still overlapped between events


def test_sim_pipeline_stats_shape():
    out = _sim(PipelineConfig(depth=2)).run(6)
    ps = out["pipeline_stats"]
    assert set(ps) == {"steps", "shard_steps", "overlapped",
                       "hazard_serialized", "drains", "worker_errors",
                       "overlap_rate"}
    assert ps["steps"] == 6  # one logical step per iteration (packed store)
    merged = PipelineStats(**{k: v for k, v in ps.items()
                              if k != "overlap_rate"})
    assert merged.as_dict() == ps


# ---------------------------------------------------------------------------
# composition layer (ThreadedShadowRunner)
# ---------------------------------------------------------------------------

def _runner(pipeline, cache=None, fault=None, **kw):
    return ThreadedShadowRunner(
        BIG, SyncConfig(algo="easgd", gap=4, engine="flat"),
        n_trainers=2, batch_size=4, optimizer=optim.make("adagrad", 0.02),
        seed=2, cache=cache, pipeline=pipeline, fault_spec=fault, **kw)


@pytest.mark.parametrize("cache", [None, CacheConfig(hot_rows=2048, lookahead=2)],
                         ids=["uncached", "cached"])
def test_threaded_pipelined_smoke(cache):
    r = _runner(PipelineConfig(depth=2), cache=cache)
    out = r.run(10)
    assert out["iter_count"] == [10, 10]
    assert all(np.isfinite(out["train_loss"]))
    ps = out["pipeline_stats"]
    assert ps["steps"] == 20 and ps["worker_errors"] == 0
    assert ps["shard_steps"] == 20 * r.n_emb_shards
    assert ps["overlapped"] + ps["hazard_serialized"] + ps["drains"] > 0
    packed = out["emb_state"]
    assert np.isfinite(np.asarray(packed["table"])).all()


def test_threaded_pipelined_ps_fail_completes():
    """A PS dying mid-run bumps its incarnation token: staged lookups
    against the dead shard drain instead of landing stale planes, and the
    run completes with canonical packed output."""
    fault = FaultSpec(ps_fail_at={0: 2}, ps_recover_after_s=0.1)
    r = _runner(PipelineConfig(depth=2),
                cache=CacheConfig(hot_rows=2048, lookahead=2), fault=fault)
    out = r.run(10)
    kinds = [e.kind for e in out["shard_events"]]
    assert "ps_fail" in kinds and "ps_recover" in kinds
    assert out["pipeline_stats"]["worker_errors"] == 0
    assert all(np.isfinite(out["train_loss"]))
    assert np.isfinite(np.asarray(out["emb_state"]["table"])).all()


def test_threaded_incarnation_bumps_on_fail_and_recover():
    r = _runner(None)
    r.run(2)
    assert r.emb.incarnation(0) == 0
    r.emb.fail_shard(0, "test")
    assert r.emb.incarnation(0) == 1
    r.emb.recover_shard(0, "test")
    assert r.emb.incarnation(0) == 2

"""Unit + property tests for the ShadowSync algorithms (paper Algorithms 2-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sync as S

jax.config.update("jax_platform_name", "cpu")


def tree_close(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


def make_stack(key, R=4, shape=(5, 3)):
    return {"w": jax.random.normal(key, (R,) + shape),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (R, shape[0]))}


class TestEASGD:
    def test_pair_update_closed_form(self):
        w_ps = {"w": jnp.ones((3,))}
        w_i = {"w": jnp.zeros((3,))}
        new_ps, new_wi = S.easgd_pair_update(w_ps, w_i, alpha=0.5)
        # ps' = 0.5*1 + 0.5*0 = 0.5 ; wi' = 0.5*0 + 0.5*0.5 = 0.25
        np.testing.assert_allclose(new_ps["w"], 0.5)
        np.testing.assert_allclose(new_wi["w"], 0.25)

    def test_asymmetry(self):
        """After the exchange, PS and replica are NOT equal (paper §3.3)."""
        key = jax.random.PRNGKey(0)
        w_ps = {"w": jax.random.normal(key, (7,))}
        w_i = {"w": jax.random.normal(jax.random.fold_in(key, 1), (7,))}
        new_ps, new_wi = S.easgd_pair_update(w_ps, w_i, alpha=0.3)
        assert float(jnp.max(jnp.abs(new_ps["w"] - new_wi["w"]))) > 1e-3

    @settings(max_examples=30, deadline=None)
    @given(alpha=st.floats(0.05, 0.95), seed=st.integers(0, 2**30))
    def test_contraction(self, alpha, seed):
        """The elastic exchange contracts ||w_ps - w_i|| for any alpha in (0,1)."""
        key = jax.random.PRNGKey(seed)
        w_ps = {"w": jax.random.normal(key, (11,))}
        w_i = {"w": jax.random.normal(jax.random.fold_in(key, 1), (11,))}
        d0 = float(jnp.linalg.norm(w_ps["w"] - w_i["w"]))
        new_ps, new_wi = S.easgd_pair_update(w_ps, w_i, alpha)
        d1 = float(jnp.linalg.norm(new_ps["w"] - new_wi["w"]))
        assert d1 <= d0 + 1e-6

    def test_round_mask(self):
        """Replicas whose shadow clock did not fire are untouched."""
        key = jax.random.PRNGKey(1)
        stack = make_stack(key)
        w_ps = jax.tree.map(jnp.zeros_like, S.tree_slice(stack, 0))
        mask = jnp.asarray([True, False, True, False])
        new_stack, new_ps = S.easgd_round(stack, w_ps, 0.5, mask=mask)
        tree_close(S.tree_slice(new_stack, 1), S.tree_slice(stack, 1))
        tree_close(S.tree_slice(new_stack, 3), S.tree_slice(stack, 3))
        assert float(jnp.max(jnp.abs(new_stack["w"][0] - stack["w"][0]))) > 1e-6

    def test_round_sequential_semantics(self):
        """PS is updated between replicas (trainer 2 sees trainer 1's push)."""
        stack = {"w": jnp.asarray([[1.0], [2.0]])}
        w_ps = {"w": jnp.asarray([0.0])}
        new_stack, new_ps = S.easgd_round(stack, w_ps, 0.5)
        # step 1: ps=0.5, w0=0.75 ; step 2: ps=(0.5+2)/2=1.25, w1=(2+1.25)/2=1.625
        np.testing.assert_allclose(new_ps["w"], [1.25])
        np.testing.assert_allclose(new_stack["w"], [[0.75], [1.625]])

    def test_snapshot_semantics(self):
        """PS pulls toward the LAUNCH snapshot; pull-back lands on current."""
        stack = {"w": jnp.asarray([[4.0]])}
        snap = {"w": jnp.asarray([[2.0]])}
        w_ps = {"w": jnp.asarray([0.0])}
        new_stack, new_ps = S.easgd_round(stack, w_ps, 0.5, snapshot=snap)
        np.testing.assert_allclose(new_ps["w"], [1.0])  # toward snapshot 2.0
        np.testing.assert_allclose(new_stack["w"], [[2.5]])  # (4 + 1)/2


class TestMA:
    @settings(max_examples=20, deadline=None)
    @given(alpha=st.floats(0.05, 1.0), seed=st.integers(0, 2**30))
    def test_preserves_mean(self, alpha, seed):
        """Elastic pull toward the average never moves the average."""
        stack = make_stack(jax.random.PRNGKey(seed))
        new = S.ma_round(stack, alpha)
        tree_close(S.replica_mean(new), S.replica_mean(stack), atol=1e-5)

    def test_alpha_one_is_hard_average(self):
        stack = make_stack(jax.random.PRNGKey(2))
        new = S.ma_round(stack, alpha=1.0)
        mean = S.replica_mean(stack)
        for i in range(4):
            tree_close(S.tree_slice(new, i), mean, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(alpha=st.floats(0.05, 0.95), seed=st.integers(0, 2**30))
    def test_reduces_dispersion(self, alpha, seed):
        stack = make_stack(jax.random.PRNGKey(seed))
        new = S.ma_round(stack, alpha)

        def disp(s):
            m = S.replica_mean(s)
            return sum(float(jnp.sum((x - m_) ** 2)) for x, m_ in
                       zip(jax.tree.leaves(s), jax.tree.leaves(m)))

        assert disp(new) <= disp(stack) + 1e-6

    def test_snapshot_average(self):
        """Background MA averages the launch snapshot, not the current stack."""
        stack = {"w": jnp.asarray([[10.0], [20.0]])}
        snap = {"w": jnp.asarray([[0.0], [2.0]])}
        new = S.ma_round(stack, alpha=1.0, snapshot=snap)
        np.testing.assert_allclose(new["w"], [[1.0], [1.0]])


class TestBMUF:
    def test_state_init_and_step(self):
        stack = {"w": jnp.asarray([[2.0], [4.0]])}
        state = S.BMUFState.init({"w": jnp.asarray([0.0])})
        new_stack, new_state = S.bmuf_round(stack, state, alpha=1.0)
        # desc = mean(3.0) - 0 = 3; global = 3; replicas -> 3
        np.testing.assert_allclose(new_state.w_global["w"], [3.0])
        np.testing.assert_allclose(new_stack["w"], [[3.0], [3.0]])

    def test_paper_n_scaling(self):
        """Algorithm 4 line 9: w_global += n * w_desc."""
        stack = {"w": jnp.asarray([[2.0], [4.0]])}
        state = S.BMUFState.init({"w": jnp.asarray([0.0])})
        _, new_state = S.bmuf_round(stack, state, alpha=0.5, step_scale_n=True)
        np.testing.assert_allclose(new_state.w_global["w"], [6.0])  # 2 * 3

    def test_momentum_accumulates(self):
        stack = {"w": jnp.asarray([[1.0], [1.0]])}
        state = S.BMUFState.init({"w": jnp.asarray([0.0])})
        _, st1 = S.bmuf_round(stack, state, alpha=0.0, block_momentum=0.9)
        _, st2 = S.bmuf_round(stack, st1, alpha=0.0, block_momentum=0.9)
        v1 = float(st1.velocity["w"][0])
        v2 = float(st2.velocity["w"][0])
        assert v2 != pytest.approx(v1)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**30))
    def test_fixed_point(self, seed):
        """If all replicas equal w_global, BMUF is a no-op."""
        key = jax.random.PRNGKey(seed)
        w = {"w": jax.random.normal(key, (6,))}
        stack = {"w": jnp.broadcast_to(w["w"], (3, 6))}
        state = S.BMUFState(w_global=jax.tree.map(lambda x: x.astype(jnp.float32), w),
                            velocity={"w": jnp.zeros((6,), jnp.float32)})
        new_stack, new_state = S.bmuf_round(stack, state, alpha=0.7)
        tree_close(new_stack, stack, atol=1e-5)
        tree_close(new_state.w_global, state.w_global, atol=1e-5)


class TestLerp:
    @settings(max_examples=20, deadline=None)
    @given(alpha=st.floats(0.0, 1.0), seed=st.integers(0, 2**30))
    def test_lerp_bounds(self, alpha, seed):
        """lerp stays within the segment endpoints elementwise."""
        key = jax.random.PRNGKey(seed)
        a = jax.random.normal(key, (8,))
        b = jax.random.normal(jax.random.fold_in(key, 1), (8,))
        out = S.lerp(a, b, alpha)
        lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
        assert bool(jnp.all(out >= lo - 1e-6) and jnp.all(out <= hi + 1e-6))

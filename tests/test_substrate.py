"""Substrate tests: optimizers, data pipeline, embeddings, checkpoint."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro import optim
from repro.configs import dlrm_ctr
from repro.data import ctr, tokens
from repro.data.loader import PrefetchLoader
from repro.embeddings import table as emb


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

class TestOptim:
    @pytest.mark.parametrize("name,lr", [
        ("sgd", 0.1), ("momentum", 0.05), ("adagrad", 0.8),
        ("rmsprop", 0.05), ("adam", 0.1),
    ])
    def test_quadratic_convergence(self, name, lr):
        """min 0.5*||x - c||^2: every optimizer converges on a convex bowl."""
        c = jnp.asarray([1.0, -2.0, 3.0])
        opt = optim.make(name, lr)
        params = {"x": jnp.zeros(3)}
        state = opt.init(params)
        for _ in range(300):
            g = {"x": params["x"] - c}
            params, state = opt.update(params, state, g)
        np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(c), atol=0.05)

    def test_sgd_closed_form(self):
        opt = optim.sgd(0.5)
        p, _ = opt.update({"x": jnp.asarray([2.0])}, (), {"x": jnp.asarray([1.0])})
        np.testing.assert_allclose(p["x"], [1.5])

    def test_adagrad_scales_by_accumulator(self):
        opt = optim.adagrad(1.0)
        params = {"x": jnp.asarray([0.0])}
        st_ = opt.init(params)
        p1, st_ = opt.update(params, st_, {"x": jnp.asarray([2.0])})
        # first step: -lr * g / sqrt(g^2) = -1
        np.testing.assert_allclose(p1["x"], [-1.0], atol=1e-4)

    def test_momentum_nesterov_differs(self):
        g = {"x": jnp.asarray([1.0])}
        p0 = {"x": jnp.asarray([0.0])}
        o1, o2 = optim.momentum(0.1, 0.9), optim.momentum(0.1, 0.9, nesterov=True)
        p1, s1 = o1.update(p0, o1.init(p0), g)
        p1, _ = o1.update(p1, s1, g)
        p2, s2 = o2.update(p0, o2.init(p0), g)
        p2, _ = o2.update(p2, s2, g)
        assert float(p1["x"][0]) != pytest.approx(float(p2["x"][0]))

    def test_wsd_schedule_shape(self):
        lr = optim.wsd_schedule(1.0, warmup=10, stable=20, decay=10)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(lr(jnp.asarray(25))) == pytest.approx(1.0)
        assert float(lr(jnp.asarray(40))) == pytest.approx(0.1, abs=1e-5)


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

class TestCTRData:
    def test_deterministic_one_pass(self):
        cfg = dlrm_ctr.tiny()
        teacher = ctr.make_teacher(cfg, 0)
        b1 = ctr.gen_batch(cfg, teacher, seed=1, batch_idx=5, batch_size=32)
        b2 = ctr.gen_batch(cfg, teacher, seed=1, batch_idx=5, batch_size=32)
        b3 = ctr.gen_batch(cfg, teacher, seed=1, batch_idx=6, batch_size=32)
        np.testing.assert_array_equal(np.asarray(b1["sparse"]), np.asarray(b2["sparse"]))
        assert not np.array_equal(np.asarray(b1["sparse"]), np.asarray(b3["sparse"]))

    def test_indices_in_range(self):
        cfg = dlrm_ctr.tiny()
        teacher = ctr.make_teacher(cfg, 0)
        b = ctr.gen_batch(cfg, teacher, 0, 0, 256)
        idx = np.asarray(b["sparse"])
        sizes = np.asarray(cfg.table_sizes)
        assert (idx >= 0).all()
        assert (idx < sizes[None, :, None]).all()

    def test_labels_learnable_structure(self):
        """Click rate reflects the hidden teacher: base CTR well below 0.5 and
        the Bayes-optimal loss is below the base-rate entropy."""
        cfg = dlrm_ctr.tiny()
        teacher = ctr.make_teacher(cfg, 0)
        b = ctr.gen_batch(cfg, teacher, 0, 0, 8192)
        rate = float(np.mean(np.asarray(b["labels"])))
        assert 0.03 < rate < 0.45

    def test_normalized_entropy(self):
        assert ctr.normalized_entropy(0.3, 0.2) == pytest.approx(0.3 / 0.5004, rel=1e-3)


class TestTokenData:
    def test_markov_stream_deterministic(self):
        trans = tokens.make_transition(64, 0)
        b1 = tokens.gen_batch(trans, 0, 3, 4, 32)
        b2 = tokens.gen_batch(trans, 0, 3, 4, 32)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        assert b1["tokens"].shape == (4, 32)

    def test_prefetch_loader_order_and_bound(self):
        loader = PrefetchLoader(lambda i: i * i, n_batches=10, prefetch=2)
        assert list(loader) == [i * i for i in range(10)]


# ---------------------------------------------------------------------------
# Embedding tables (the paper's embedding-PS substrate)
# ---------------------------------------------------------------------------

class TestEmbeddings:
    def setup_method(self):
        self.cfg = dlrm_ctr.tiny()
        self.spec = emb.spec_from_config(self.cfg)

    def test_lookup_matches_manual(self):
        state = emb.init_tables(self.spec, jax.random.PRNGKey(0))
        idx = jnp.asarray([[[0, 1]] * self.cfg.n_sparse_features])  # (1, F, 2)
        out = emb.lookup(state, self.spec, idx)
        offs = self.spec.offsets
        for f in range(self.cfg.n_sparse_features):
            manual = state["table"][offs[f] + 0] + state["table"][offs[f] + 1]
            np.testing.assert_allclose(np.asarray(out[0, f]), np.asarray(manual), rtol=1e-6)

    def test_sparse_adagrad_only_touches_rows(self):
        state = emb.init_tables(self.spec, jax.random.PRNGKey(1))
        before = np.asarray(state["table"]).copy()
        idx = jnp.zeros((2, self.cfg.n_sparse_features, self.cfg.multi_hot), jnp.int32)
        g = jnp.ones((2, self.cfg.n_sparse_features, self.cfg.embedding_dim))
        new = emb.sparse_adagrad_update(state, self.spec, idx, g, lr=0.1)
        after = np.asarray(new["table"])
        touched = set(np.asarray(emb.global_row_ids(self.spec, idx)).reshape(-1).tolist())
        for r in range(before.shape[0]):
            if r in touched:
                assert not np.allclose(after[r], before[r])
            else:
                np.testing.assert_array_equal(after[r], before[r])

    def test_adagrad_accumulator_grows(self):
        state = emb.init_tables(self.spec, jax.random.PRNGKey(2))
        idx = jnp.zeros((1, self.cfg.n_sparse_features, self.cfg.multi_hot), jnp.int32)
        g = jnp.ones((1, self.cfg.n_sparse_features, self.cfg.embedding_dim))
        s1 = emb.sparse_adagrad_update(state, self.spec, idx, g, lr=0.1)
        s2 = emb.sparse_adagrad_update(s1, self.spec, idx, g, lr=0.1)
        assert float(jnp.sum(s2["acc"])) > float(jnp.sum(s1["acc"]))

    @settings(max_examples=25, deadline=None)
    @given(n_bins=st.integers(1, 8), seed=st.integers(0, 1000))
    def test_bin_pack_properties(self, n_bins, seed):
        """Every table lands in exactly one bin; LPT load <= 4/3 OPT + max."""
        rng = np.random.RandomState(seed)
        costs = rng.exponential(10.0, size=12)
        bins = emb.bin_pack(costs, n_bins)
        flat = sorted(i for b in bins for i in b)
        assert flat == list(range(12))
        loads = [sum(costs[i] for i in b) for b in bins]
        lower = max(costs.max(), costs.sum() / n_bins)
        assert max(loads) <= (4.0 / 3.0) * lower + 1e-9

    def test_lookup_costs_monotone_in_batch(self):
        c1 = emb.lookup_costs(self.spec, 100)
        c2 = emb.lookup_costs(self.spec, 200)
        assert (c2 > c1).all()


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "w": {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7),
        }
        ckpt.save(str(tmp_path / "c"), tree, metadata={"algo": "easgd"})
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        restored, meta = ckpt.restore(str(tmp_path / "c"), like)
        assert meta["algo"] == "easgd"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_resume_mid_stream(self, tmp_path):
        """Save/restore the full HogwildSim state and continue the one-pass stream."""
        from repro.core.runners import HogwildSim
        from repro.core.sync import SyncConfig

        cfg = dlrm_ctr.tiny()
        sim = HogwildSim(cfg, SyncConfig(algo="ma"), n_trainers=2, n_threads=1,
                         batch_size=32, optimizer=optim.adagrad(0.02))
        out = sim.run(10)
        st = out["state"]
        ckpt.save(str(tmp_path / "c"), {"w": st.w_stack, "emb": st.emb_state},
                  metadata={"step": st.step})
        like = {"w": jax.tree.map(jnp.zeros_like, st.w_stack),
                "emb": jax.tree.map(jnp.zeros_like, st.emb_state)}
        restored, meta = ckpt.restore(str(tmp_path / "c"), like)
        assert meta["step"] == 10
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(restored["w"])[0]),
            np.asarray(jax.tree.leaves(st.w_stack)[0]))

"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sync import easgd_pair_update
from repro.kernels.easgd_update.ops import easgd_pair_op
from repro.kernels.easgd_update.ref import easgd_update_ref
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import gqa_attention_op
from repro.kernels.flash_attention.ref import attention_ref


class TestEmbeddingBag:
    @pytest.mark.parametrize("rows,d,n_bags,m", [
        (64, 128, 8, 1), (100, 16, 32, 4), (512, 48, 17, 3), (1000, 256, 5, 8),
    ])
    def test_shapes(self, rows, d, n_bags, m):
        key = jax.random.PRNGKey(rows + d)
        table = jax.random.normal(key, (rows, d))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (n_bags, m), 0, rows)
        out = embedding_bag_op(table, idx)
        ref = embedding_bag_ref(table, idx)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(7)
        table = jax.random.normal(key, (128, 128)).astype(dtype)
        idx = jax.random.randint(jax.random.fold_in(key, 1), (16, 4), 0, 128)
        out = embedding_bag_op(table, idx)
        ref = embedding_bag_ref(table, idx)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2)

    def test_batched_bag_dims(self):
        """(B, F, m) bags, as DLRM uses them."""
        key = jax.random.PRNGKey(9)
        table = jax.random.normal(key, (200, 32))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (4, 6, 2), 0, 200)
        out = embedding_bag_op(table, idx)
        assert out.shape == (4, 6, 32)
        ref = embedding_bag_ref(table, idx.reshape(-1, 2)).reshape(4, 6, 32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)

    def test_duplicate_rows_pool(self):
        table = jnp.eye(8, 128)
        idx = jnp.asarray([[0, 0, 3]])
        out = embedding_bag_op(table, idx)
        assert float(out[0, 0]) == 2.0 and float(out[0, 3]) == 1.0


class TestEASGDKernel:
    @pytest.mark.parametrize("shape", [(130_000,), (257, 33), (64, 64, 3)])
    @pytest.mark.parametrize("alpha", [0.1, 0.5, 0.9])
    def test_vs_core_math(self, shape, alpha):
        key = jax.random.PRNGKey(sum(shape))
        tree = {"a": jax.random.normal(key, shape), "b": jnp.ones((5,))}
        tree2 = jax.tree.map(lambda x: x * 2 + 1, tree)
        ps1, wi1 = easgd_pair_op(tree, tree2, alpha)
        ps2, wi2 = easgd_pair_update(tree, tree2, alpha)
        for a, b in zip(jax.tree.leaves((ps1, wi1)), jax.tree.leaves((ps2, wi2))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    def test_flat_kernel_vs_ref(self):
        key = jax.random.PRNGKey(3)
        a = jax.random.normal(key, (2048, 128))
        b = jax.random.normal(jax.random.fold_in(key, 1), (2048, 128))
        from repro.kernels.easgd_update.easgd_update import easgd_update

        k_ps, k_wi = easgd_update(a, b, 0.3, block=512, interpret=True)
        r_ps, r_wi = easgd_update_ref(a, b, 0.3)
        np.testing.assert_allclose(np.asarray(k_ps), np.asarray(r_ps), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(k_wi), np.asarray(r_wi), rtol=1e-5, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("s,h,kv,d", [
        (128, 4, 4, 64), (256, 4, 2, 64), (256, 8, 1, 128), (384, 2, 2, 32),
    ])
    def test_causal_gqa(self, s, h, kv, d):
        key = jax.random.PRNGKey(s + h)
        q = jax.random.normal(key, (2, s, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, kv, d), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, kv, d), jnp.float32)
        out = gqa_attention_op(q, k, v, causal=True)
        rep = h // kv
        kr, vr = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
        ref = attention_ref(
            q.transpose(0, 2, 1, 3).reshape(2 * h, s, d),
            kr.transpose(0, 2, 1, 3).reshape(2 * h, s, d),
            vr.transpose(0, 2, 1, 3).reshape(2 * h, s, d),
        ).reshape(2, h, s, d).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    def test_noncausal(self):
        key = jax.random.PRNGKey(11)
        q = jax.random.normal(key, (1, 128, 2, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64))
        out = gqa_attention_op(q, k, v, causal=False)
        ref = attention_ref(q.transpose(0, 2, 1, 3).reshape(2, 128, 64),
                            k.transpose(0, 2, 1, 3).reshape(2, 128, 64),
                            v.transpose(0, 2, 1, 3).reshape(2, 128, 64),
                            causal=False).reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    def test_unaligned_seq_padding(self):
        """S not a multiple of the block: wrapper pads and slices."""
        key = jax.random.PRNGKey(13)
        q = jax.random.normal(key, (1, 100, 2, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 100, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 100, 2, 64))
        out = gqa_attention_op(q, k, v, causal=True)
        ref = gqa_attention_op(q, k, v, causal=True, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
    def test_dtypes(self, dtype, tol):
        key = jax.random.PRNGKey(17)
        q = jax.random.normal(key, (1, 128, 2, 64)).astype(dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64)).astype(dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64)).astype(dtype)
        out = gqa_attention_op(q, k, v, causal=True)
        ref = gqa_attention_op(q, k, v, causal=True, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), rtol=tol, atol=tol)


class TestInteractionKernel:
    @pytest.mark.parametrize("b,f,d", [(64, 9, 16), (128, 27, 64), (100, 5, 32)])
    def test_vs_ref(self, b, f, d):
        from repro.kernels.interaction.ops import interaction_op
        from repro.kernels.interaction.ref import interaction_ref

        key = jax.random.PRNGKey(b + f)
        z = jax.random.normal(key, (b, f, d))
        out = interaction_op(z)
        ref = interaction_ref(z)
        assert out.shape == (b, f * (f - 1) // 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    def test_matches_dlrm_interact(self):
        """The kernel computes exactly the dot features of models.dlrm.interact."""
        from repro.kernels.interaction.ops import interaction_op
        from repro.models.dlrm import interact

        key = jax.random.PRNGKey(3)
        bottom = jax.random.normal(key, (16, 8))
        pooled = jax.random.normal(jax.random.fold_in(key, 1), (16, 4, 8))
        full = interact(bottom, pooled)  # (B, d + n_pairs)
        z = jnp.concatenate([bottom[:, None, :], pooled], axis=1)
        dots = interaction_op(z)
        np.testing.assert_allclose(np.asarray(full[:, 8:]), np.asarray(dots),
                                   rtol=1e-5, atol=1e-5)

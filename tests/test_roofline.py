"""Roofline analysis unit tests: HLO collective parsing + term math + the
calibrated EPS throughput model's paper-claim checks."""
import pytest

from benchmarks.eps_model import ClusterModel
from repro.roofline import analysis as RA
from repro.roofline.params import active_param_count, param_count

HLO_SAMPLE = """
ENTRY %main {
  %ag = f32[1024,256]{1,0} all-gather(%x), channel_id=1, replica_groups={{0,1},{2,3}}
  %ar = bf16[64,64]{1,0} all-reduce(%y), channel_id=2, to_apply=%sum
  %aa = (f32[8,16], f32[8,16]) all-to-all(%a, %b), channel_id=3
  %cp = f32[32]{0} collective-permute(%z), channel_id=4
  %dot = f32[10,10]{1,0} dot(%p, %q)
}
"""


class TestCollectiveParse:
    def test_bytes_per_kind(self):
        c = RA.collective_bytes(HLO_SAMPLE)
        assert c["all-gather"] == 1024 * 256 * 4
        assert c["all-reduce"] == 64 * 64 * 2 * 2  # bf16, x2 for RS+AG phases
        assert c["all-to-all"] == 2 * 8 * 16 * 4  # tuple result
        assert c["collective-permute"] == 32 * 4
        assert c["reduce-scatter"] == 0

    def test_non_collectives_ignored(self):
        c = RA.collective_bytes("%d = f32[64,64] dot(%a, %b)\n")
        assert sum(c.values()) == 0


class TestRooflineTerms:
    def _r(self, **kw):
        base = dict(arch="a", shape="s", mesh="m", mode="syncdp", chips=256,
                    flops_per_chip=197e12, bytes_per_chip=819e9,
                    collective_bytes_per_chip=50e9, collectives={},
                    arg_bytes=0, temp_bytes=0, out_bytes=0, model_flops=0.0)
        base.update(kw)
        return RA.Roofline(**base)

    def test_unit_terms(self):
        r = self._r()
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.t_collective == pytest.approx(1.0)

    def test_bottleneck_attribution(self):
        assert self._r(collective_bytes_per_chip=500e9).bottleneck == "collective"
        assert self._r(bytes_per_chip=9e12).bottleneck == "memory"
        assert self._r(flops_per_chip=1e15, bytes_per_chip=1e9,
                       collective_bytes_per_chip=1e9).bottleneck == "compute"

    def test_useful_ratio(self):
        r = self._r(model_flops=197e12 * 256 * 0.75)
        assert r.useful_flops_ratio == pytest.approx(0.75)


class TestModelFlops:
    def test_dense_6nd(self):
        from repro.configs.base import INPUT_SHAPES, get_config

        cfg = get_config("granite-20b")
        mf = RA.model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
        n = param_count(cfg)
        assert mf == pytest.approx(6.0 * n * 256 * 4096)

    def test_moe_active_params(self):
        from repro.configs.base import get_config

        cfg = get_config("kimi-k2-1t-a32b")
        total, active = param_count(cfg), active_param_count(cfg)
        assert 0.8e12 < total < 1.3e12, total / 1e12  # ~1T
        assert 20e9 < active < 60e9, active / 1e9  # ~32B active

    def test_decode_counts_one_token(self):
        from repro.configs.base import INPUT_SHAPES, get_config

        cfg = get_config("granite-20b")
        mf = RA.model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
        assert mf == pytest.approx(2.0 * param_count(cfg) * 128)


class TestEPSModel:
    """The Fig-5 fluid model must reproduce every paper-reported behaviour."""

    def setup_method(self):
        self.m = ClusterModel()

    def test_fr5_2ps_plateaus_near_14(self):
        eps = [self.m.fr_eps(n, 5, 2) for n in range(5, 21)]
        # growth stops: EPS at 20 trainers barely above EPS at 14
        assert eps[-1] < eps[14 - 5] * 1.10

    def test_fr30_linear(self):
        assert self.m.fr_eps(20, 30, 2) > 0.95 * self.m.shadow_eps(20)

    def test_four_ps_fixes_plateau(self):
        assert self.m.fr_eps(20, 5, 4) > 0.95 * self.m.shadow_eps(20)

    def test_shadow_always_linear(self):
        for n in (5, 10, 20, 40):
            assert self.m.shadow_eps(n) == pytest.approx(n * self.m.eps_0)

    def test_shadow_gap_grows_with_n(self):
        gaps = [self.m.shadow_avg_sync_gap(n, 2) for n in range(15, 21)]
        assert all(b > a for a, b in zip(gaps, gaps[1:]))
        assert 3 < gaps[0] < 30  # same order as paper's 8.60..12.48

    def test_hogwild_saturates(self):
        e12, e24, e64 = (self.m.hogwild_eps(t) for t in (12, 24, 64))
        assert e24 / e12 < 1.9
        assert e64 / e24 < 1.25

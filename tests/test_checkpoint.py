"""checkpoint/: full-SimState round trips (flat buffer, per-shard embedding
states, opaque algo_state incl. BMUFState, bf16 leaves, metadata), the
ValueError contract for missing/mismatched leaves, elastic restore
semantics, and the crash-safety layer (generation dirs, atomic publish,
CRC verification, fallback to the newest intact generation)."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.configs import dlrm_ctr
from repro.core import sync as S
from repro.core.runners import HogwildSim
from repro.core.sync import SyncConfig

jax.config.update("jax_platform_name", "cpu")

CFG = dlrm_ctr.tiny()


def _tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(
            x.astype(np.float32) if x.dtype == jnp.bfloat16 else x,
            y.astype(np.float32) if y.dtype == jnp.bfloat16 else y)


# ---------------------------------------------------------------------------
# Generic pytree round trips
# ---------------------------------------------------------------------------

class TestRoundTrip:
    def test_mixed_tree_with_bf16_and_bmuf_state(self, tmp_path):
        key = jax.random.PRNGKey(0)
        tree = {
            "dense": jax.random.normal(key, (5, 7)).astype(jnp.bfloat16),
            "opt": [{"acc": jnp.ones((3,), jnp.float32)},
                    {"acc": jnp.zeros((2, 2), jnp.float32)}],
            "bmuf": S.BMUFState(
                w_global={"w": jnp.arange(6, dtype=jnp.float32)},
                velocity={"w": jnp.full((6,), 0.25, jnp.float32)}),
            "counter": jnp.int32(11),
        }
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, tree, metadata={"step": 3, "note": "hi"})
        out, meta = ckpt.restore(path, tree)
        _tree_equal(out, tree)
        assert meta == {"step": 3, "note": "hi"}

    @pytest.mark.parametrize("algo", ["easgd", "ma", "bmuf", "gossip"])
    @pytest.mark.parametrize("engine", ["flat", "pytree"])
    def test_full_sim_state_round_trip(self, tmp_path, algo, engine):
        """The whole SimState — flat replica buffer (or pytree stack),
        per-trainer optimizer stacks, embedding table+acc, and the opaque
        algo_state (PS plane / BMUFState / round counter / None)."""
        sim = HogwildSim(
            CFG, SyncConfig(algo=algo, gap=3, alpha=0.5, engine=engine),
            n_trainers=3, n_threads=2, batch_size=32,
            optimizer=optim.adagrad(0.02), seed=0)
        out = sim.run(5)
        st = out["state"]
        path = os.path.join(tmp_path, "ck")
        sim.save_state(path, st)
        st2 = sim.load_state(path)
        _tree_equal(sim.dense_stack(st2), sim.dense_stack(st))
        _tree_equal(st2.opt_stack, st.opt_stack)
        _tree_equal(st2.emb_state, st.emb_state)
        _tree_equal(st2.algo_state, st.algo_state)
        assert st2.step == st.step
        # and training continues bit-compatibly from the restored state
        out_a = sim.run(3, state=st)
        out_b = sim.run(3, state=st2)
        np.testing.assert_allclose(out_a["train_loss"], out_b["train_loss"],
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# Error contract (satellite: no bare asserts / KeyErrors)
# ---------------------------------------------------------------------------

class TestErrors:
    def _save_simple(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, {"a": jnp.ones((4, 2)), "b": jnp.zeros((3,))})
        return path

    def test_missing_leaf_is_value_error_naming_key(self, tmp_path):
        path = self._save_simple(tmp_path)
        with pytest.raises(ValueError, match=r"no leaf 'c'"):
            ckpt.restore(path, {"a": jnp.ones((4, 2)), "b": jnp.zeros((3,)),
                                "c": jnp.zeros((1,))})

    def test_shape_mismatch_names_key_and_both_shapes(self, tmp_path):
        path = self._save_simple(tmp_path)
        with pytest.raises(ValueError) as ei:
            ckpt.restore(path, {"a": jnp.ones((5, 2)), "b": jnp.zeros((3,))})
        msg = str(ei.value)
        assert "'a'" in msg and "(4, 2)" in msg and "(5, 2)" in msg

    def test_elastic_rejects_non_leading_mismatch(self, tmp_path):
        path = self._save_simple(tmp_path)
        with pytest.raises(ValueError, match="only the leading"):
            ckpt.restore_elastic(path, {"a": jnp.ones((4, 3)),
                                        "b": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# Elastic resize semantics
# ---------------------------------------------------------------------------

class TestElasticRestore:
    def test_grow_fills_with_replica_mean(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        w = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
        ckpt.save(path, {"w": w})
        out, _, resized = ckpt.restore_elastic(path, {"w": jnp.zeros((4, 2))})
        np.testing.assert_allclose(np.asarray(out["w"][:2]), np.asarray(w))
        np.testing.assert_allclose(np.asarray(out["w"][2]), [2.0, 3.0])
        np.testing.assert_allclose(np.asarray(out["w"][3]), [2.0, 3.0])
        assert resized == {"w": ((2, 2), (4, 2))}

    def test_shrink_truncates(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, {"w": jnp.arange(12, dtype=jnp.float32).reshape(4, 3)})
        out, _, resized = ckpt.restore_elastic(path, {"w": jnp.zeros((2, 3))})
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   [[0, 1, 2], [3, 4, 5]])
        assert "w" in resized

    def test_bf16_leaf_grows_losslessly(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        w = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.bfloat16)
        ckpt.save(path, {"w": w})
        out, _, _ = ckpt.restore_elastic(
            path, {"w": jnp.zeros((3, 2), jnp.bfloat16)})
        assert out["w"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out["w"][:2], np.float32),
                                   np.asarray(w, np.float32))
        np.testing.assert_allclose(np.asarray(out["w"][2], np.float32),
                                   [2.0, 3.0])

    def test_exact_shapes_pass_through_unresized(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        t = {"w": jnp.ones((3, 2)), "s": jnp.float32(1.5)}
        ckpt.save(path, t)
        out, _, resized = ckpt.restore_elastic(path, t)
        _tree_equal(out, t)
        assert resized == {}

    def test_may_resize_guards_non_replica_leaves(self, tmp_path):
        """A leading-axis mismatch on a leaf the caller did NOT mark as
        replica-stacked (e.g. an embedding table whose row count changed
        between configs) must raise, not silently mean-fill."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, {"w": jnp.ones((2, 5)), "emb": jnp.ones((4, 3))})
        like = {"w": jnp.zeros((3, 5)), "emb": jnp.zeros((6, 3))}
        with pytest.raises(ValueError, match="'emb'"):
            ckpt.restore_elastic(path, like,
                                 may_resize=lambda k: k.startswith("w"))
        # with the guard satisfied, only "w" resizes
        out, _, resized = ckpt.restore_elastic(
            path, {"w": jnp.zeros((3, 5)), "emb": jnp.ones((4, 3))},
            may_resize=lambda k: k.startswith("w"))
        assert set(resized) == {"w"}


# ---------------------------------------------------------------------------
# Crash safety: generations, atomic publish, CRC fallback (DESIGN.md §10.4)
# ---------------------------------------------------------------------------

class TestCrashSafety:
    def _tree(self, salt=0.0):
        return {"a": jnp.full((4, 2), 1.0 + salt),
                "b": jnp.arange(3, dtype=jnp.float32) + salt}

    def test_each_save_is_a_new_generation_pruned_to_keep(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        for i in range(4):
            ckpt.save(path, self._tree(float(i)), metadata={"i": i},
                      keep=2)
        gens = ckpt.generations(path)
        assert len(gens) == 2  # pruned to keep
        assert [os.path.basename(g) for g in gens] == \
            ["gen-000003", "gen-000002"]  # numbering survives pruning
        out, meta = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(3.0))  # newest wins
        assert meta == {"i": 3}

    def test_tmp_debris_from_a_crashed_save_is_invisible(self, tmp_path):
        """A save that died before its os.replace leaves only a .tmp-* dir:
        readers ignore it, and the next save reclaims the slot."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, self._tree(1.0))
        debris = os.path.join(path, ".tmp-gen-000001")
        os.makedirs(debris)
        with open(os.path.join(debris, "manifest.json"), "w") as f:
            f.write("{ torn mid-write")
        out, _ = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(1.0))
        ckpt.save(path, self._tree(2.0))  # reclaims .tmp-gen-000001
        assert not os.path.exists(debris)
        out, _ = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(2.0))

    def test_crc_mismatch_falls_back_naming_the_leaf(self, tmp_path):
        """Bit-rot in the newest generation: restore must warn (naming the
        corrupt leaf), fall back to the older intact generation, and return
        ITS data."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, self._tree(1.0), metadata={"i": 1})
        ckpt.save(path, self._tree(2.0), metadata={"i": 2})
        newest = ckpt.generations(path)[0]
        mf = os.path.join(newest, "manifest.json")
        with open(mf) as f:
            manifest = json.load(f)
        manifest["crc32"]["a"] ^= 0xFFFF  # the stored bytes no longer match
        with open(mf, "w") as f:
            json.dump(manifest, f)
        with pytest.warns(RuntimeWarning, match=r"'a'.*falling back"):
            out, meta = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(1.0))
        assert meta == {"i": 1}

    def test_truncated_archive_falls_back(self, tmp_path):
        """A torn write (arrays.npz cut mid-stream) is corruption, not a
        crash: fallback to the previous generation."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, self._tree(1.0))
        ckpt.save(path, self._tree(2.0))
        npz = os.path.join(ckpt.generations(path)[0], "arrays.npz")
        with open(npz, "r+b") as f:
            f.truncate(os.path.getsize(npz) // 2)
        with pytest.warns(RuntimeWarning, match="falling back"):
            out, _ = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(1.0))

    def test_every_generation_corrupt_raises_with_provenance(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, self._tree(1.0), keep=2)
        ckpt.save(path, self._tree(2.0), keep=2)
        for g in ckpt.generations(path):
            with open(os.path.join(g, "manifest.json"), "w") as f:
                f.write("not json")
        with pytest.raises(ckpt.CheckpointCorruptError,
                           match="every generation"):
            with pytest.warns(RuntimeWarning):
                ckpt.restore(path, self._tree())

    def test_shape_mismatch_never_triggers_fallback(self, tmp_path):
        """Only CORRUPTION may fall back: a template/shape disagreement with
        an intact newest generation is a caller bug and must raise even
        though an older generation with the requested shape exists —
        anything else silently resurrects stale weights."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, {"w": jnp.ones((2, 3))})  # old shape
        ckpt.save(path, {"w": jnp.ones((5, 3))})  # current shape
        with pytest.raises(ValueError, match="shape mismatch") as ei:
            ckpt.restore(path, {"w": jnp.zeros((2, 3))})
        assert not isinstance(ei.value, ckpt.CheckpointCorruptError)

    def test_legacy_flat_layout_still_restores(self, tmp_path):
        """Pre-generational checkpoints (manifest.json directly under the
        path) remain readable — as the final fallback candidate."""
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, self._tree(7.0), metadata={"legacy": True})
        gen = ckpt.generations(path)[0]
        for name in os.listdir(gen):
            shutil.move(os.path.join(gen, name), os.path.join(path, name))
        os.rmdir(gen)
        assert ckpt.generations(path) == []
        out, meta = ckpt.restore(path, self._tree())
        _tree_equal(out, self._tree(7.0))
        assert meta == {"legacy": True}
        assert ckpt.read_metadata(path) == {"legacy": True}

    def test_read_metadata_and_elastic_share_the_fallback(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, {"w": jnp.ones((2, 3))}, metadata={"i": 1})
        ckpt.save(path, {"w": jnp.full((2, 3), 2.0)}, metadata={"i": 2})
        npz = os.path.join(ckpt.generations(path)[0], "arrays.npz")
        with open(npz, "wb") as f:
            # zip magic + garbage: np.load routes to zipfile -> BadZipFile
            f.write(b"PK\x03\x04" + b"\x00" * 12)
        # metadata comes from the intact manifest of the newest gen (only
        # the arrays are gone), so only array-loading paths fall back
        with pytest.warns(RuntimeWarning, match="falling back"):
            out, _, _ = ckpt.restore_elastic(path, {"w": jnp.zeros((4, 3))})
        np.testing.assert_allclose(np.asarray(out["w"][:2]),
                                   np.ones((2, 3)))

    def test_missing_checkpoint_is_file_not_found(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no checkpoint"):
            ckpt.restore(os.path.join(tmp_path, "nope"), self._tree())

    def test_keep_must_be_positive(self, tmp_path):
        with pytest.raises(ValueError, match="keep"):
            ckpt.save(os.path.join(tmp_path, "ck"), self._tree(), keep=0)


class TestResume:
    def test_resume_continues_the_batch_stream(self, tmp_path):
        """A restored run must NOT replay batches from t=0: a straight
        2N-iteration run and an N + save/load + N run land identical
        trajectories, and the step counter keeps advancing."""
        def mk():
            return HogwildSim(
                CFG, SyncConfig(algo="ma", mode="fixed_rate", gap=2,
                                alpha=0.5, engine="flat"),
                n_trainers=3, n_threads=2, batch_size=32,
                optimizer=optim.adagrad(0.02), seed=0)

        full = mk().run(6)
        sim_a = mk()
        out_a = sim_a.run(3)
        path = os.path.join(tmp_path, "ck")
        sim_a.save_state(path, out_a["state"])
        sim_b = mk()
        st = sim_b.load_state(path)
        out_b = sim_b.run(3, state=st)
        assert out_b["state"].step == 6
        np.testing.assert_allclose(
            out_a["train_loss"] + out_b["train_loss"], full["train_loss"],
            rtol=1e-6)

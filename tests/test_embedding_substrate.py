"""Sparse embedding substrate: fused kernels, shard plan, runner wiring.

Covers DESIGN.md §7: kernel-vs-pytree-oracle parity for the fused
sparse-Adagrad backward (duplicate-row accumulate semantics, both grid
strategies), the bag-blocked lookup kernel, `EmbeddingShards` routing
invariants (every global row on exactly one shard; plan == bin_pack output),
the runners' fused/sharded defaults, the `delay=0` same-iteration landing
regression, and `SyncConfig.validate` input hardening."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig
from repro.embeddings import shards
from repro.embeddings import table as emb
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op
from repro.kernels.sparse_adagrad.ref import sparse_adagrad_ref

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)


CFG = dlrm_ctr.tiny()
SPEC = emb.spec_from_config(CFG)


# ---------------------------------------------------------------------------
# Fused sparse-Adagrad kernel vs oracle
# ---------------------------------------------------------------------------

class TestSparseAdagradKernel:
    @pytest.mark.parametrize("strategy", ["rows", "block"])
    @pytest.mark.parametrize("n_rows,d,n_bags,m", [
        (100, 16, 32, 4), (57, 48, 7, 3), (513, 128, 19, 1),
    ])
    def test_parity_random(self, strategy, n_rows, d, n_bags, m):
        key = jax.random.PRNGKey(n_rows + d)
        table = jax.random.normal(key, (n_rows, d))
        acc = jax.random.uniform(jax.random.fold_in(key, 1), (n_rows, d))
        idx = jax.random.randint(jax.random.fold_in(key, 2), (n_bags, m), 0, n_rows)
        g = jax.random.normal(jax.random.fold_in(key, 3), (n_bags, d))
        t2, a2 = sparse_adagrad_op(table, acc, idx, g, lr=0.05, strategy=strategy)
        rt, ra = sparse_adagrad_ref(table, acc, idx, g, 0.05)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(rt), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(ra), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("strategy", ["rows", "block"])
    def test_duplicate_rows_accumulate(self, strategy):
        """Duplicates in a batch scatter-ADD (Hogwild accumulate), and the row
        step is scaled by the FINAL accumulator — tiny row range forces heavy
        collision."""
        key = jax.random.PRNGKey(7)
        table = jax.random.normal(key, (5, 16))
        acc = jnp.zeros((5, 16))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (64, 4), 0, 5)
        g = jax.random.normal(jax.random.fold_in(key, 2), (64, 16))
        t2, a2 = sparse_adagrad_op(table, acc, idx, g, lr=0.1, strategy=strategy)
        rt, ra = sparse_adagrad_ref(table, acc, idx, g, 0.1)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(ra), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(rt), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("strategy", ["rows", "block"])
    def test_all_indices_identical_worst_case(self, strategy):
        """Every occurrence hits ONE row: the longest possible duplicate run
        (rows strategy) / maximal in-block collision (blocked strategy)."""
        key = jax.random.PRNGKey(11)
        table = jax.random.normal(key, (9, 32))
        acc = jnp.ones((9, 32)) * 0.5
        idx = jnp.full((16, 4), 3, jnp.int32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (16, 32))
        t2, a2 = sparse_adagrad_op(table, acc, idx, g, lr=0.2, strategy=strategy)
        rt, ra = sparse_adagrad_ref(table, acc, idx, g, 0.2)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(ra), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(rt), rtol=1e-4, atol=1e-4)
        # untouched rows bit-identical (aliased in/out, never streamed)
        touched = {3}
        for r in range(9):
            if r not in touched:
                np.testing.assert_array_equal(np.asarray(t2[r]), np.asarray(table[r]))
                np.testing.assert_array_equal(np.asarray(a2[r]), np.asarray(acc[r]))

    def test_fused_update_vs_pytree_oracle(self):
        """The table-level entry point: fused kernel vs emb.sparse_adagrad_update
        on a real (B, F, m) batch, duplicates included."""
        key = jax.random.PRNGKey(3)
        state = emb.init_tables(SPEC, key)
        idx = jax.random.randint(
            jax.random.fold_in(key, 1), (8, CFG.n_sparse_features, CFG.multi_hot),
            0, 1 << 30) % jnp.asarray(SPEC.sizes)[None, :, None]
        g = jax.random.normal(
            jax.random.fold_in(key, 2), (8, CFG.n_sparse_features, CFG.embedding_dim))
        fused = emb.sparse_adagrad_update_fused(state, SPEC, idx, g, 0.05)
        oracle = emb.sparse_adagrad_update(state, SPEC, idx, g, 0.05)
        for k in oracle:
            np.testing.assert_allclose(
                np.asarray(fused[k]), np.asarray(oracle[k]), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("strategy", ["rows", "block"])
    def test_bf16_table(self, strategy):
        key = jax.random.PRNGKey(5)
        table = jax.random.normal(key, (32, 16)).astype(jnp.bfloat16)
        acc = jnp.zeros((32, 16))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (8, 2), 0, 32)
        g = jax.random.normal(jax.random.fold_in(key, 2), (8, 16))
        t2, a2 = sparse_adagrad_op(table, acc, idx, g, lr=0.1, strategy=strategy)
        rt, ra = sparse_adagrad_ref(table, acc, idx, g, 0.1)
        assert t2.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(t2, np.float32),
                                   np.asarray(rt, np.float32), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(a2), np.asarray(ra), rtol=1e-4, atol=1e-4)


class TestEmbeddingBagBlocked:
    """The bag-blocked grid strategy (the off-TPU interpret path)."""

    @pytest.mark.parametrize("rows,d,n_bags,m", [
        (64, 128, 8, 1), (100, 16, 37, 4), (512, 48, 1025, 3),
    ])
    def test_parity_both_strategies(self, rows, d, n_bags, m):
        key = jax.random.PRNGKey(rows + n_bags)
        table = jax.random.normal(key, (rows, d))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (n_bags, m), 0, rows)
        ref = embedding_bag_ref(table, idx)
        for strategy in ("stream", "block"):
            out = embedding_bag_op(table, idx, strategy=strategy)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5, err_msg=strategy)

    def test_lookup_dispatch_matches_ref(self):
        state = emb.init_tables(SPEC, jax.random.PRNGKey(0))
        idx = jax.random.randint(
            jax.random.PRNGKey(1), (6, CFG.n_sparse_features, CFG.multi_hot),
            0, 1 << 30) % jnp.asarray(SPEC.sizes)[None, :, None]
        np.testing.assert_allclose(
            np.asarray(emb.lookup(state, SPEC, idx)),
            np.asarray(emb.lookup_ref(state, SPEC, idx)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Shard plan + EmbeddingShards routing invariants
# ---------------------------------------------------------------------------

class TestEmbeddingShards:
    @pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
    def test_plan_matches_bin_pack(self, n_shards):
        plan = shards.plan_shards(SPEC, n_shards, 64)
        expect = emb.bin_pack(emb.lookup_costs(SPEC, 64), min(n_shards, len(SPEC.sizes)))
        assert [list(b) for b in plan.bins] == expect

    @pytest.mark.parametrize("n_shards", [1, 3, 4, 8])
    def test_every_row_on_exactly_one_shard(self, n_shards):
        """The shard layouts partition the global packed row space."""
        plan = shards.plan_shards(SPEC, n_shards, 64)
        seen = {}
        goff = SPEC.offsets
        for f in range(len(SPEC.sizes)):
            s, loff = plan.feature_shard[f], plan.feature_local_offset[f]
            assert f in plan.bins[s]
            for r in range(SPEC.sizes[f]):
                key = (s, loff + r)
                assert key not in seen, f"shard row claimed twice: {key}"
                seen[key] = int(goff[f]) + r
        assert sorted(seen.values()) == list(range(SPEC.total_rows))
        assert sum(plan.shard_rows) == SPEC.total_rows

    def test_split_roundtrip_and_seed_parity(self):
        state = emb.init_tables(SPEC, jax.random.PRNGKey(0))
        plan = shards.plan_shards(SPEC, 4, 64)
        es = shards.EmbeddingShards.init(plan, jax.random.PRNGKey(0))
        packed = es.to_packed()
        for k in state:
            np.testing.assert_array_equal(np.asarray(packed[k]), np.asarray(state[k]))

    def test_sharded_cycle_matches_single_table(self):
        """Plan-routed lookup + per-shard fused backward == the packed
        single-table oracle."""
        key = jax.random.PRNGKey(9)
        state = emb.init_tables(SPEC, key)
        plan = shards.plan_shards(SPEC, 3, 16)
        es = shards.EmbeddingShards(plan, shards.shard_states(plan, state))
        idx = jax.random.randint(
            jax.random.fold_in(key, 1), (16, CFG.n_sparse_features, CFG.multi_hot),
            0, 1 << 30) % jnp.asarray(SPEC.sizes)[None, :, None]
        g = jax.random.normal(
            jax.random.fold_in(key, 2), (16, CFG.n_sparse_features, CFG.embedding_dim))
        np.testing.assert_allclose(
            np.asarray(shards.shard_lookup(plan, es.tables(), idx)),
            np.asarray(emb.lookup_ref(state, SPEC, idx)), rtol=1e-5, atol=1e-5)
        for s in range(plan.n_shards):
            es.states[s] = shards.shard_update(plan, s, es.states[s], idx, g, 0.05)
        oracle = emb.sparse_adagrad_update(state, SPEC, idx, g, 0.05)
        packed = es.to_packed()
        for k in oracle:
            np.testing.assert_allclose(np.asarray(packed[k]), np.asarray(oracle[k]),
                                       rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Runner wiring
# ---------------------------------------------------------------------------

class TestRunnerWiring:
    def test_threaded_runner_consumes_plan(self):
        """The LPT plan is a runner-path input, not test-only: the runner's
        shard assignment IS the bin_pack output, and training produces finite
        losses through the per-PS states."""
        r = ThreadedShadowRunner(
            CFG, SyncConfig(algo="ma", alpha=0.5), n_trainers=2, batch_size=16,
            optimizer=optim.adagrad(0.02), n_emb_shards=3)
        assert [list(b) for b in r.plan.bins] == emb.bin_pack(
            emb.lookup_costs(SPEC, 16), 3)
        out = r.run(4)
        assert all(np.isfinite(l) for l in out["train_loss"])
        assert out["emb_state"]["table"].shape == (SPEC.total_rows, CFG.embedding_dim)
        # the packed table moved away from init: updates landed through shards
        init = emb.init_tables(SPEC, jax.random.PRNGKey(0))
        assert not np.allclose(np.asarray(out["emb_state"]["acc"]),
                               np.asarray(init["acc"]))

    def test_hogwild_sim_step_matches_manual_oracle(self):
        """One _train_iter of the sim (fused kernels by default) produces the
        same embedding state and loss as an independently written oracle
        chain (lookup_ref -> dense grads -> sparse_adagrad_update) — this
        pins train_core's reshuffle/wiring, which kernel-level parity tests
        never exercise."""
        from repro.models import dlrm

        sim = HogwildSim(CFG, SyncConfig(algo="easgd"), n_trainers=1,
                         n_threads=1, batch_size=8,
                         optimizer=optim.adagrad(0.02), seed=5)
        st = sim.init_state()
        batch = sim.make_batch(0)
        # _train_iter donates its buffers: keep pre-step copies for the oracle.
        emb0 = jax.tree.map(jnp.copy, st.emb_state)
        w0 = sim.replica_params(st, 0)
        _, _, emb2, loss = sim._train_iter(
            st.w_stack, st.opt_stack, st.emb_state, batch)

        idx = batch["sparse"][0, 0]  # (B, F, m)
        pooled = emb.lookup_ref(emb0, SPEC, idx)
        loss_ref, _, g_pooled = dlrm.dense_loss_and_grads(
            w0, batch["dense"][0, 0], pooled, batch["labels"][0, 0])
        emb_oracle = emb.sparse_adagrad_update(emb0, SPEC, idx, g_pooled,
                                               sim.emb_lr)
        np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-5)
        for k in emb_oracle:
            np.testing.assert_allclose(np.asarray(emb2[k]),
                                       np.asarray(emb_oracle[k]),
                                       rtol=2e-5, atol=2e-5, err_msg=k)


# ---------------------------------------------------------------------------
# delay=0 same-iteration landing (regression)
# ---------------------------------------------------------------------------

class TestDelayZero:
    @staticmethod
    def _losses(delay, iters=10):
        sim = HogwildSim(
            CFG, SyncConfig(algo="easgd", gap=2, delay=delay), n_trainers=2,
            n_threads=1, batch_size=16, optimizer=optim.adagrad(0.02), seed=0)
        return sim.run(iters)["train_loss"]

    def test_delay0_distinct_from_delay1(self):
        """Pre-fix, delay=0 behaved identically to delay=1 (the landing check
        ran before the launch, so a snapshot with land_t == launch_t was only
        seen one iteration later). Same-iteration landing must change the
        trajectory."""
        l0, l1 = self._losses(0), self._losses(1)
        assert l0 != l1, "delay=0 trajectory identical to delay=1"
        assert all(np.isfinite(l) for l in l0 + l1)

    def test_delay0_sync_counts(self):
        """With delay=0 every launched sync lands in the SAME run() loop pass,
        so nothing is pending at exit and counts match the schedule exactly."""
        sim = HogwildSim(
            CFG, SyncConfig(algo="easgd", gap=2, delay=0), n_trainers=2,
            n_threads=1, batch_size=16, optimizer=optim.adagrad(0.02), seed=0)
        out = sim.run(8)
        expect = sum(int(sim._shadow_schedule(t + 1).sum()) for t in range(8))
        assert out["sync_count"] == expect


# ---------------------------------------------------------------------------
# SyncConfig.validate hardening
# ---------------------------------------------------------------------------

class TestSyncConfigValidate:
    def test_rejects_gap_zero(self):
        with pytest.raises(ValueError, match="gap"):
            SyncConfig(gap=0).validate()

    def test_rejects_negative_gap(self):
        with pytest.raises(ValueError, match="gap"):
            SyncConfig(gap=-3).validate()

    def test_rejects_negative_delay(self):
        with pytest.raises(ValueError, match="delay"):
            SyncConfig(delay=-1).validate()

    @pytest.mark.parametrize("alpha", [-0.1, 1.5])
    def test_rejects_alpha_outside_unit_interval(self, alpha):
        with pytest.raises(ValueError, match="alpha"):
            SyncConfig(alpha=alpha).validate()

    def test_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="mode"):
            SyncConfig(mode="sometimes").validate()

    def test_accepts_valid_edge_values(self):
        SyncConfig(gap=1, delay=0, alpha=0.0).validate()
        SyncConfig(gap=10 ** 9, delay=7, alpha=1.0).validate()

"""Tuning-free sync<->async mode switching (DESIGN.md §14): the dispersion
signal, the hysteresis + dwell state machine, deterministic sim replay with
flat/pytree parity across the algorithm registry, the PR 5 follow-on quality
signals on ``StragglerPolicy``, and the threaded whole-cohort handoffs
composed with demotion, PS failure, and step pipelining."""
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core import algorithms
from repro.core.membership import FaultSpec
from repro.core.modeswitch import (
    MODES, ControllerModeSchedule, ModeConfig, ModeController, ModeSchedule)
from repro.core.pipeline import PipelineConfig
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.scheduler import PolicyConfig, StragglerPolicy
from repro.core.sync import SyncConfig

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)

CFG = dlrm_ctr.tiny()
TOL = dict(rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ModeConfig validation
# ---------------------------------------------------------------------------

class TestModeConfig:
    def test_defaults_validate(self):
        cfg = ModeConfig().validate()
        assert cfg.skew_high > cfg.skew_low >= 1.0

    def test_unknown_start_mode(self):
        with pytest.raises(ValueError, match="start_mode"):
            ModeConfig(start_mode="async").validate()

    def test_skew_low_below_one(self):
        with pytest.raises(ValueError, match="skew_low"):
            ModeConfig(skew_low=0.9).validate()

    def test_inverted_hysteresis_band(self):
        with pytest.raises(ValueError, match="skew_high"):
            ModeConfig(skew_high=1.3, skew_low=1.3).validate()

    def test_bad_window_and_dwell(self):
        with pytest.raises(ValueError, match="window_s"):
            ModeConfig(window_s=0.0).validate()
        with pytest.raises(ValueError, match="min_dwell_s"):
            ModeConfig(min_dwell_s=-1.0).validate()


# ---------------------------------------------------------------------------
# Dispersion signal
# ---------------------------------------------------------------------------

class TestDispersion:
    def test_fewer_than_two_measurable_slots_is_no_signal(self):
        assert ModeController.dispersion({0: 100.0}, [True]) == 0.0
        assert ModeController.dispersion({0: 100.0, 1: 0.0}, [True, True]) == 0.0
        assert ModeController.dispersion({}, [True, True, True]) == 0.0

    def test_homogeneous_cohort_is_one(self):
        eps = {i: 100.0 for i in range(4)}
        assert ModeController.dispersion(eps, [True] * 4) == pytest.approx(1.0)

    def test_slow_outlier_registers_via_median_over_min(self):
        eps = {0: 100.0, 1: 100.0, 2: 25.0}
        assert ModeController.dispersion(eps, [True] * 3) == pytest.approx(4.0)

    def test_fast_outlier_registers_via_max_over_median(self):
        eps = {0: 100.0, 1: 100.0, 2: 400.0}
        assert ModeController.dispersion(eps, [True] * 3) == pytest.approx(4.0)

    def test_inactive_and_ineligible_slots_excluded(self):
        eps = {0: 100.0, 1: 100.0, 2: 10.0}
        assert ModeController.dispersion(eps, [True, True, False]) == pytest.approx(1.0)
        assert ModeController.dispersion(
            eps, [True] * 3, eligible=[True, True, False]
        ) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Controller state machine
# ---------------------------------------------------------------------------

def _ctl(**kw):
    base = dict(skew_high=2.0, skew_low=1.3, window_s=1.0,
                min_dwell_s=0.0, start_mode="fixed_rate")
    base.update(kw)
    return ModeController(ModeConfig(**base))


class TestModeController:
    def test_single_spike_never_switches(self):
        c = _ctl()
        assert c.observe(0.0, 3.0) is None  # breach starts the streak only
        assert c.mode == "fixed_rate" and c.transitions == []

    def test_breach_must_persist_a_full_window(self):
        c = _ctl()
        assert c.observe(0.0, 3.0) is None
        assert c.observe(0.5, 3.0) is None  # 0.5s < window_s
        dec = c.observe(1.0, 3.0)
        assert dec is not None and dec.target == "shadow"
        assert c.mode == "shadow"
        assert [(frm, to) for _, frm, to, _ in c.transitions] == [("fixed_rate", "shadow")]
        assert "skew_high" in c.transitions[0][3]

    def test_recovery_mid_window_resets_the_streak(self):
        c = _ctl()
        assert c.observe(0.0, 3.0) is None
        assert c.observe(0.5, 1.5) is None  # below skew_high: streak broken
        assert c.observe(1.0, 3.0) is None  # new streak starts here
        assert c.observe(1.9, 3.0) is None
        assert c.observe(2.0, 3.0) is not None

    def test_hysteresis_band_parks_in_current_mode(self):
        c = _ctl(start_mode="shadow")
        for t in range(10):
            # between skew_low and skew_high: breaches NEITHER band
            assert c.observe(float(t), 1.5) is None
        assert c.mode == "shadow" and c.transitions == []

    def test_min_dwell_holds_a_fresh_mode(self):
        c = _ctl(min_dwell_s=5.0)
        assert c.observe(0.0, 3.0) is None
        assert c.observe(1.0, 3.0) is None  # breach persisted, dwell holds
        assert c.observe(5.0, 3.0) is not None  # dwell satisfied
        # now in shadow: homogeneous readings breach skew_low immediately...
        assert c.observe(5.5, 1.0) is None
        assert c.observe(6.5, 1.0) is None  # ...but the dwell parks us
        dec = c.observe(10.0, 1.0)
        assert dec is not None and dec.target == "fixed_rate"
        assert len(c.transitions) == 2

    def test_zero_dispersion_is_no_signal_and_resets(self):
        c = _ctl()
        assert c.observe(0.0, 3.0) is None
        assert c.observe(5.0, 0.0) is None  # startup/no-signal: never act blind
        assert c.observe(6.0, 3.0) is None  # streak restarted from scratch
        assert c.observe(7.0, 3.0) is not None

    def test_quality_skew_feeds_the_decision(self):
        c = _ctl()
        # pace is homogeneous (1.0) but one trajectory diverges 3x
        assert c.observe(0.0, 1.0, quality_skew=3.0) is None
        dec = c.observe(1.0, 1.0, quality_skew=3.0)
        assert dec is not None and dec.target == "shadow"


# ---------------------------------------------------------------------------
# Scripted + controller-driven schedules in the deterministic sim
# ---------------------------------------------------------------------------

class TestModeSchedule:
    def test_mode_at_switch_points(self):
        s = ModeSchedule([(5, "fixed_rate"), (10, "shadow")], start_mode="shadow")
        assert s.mode_at(0) == "shadow"
        assert s.mode_at(5) == "fixed_rate"
        assert s.mode_at(9) == "fixed_rate"
        assert s.mode_at(10) == "shadow"

    def test_unknown_modes_rejected(self):
        with pytest.raises(ValueError, match="unknown mode"):
            ModeSchedule([(3, "turbo")])
        with pytest.raises(ValueError, match="start_mode"):
            ModeSchedule([], start_mode="turbo")

    def test_sim_rejects_start_mode_mismatch(self):
        with pytest.raises(ValueError, match="mode_schedule"):
            HogwildSim(
                CFG, SyncConfig(algo="easgd", mode="fixed_rate", gap=4, alpha=0.5),
                n_trainers=2, n_threads=2, batch_size=16,
                optimizer=optim.adagrad(0.02), seed=0,
                mode_schedule=ModeSchedule([(3, "fixed_rate")], start_mode="shadow"))

    def test_controller_schedule_needs_slots(self):
        with pytest.raises(ValueError, match="n_slots"):
            ControllerModeSchedule(_ctl(), lambda t, s: 1.0, 0)


R_SIM = 3


def _sim_rates(t, s):
    # slot R-1 runs at 10% pace for iterations [5, 15): the controller should
    # earn shadow shortly after t=5 and hand back after the recovery at t=15
    return 0.1 if (s == R_SIM - 1 and 5 <= t < 15) else 1.0


def _sim_run(algo, engine, *, iters=24, quality=None, rates=_sim_rates):
    ctl = ModeController(ModeConfig(skew_high=2.0, skew_low=1.3, window_s=2.0,
                                    min_dwell_s=3.0, start_mode="fixed_rate"))
    msched = ControllerModeSchedule(ctl, rates, n_slots=R_SIM, quality=quality)
    sim = HogwildSim(
        CFG, SyncConfig(algo=algo, mode="fixed_rate", gap=4, alpha=0.5, engine=engine),
        n_trainers=R_SIM, n_threads=2, batch_size=16,
        optimizer=optim.adagrad(0.02), seed=0, mode_schedule=msched)
    return sim.run(iters)


class TestSimModeSwitch:
    @pytest.mark.parametrize("algo", algorithms.names())
    def test_flat_pytree_parity_across_a_switch_cycle(self, algo):
        """The same closed-loop mode trace produces the same trajectory on
        both sync engines, for every registered algorithm."""
        a = _sim_run(algo, "flat")
        b = _sim_run(algo, "pytree")
        assert a["mode_events"] == b["mode_events"]
        switches = [(frm, to) for _, frm, to in a["mode_events"]]
        assert ("fixed_rate", "shadow") in switches, a["mode_events"]
        assert ("shadow", "fixed_rate") in switches, a["mode_events"]
        np.testing.assert_allclose(a["train_loss"], b["train_loss"], **TOL)

    def test_replay_is_bit_identical(self):
        a = _sim_run("easgd", "flat")
        b = _sim_run("easgd", "flat")
        assert a["mode_events"] == b["mode_events"]
        assert list(a["train_loss"]) == list(b["train_loss"])
        assert a["mode"] == b["mode"]

    def test_quality_trace_pushes_to_shadow_at_healthy_pace(self):
        def quality(t, s):
            # slot 2's loss EMA diverges 3x from t=5 on; pace stays uniform
            return 3.0 if (s == 2 and t >= 5) else 1.0

        out = _sim_run("easgd", "flat", quality=quality, rates=lambda t, s: 1.0)
        switches = [(frm, to) for _, frm, to in out["mode_events"]]
        assert ("fixed_rate", "shadow") in switches
        assert out["mode"] == "shadow"  # divergence never clears: no handback

    def test_no_schedule_no_mode_keys(self):
        sim = HogwildSim(
            CFG, SyncConfig(algo="easgd", mode="shadow", gap=4, alpha=0.5),
            n_trainers=2, n_threads=2, batch_size=16,
            optimizer=optim.adagrad(0.02), seed=0)
        out = sim.run(6)
        assert "mode_events" not in out


# ---------------------------------------------------------------------------
# PR 5 follow-on: quality signals on the demotion policy
# ---------------------------------------------------------------------------

class TestPolicyQualitySignals:
    def _policy(self, **kw):
        base = dict(eps_floor_frac=0.5, readmit_frac=0.8, window_s=1.0,
                    probation_s=1.0)
        base.update(kw)
        return StragglerPolicy(PolicyConfig(**base), n_slots=3)

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="loss_div_frac"):
            PolicyConfig(loss_div_frac=0.0).validate()
        with pytest.raises(ValueError, match="staleness_max"):
            PolicyConfig(staleness_max=-1.0).validate()

    def test_loss_divergence_demotes_at_healthy_pace(self):
        p = self._policy(loss_div_frac=0.5)
        eps = {i: 100.0 for i in range(3)}
        active = [True] * 3
        loss = {0: 1.0, 1: 1.0, 2: 2.0}  # 2x the cohort median
        assert p.observe(0.0, eps, active, loss_by_slot=loss) == []
        acts = p.observe(1.0, eps, active, loss_by_slot=loss)
        assert [(a.kind, a.slot) for a in acts] == [("demote", 2)]
        assert "loss-divergence" in acts[0].reason

    def test_staleness_demotes(self):
        p = self._policy(staleness_max=5.0)
        eps = {i: 100.0 for i in range(3)}
        active = [True] * 3
        stale = {0: 0.5, 1: 0.5, 2: 12.0}
        assert p.observe(0.0, eps, active, staleness_by_slot=stale) == []
        acts = p.observe(1.0, eps, active, staleness_by_slot=stale)
        assert [(a.kind, a.slot) for a in acts] == [("demote", 2)]
        assert "staleness" in acts[0].reason

    def test_divergent_loss_blocks_readmission_but_staleness_does_not(self):
        p = self._policy(loss_div_frac=0.5, staleness_max=5.0)
        eps = {i: 100.0 for i in range(3)}
        active = [True] * 3
        loss = {0: 1.0, 1: 1.0, 2: 2.0}
        stale = {0: 0.5, 1: 0.5, 2: 50.0}
        p.observe(0.0, eps, active, loss_by_slot=loss, staleness_by_slot=stale)
        p.observe(1.0, eps, active, loss_by_slot=loss, staleness_by_slot=stale)
        assert p.state(2) == "demoted"
        # pace is perfect, but the trajectory still diverges: stay demoted
        p.observe(2.0, eps, active, loss_by_slot=loss, staleness_by_slot=stale)
        assert p.state(2) == "demoted"
        # loss recovers; staleness is HUGE by construction (no landed syncs
        # while demoted) — it must not block the probation path
        ok_loss = {0: 1.0, 1: 1.0, 2: 1.0}
        p.observe(3.0, eps, active, loss_by_slot=ok_loss, staleness_by_slot=stale)
        assert p.state(2) == "probation"
        acts = p.observe(4.5, eps, active, loss_by_slot=ok_loss, staleness_by_slot=stale)
        assert [(a.kind, a.slot) for a in acts] == [("readmit", 2)]

    def test_pace_breach_names_the_demotion_before_quality(self):
        p = self._policy(loss_div_frac=0.5)
        eps = {0: 100.0, 1: 100.0, 2: 10.0}  # pace AND loss both breach
        loss = {0: 1.0, 1: 1.0, 2: 9.0}
        active = [True] * 3
        p.observe(0.0, eps, active, loss_by_slot=loss)
        acts = p.observe(1.0, eps, active, loss_by_slot=loss)
        assert len(acts) == 1 and "straggler" in acts[0].reason


# ---------------------------------------------------------------------------
# Threaded whole-cohort handoffs
# ---------------------------------------------------------------------------

def _snappy_ctl(**kw):
    base = dict(skew_high=2.0, skew_low=1.2, window_s=0.15,
                min_dwell_s=0.3, start_mode="fixed_rate")
    base.update(kw)
    return ModeController(ModeConfig(**base))


def _threaded(mode="fixed_rate", fault=None, ctl=None, iters=8, warm=False, **kw):
    r = ThreadedShadowRunner(
        CFG, SyncConfig(algo="easgd", alpha=0.5, mode=mode, gap=3),
        n_trainers=3, batch_size=32, optimizer=optim.adagrad(0.02),
        sync_sleep_s=0.01, fault_spec=fault, mode_controller=ctl, **kw)
    if warm:
        r.warmup()  # keep tracing out of the controllers' detection windows
    return r.run(iters)


class TestThreadedModeSwitch:
    @pytest.fixture(scope="class", autouse=True)
    def warmup(self):
        # compile both modes' programs so timing-sensitive runs are clean
        _threaded("shadow", iters=2)
        _threaded("fixed_rate", iters=2)

    def test_controller_start_mode_mismatch_raises(self):
        with pytest.raises(ValueError, match="mode_controller"):
            _threaded("fixed_rate", ctl=_snappy_ctl(start_mode="shadow"), iters=2)

    def test_dispersion_hands_off_to_shadow(self):
        """A persistent straggler under the foreground barrier: the controller
        must drain the barrier and move the WHOLE cohort to shadow, and the
        run must complete every slot's iterations."""
        ctl = _snappy_ctl()
        out = _threaded("fixed_rate", FaultSpec(straggler_sleep_s={2: 0.5}),
                        ctl=ctl, iters=8)
        assert out["iter_count"] == [8, 8, 8]
        assert all(np.isfinite(l) for l in out["train_loss"])
        trans = [(frm, to) for _, frm, to, _ in out["mode_transitions"]]
        assert trans and trans[0] == ("fixed_rate", "shadow"), out["mode_transitions"]
        assert out["mode"] == "shadow"
        # the handoff lands in the membership log with provenance
        notes = [e for e in out["membership_events"] if e.kind == "mode_switch"]
        assert notes and "shadow" in notes[0].reason

    def test_no_controller_is_legacy_behavior(self):
        out = _threaded("fixed_rate", iters=4)
        assert out["mode"] == "fixed_rate" and out["mode_transitions"] == []

    def test_switch_under_demotion_interleave(self):
        """Mode controller AND straggler policy live on the same run: the
        mode handoff fires first (shorter window), the policy then demotes
        the transient straggler, and nothing deadlocks or loses iterations.
        Recipe margins follow test_scheduler's closed-loop test: a short
        busy-clock meter window, a warmed-up runner, and an iteration budget
        that keeps the healthy slots alive past both detection windows."""
        ctl = _snappy_ctl()
        # Policy window (1.0s) is deliberately much longer than the
        # controller's (0.15s): the handoff must land first, because a
        # demoted slot drops out of dispersion() and would mask the skew.
        policy = StragglerPolicy(
            PolicyConfig(eps_floor_frac=0.5, readmit_frac=0.75,
                         window_s=1.0, probation_s=0.1, min_active=2),
            n_slots=3)
        # eps_window_s must exceed the straggler's sleep: with zero events
        # in-window its EPS reads 0.0, which dispersion() treats as "no
        # signal" and EXCLUDES — the controller would never see the skew.
        out = _threaded(
            "fixed_rate",
            FaultSpec(straggler_sleep_s={2: 0.4}, straggler_until={2: 8}),
            ctl=ctl, iters=1200, warm=True, eps_window_s=1.0,
            straggler_policy=policy)
        assert out["iter_count"] == [1200, 1200, 1200]
        assert all(np.isfinite(l) for l in out["train_loss"])
        trans = [(frm, to) for _, frm, to, _ in out["mode_transitions"]]
        assert trans and trans[0] == ("fixed_rate", "shadow"), out["mode_transitions"]
        assert any(to == "demoted" for _, _, _, to in policy.transitions), (
            policy.transitions)
        assert out["mode"] in MODES

    def test_switch_during_ps_fail_with_pipeline(self):
        """Chaos composition: a PS shard dies and rehydrates, step pipelines
        are double-buffering lookups, AND the controller switches modes
        mid-run. Handoffs drain the pipelines; the run completes and the PS
        recovers."""
        ctl = _snappy_ctl()
        fault = FaultSpec(straggler_sleep_s={2: 0.4}, ps_fail_at={0: 3},
                          ps_recover_after_s=0.2)
        out = _threaded("fixed_rate", fault, ctl=ctl, iters=8,
                        pipeline=PipelineConfig(depth=2))
        assert out["iter_count"] == [8, 8, 8]
        assert all(np.isfinite(l) for l in out["train_loss"])
        trans = [(frm, to) for _, frm, to, _ in out["mode_transitions"]]
        assert trans and trans[0] == ("fixed_rate", "shadow")
        kinds = [(e.kind, e.shard) for e in out["shard_events"]]
        assert ("ps_fail", 0) in kinds and ("ps_recover", 0) in kinds
        # the handoff (and the PS epoch) drained in-flight pipeline stages
        assert out["pipeline_stats"]["drains"] >= 1, out["pipeline_stats"]

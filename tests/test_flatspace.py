"""Flat replica-space sync engine: layout round-trips, fused-kernel parity
against the core/sync.py pytree oracle, and end-to-end flat-vs-pytree runner
equivalence (DESIGN.md §3)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms
from repro.core import sync as S
from repro.core.flatspace import LANE, FlatSpace
from repro.kernels.bmuf_update.ops import bmuf_sync_op
from repro.kernels.bmuf_update.ref import bmuf_update_ref
from repro.kernels.easgd_update.ops import easgd_round_op
from repro.kernels.easgd_update.ref import easgd_round_ref
from repro.kernels.ma_update.ops import ma_sync_op, replica_mean_op
from repro.kernels.ma_update.ref import ma_update_ref, replica_mean_ref

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-5, atol=1e-6)


def tree_close(a, b, **tol):
    tol = tol or TOL
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **tol)


# ---------------------------------------------------------------------------
# Layout: pack -> unpack round trips
# ---------------------------------------------------------------------------

def _random_tree(key, dtypes):
    """Nested mixed-dtype pytree with awkward (non-lane-aligned) shapes."""
    ks = jax.random.split(key, 5)
    return {
        "mlp": [
            {"w": jax.random.normal(ks[0], (13, 37)).astype(dtypes[0]),
             "b": jax.random.normal(ks[1], (37,)).astype(dtypes[1])},
            {"w": jax.random.normal(ks[2], (37, 5)).astype(dtypes[2 % len(dtypes)]),
             "b": jnp.float32(0.25)},  # scalar leaf
        ],
        "gain": (jax.random.normal(ks[3], (3, 1, 7)).astype(dtypes[0]),
                 jax.random.normal(ks[4], (111,)).astype(dtypes[1])),
    }


class TestRoundTrip:
    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("dtypes", [
        (jnp.float32, jnp.float32, jnp.float32),
        (jnp.bfloat16, jnp.float32, jnp.float16),
        (jnp.float16, jnp.bfloat16, jnp.float32),
    ])
    def test_pack_unpack_property(self, seed, dtypes):
        """fp32 packing is lossless for f32/bf16/f16 leaves: unpack(pack(t)) == t
        exactly, with dtypes and shapes restored."""
        tree = _random_tree(jax.random.PRNGKey(seed), dtypes)
        fs = FlatSpace.from_tree(tree)
        plane = fs.pack(tree)
        assert plane.shape == (fs.n_rows, LANE) and plane.dtype == jnp.float32
        assert fs.n_rows % fs.block == 0
        out = fs.unpack(plane)
        assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    @pytest.mark.parametrize("seed", range(3))
    def test_stack_roundtrip(self, seed):
        tree = _random_tree(jax.random.PRNGKey(seed),
                            (jnp.float32, jnp.bfloat16, jnp.float32))
        stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (4,) + jnp.shape(x)), tree)
        fs = FlatSpace.from_tree(tree)
        buf = fs.pack_stack(stack)
        assert buf.shape == (4, fs.n_rows, LANE)
        out = fs.unpack_stack(buf)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(stack)):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        # per-replica view agrees with the stack view
        one = fs.unpack_replica(buf, 2)
        tree_close(one, tree)

    def test_padding_is_zero_and_stable(self):
        tree = {"w": jnp.ones((130,))}
        fs = FlatSpace.from_tree(tree, block=8)
        plane = fs.pack(tree)
        assert fs.total == 130 and fs.slots >= 130
        np.testing.assert_array_equal(np.asarray(plane.reshape(-1)[130:]), 0.0)

    def test_unpackable_dtypes_rejected(self):
        """fp32 round-tripping silently corrupts int/f64 leaves (e.g. int32
        16777217 -> 16777216), so from_tree must refuse them up front."""
        with pytest.raises(TypeError, match="lossless"):
            FlatSpace.from_tree({"w": jnp.ones((4,)), "step": jnp.int32(7)})
        with pytest.raises(TypeError, match="lossless"):
            FlatSpace.from_tree({"ids": jnp.zeros((3,), jnp.int64)})


# ---------------------------------------------------------------------------
# Fused kernels vs the sync.py pytree oracle
# ---------------------------------------------------------------------------

def _buffers(key, R=4, n=256):
    stack = jax.random.normal(key, (R, n, LANE), jnp.float32)
    snap = jax.random.normal(jax.random.fold_in(key, 1), (R, n, LANE), jnp.float32)
    ps = jax.random.normal(jax.random.fold_in(key, 2), (n, LANE), jnp.float32)
    return stack, snap, ps


class TestEASGDFlat:
    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("fired", [(0, 1, 2, 3), (1, 3), (2,)])
    def test_masked_round_vs_oracle(self, fired, use_pallas):
        """Fired replicas follow sequential Algorithm-2 semantics against the
        launch snapshot; un-fired replicas are bit-identical."""
        stack, snap, ps = _buffers(jax.random.PRNGKey(7))
        fired_arr = jnp.asarray(fired, jnp.int32)
        # the op donates stack/ps — pass copies so the originals survive;
        # the snapshot is a compact gather of only the fired rows
        new_stack, new_ps = easgd_round_op(
            stack.copy(), ps.copy(), snap[fired_arr], fired_arr, 0.3,
            use_pallas=use_pallas)
        ref_stack, ref_ps = easgd_round_ref(stack, ps, snap[fired_arr], fired, 0.3)
        np.testing.assert_allclose(np.asarray(new_stack), np.asarray(ref_stack), **TOL)
        np.testing.assert_allclose(np.asarray(new_ps), np.asarray(ref_ps), **TOL)
        mask = jnp.asarray([i in fired for i in range(4)])
        o_stack, o_ps = S.easgd_round(
            {"w": stack}, {"w": ps}, 0.3, mask=mask, snapshot={"w": snap})
        np.testing.assert_allclose(np.asarray(new_stack), np.asarray(o_stack["w"]), **TOL)
        np.testing.assert_allclose(np.asarray(new_ps), np.asarray(o_ps["w"]), **TOL)
        for i in range(4):
            if i not in fired:
                assert np.array_equal(np.asarray(new_stack[i]), np.asarray(stack[i]))

    def test_delay_path_snapshot_differs_from_current(self):
        """PS pulls toward the LAUNCH snapshot while the pull-back lands on the
        current (moved-on) replica — the §3.3 background semantics."""
        stack, snap, ps = _buffers(jax.random.PRNGKey(11))
        fired = jnp.arange(4, dtype=jnp.int32)
        with_snap, _ = easgd_round_op(stack.copy(), ps.copy(), snap[fired], fired, 0.5)
        no_snap, _ = easgd_round_op(stack.copy(), ps.copy(), stack[fired], fired, 0.5)
        assert float(jnp.max(jnp.abs(with_snap - no_snap))) > 1e-3


class TestMAFlat:
    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_mean_and_pullback_vs_oracle(self, use_pallas):
        stack, snap, _ = _buffers(jax.random.PRNGKey(3))
        mean = (replica_mean_op(snap) if use_pallas else replica_mean_ref(snap))
        new = (ma_sync_op(stack.copy(), mean, 0.4) if use_pallas  # op donates stack
               else ma_update_ref(stack, mean, 0.4))
        oracle = S.ma_round({"w": stack}, 0.4, snapshot={"w": snap})
        np.testing.assert_allclose(np.asarray(new), np.asarray(oracle["w"]), **TOL)

    def test_no_delay_uses_current_stack(self):
        stack, _, _ = _buffers(jax.random.PRNGKey(4))
        mean = jnp.mean(stack, axis=0)
        new = ma_sync_op(stack.copy(), replica_mean_op(stack), 1.0)
        for i in range(4):
            np.testing.assert_allclose(np.asarray(new[i]), np.asarray(mean), **TOL)


class TestBMUFFlat:
    @pytest.mark.parametrize("use_pallas", [True, False])
    @pytest.mark.parametrize("bm,nesterov", [(0.0, False), (0.8, False), (0.9, True)])
    def test_landing_vs_oracle_multi_round(self, bm, nesterov, use_pallas):
        """State (w_global, velocity) carries correctly across rounds."""
        stack, snap, _ = _buffers(jax.random.PRNGKey(5))
        wg = jnp.mean(stack, axis=0)
        vel = jnp.zeros_like(wg)
        # the fused op donates stack/wg/vel — the oracle carries its own copies
        o_state = S.BMUFState(w_global={"w": wg.copy()}, velocity={"w": vel.copy()})
        o_stack = {"w": stack.copy()}
        for r in range(3):
            mean = replica_mean_op(snap) if use_pallas else replica_mean_ref(snap)
            if use_pallas:
                stack, wg, vel = bmuf_sync_op(stack, mean, wg, vel, 0.5,
                                              eta=0.9, block_momentum=bm,
                                              nesterov=nesterov)
            else:
                stack, wg, vel = bmuf_update_ref(stack, mean, wg, vel, 0.5,
                                                 eta=0.9, block_momentum=bm,
                                                 nesterov=nesterov)
            o_stack, o_state = S.bmuf_round(o_stack, o_state, 0.5, eta=0.9,
                                            block_momentum=bm, nesterov=nesterov,
                                            snapshot={"w": snap})
            # next round's launch snapshot = current state (copy: the fused op
            # donates `stack`, and the oracle still reads the snapshot)
            snap = stack.copy()
        np.testing.assert_allclose(np.asarray(stack), np.asarray(o_stack["w"]), **TOL)
        np.testing.assert_allclose(np.asarray(wg), np.asarray(o_state.w_global["w"]), **TOL)
        np.testing.assert_allclose(np.asarray(vel), np.asarray(o_state.velocity["w"]), **TOL)


# ---------------------------------------------------------------------------
# End-to-end: HogwildSim flat engine == pytree engine
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _run_engine(algo, engine, mode="shadow", delay=1, iters=12):
    from repro import optim
    from repro.configs import dlrm_ctr
    from repro.core.runners import HogwildSim

    sim = HogwildSim(
        dlrm_ctr.tiny(),
        S.SyncConfig(algo=algo, mode=mode, gap=4, alpha=0.5, delay=delay,
                     engine=engine),
        n_trainers=3, n_threads=2, batch_size=32,
        optimizer=optim.adagrad(0.02),
        seed=0,
    )
    out = sim.run(iters)
    ev = sim.evaluate(out["state"], n_batches=2, batch_size=256)
    return tuple(out["train_loss"]), ev, out["sync_count"]


# Parameterized over the REGISTRY: a newly registered algorithm (e.g.
# gossip) gets flat-vs-pytree parity coverage for free.
@pytest.mark.parametrize("algo", algorithms.names())
def test_sim_flat_matches_pytree_shadow(algo):
    """mode="shadow" exercises the masked + launch-snapshot/delay paths; the
    two engines must produce numerically equivalent training (fp32 tol)."""
    loss_f, ev_f, n_f = _run_engine(algo, "flat")
    loss_p, ev_p, n_p = _run_engine(algo, "pytree")
    assert n_f == n_p
    np.testing.assert_allclose(loss_f, loss_p, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ev_f, ev_p, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", algorithms.names())
def test_sim_flat_matches_pytree_fixed_rate(algo):
    loss_f, ev_f, _ = _run_engine(algo, "flat", mode="fixed_rate")
    loss_p, ev_p, _ = _run_engine(algo, "pytree", mode="fixed_rate")
    np.testing.assert_allclose(loss_f, loss_p, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ev_f, ev_p, rtol=1e-4, atol=1e-5)


def test_sim_flat_longer_delay_matches(algo="ma"):
    loss_f, ev_f, _ = _run_engine(algo, "flat", delay=3)
    loss_p, ev_p, _ = _run_engine(algo, "pytree", delay=3)
    np.testing.assert_allclose(loss_f, loss_p, rtol=1e-4, atol=1e-5)


def test_invalid_engine_rejected():
    with pytest.raises(ValueError):
        S.SyncConfig(engine="sparse").validate()


# ---------------------------------------------------------------------------
# HBM stream accounting (the perf claim sync_bench records per PR)
# ---------------------------------------------------------------------------

class TestStreamAccounting:
    @pytest.mark.parametrize("r", [2, 8, 20])
    def test_flat_strictly_reduces_streams(self, r):
        from benchmarks.sync_bench import (
            MIN_STREAM_RATIO, flat_sync_bytes, pytree_sync_bytes)

        n = 512 * 1024
        for algo in algorithms.names():
            ratio = pytree_sync_bytes(algo, r, n) / flat_sync_bytes(algo, r, n)
            assert ratio >= MIN_STREAM_RATIO[algo], (algo, r, ratio)

    def test_unfired_replicas_cost_nothing(self):
        from benchmarks.sync_bench import flat_sync_bytes

        n = 1024
        full = flat_sync_bytes("easgd", 8, n, fired=8)
        one = flat_sync_bytes("easgd", 8, n, fired=1)
        assert one < full

"""End-to-end behaviour tests for the ShadowSync system (paper claims, scaled down)."""
import functools

import jax
import numpy as np
import pytest

from repro import optim
from repro.configs import dlrm_ctr
from repro.core import algorithms
from repro.core.elp import PAPER_TABLE1, elp
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig

# real-thread suites must never wedge CI: pytest-timeout (see
# requirements-ci.txt) enforces this per-test wall ceiling
pytestmark = pytest.mark.timeout(300)


CFG = dlrm_ctr.tiny()
ITERS = 60


@functools.lru_cache(maxsize=None)
def run_cached(algo, mode, gap=5, trainers=4, threads=2, seed=0, iters=ITERS, delay=1):
    sim = HogwildSim(
        CFG, SyncConfig(algo=algo, mode=mode, gap=gap, alpha=0.5, delay=delay),
        n_trainers=trainers, n_threads=threads,
        batch_size=64, optimizer=optim.adagrad(0.02), seed=seed)
    out = sim.run(iters)
    return {
        "start": float(np.mean(out["train_loss"][:5])),
        "end": float(np.mean(out["train_loss"][-5:])),
        "eval": sim.evaluate(out["state"], n_batches=5, batch_size=1024),
        "avg_sync_gap": out["avg_sync_gap"],
    }


@pytest.mark.parametrize("algo", algorithms.names())
@pytest.mark.parametrize("mode", ["shadow", "fixed_rate"])
def test_training_converges(algo, mode):
    """One-pass CTR training converges for every registered algorithm in both
    shadow and fixed-rate mode (gossip rides in via the registry)."""
    out = run_cached(algo, mode)
    assert out["end"] < out["start"] - 0.05, (algo, mode, out)
    assert np.isfinite(out["eval"])


def test_shadow_quality_on_par_with_fixed_rate():
    """Paper Table 2: shadow-EASGD evaluation quality ~ FR-EASGD (or better)."""
    ev_shadow = run_cached("easgd", "shadow")["eval"]
    ev_fr = run_cached("easgd", "fixed_rate")["eval"]
    assert ev_shadow < ev_fr * 1.05  # within 5% (paper: shadow wins outright)


def test_sync_keeps_replicas_consistent():
    """The constraint in Eq. 1: with sync, replica dispersion shrinks by orders
    of magnitude vs unsynced independent training (and quality stays on par —
    at laptop scale the quality gap itself is within noise)."""
    import jax

    def dispersion(algo, mode, gap):
        sim = HogwildSim(CFG, SyncConfig(algo=algo, mode=mode, gap=gap, alpha=0.5),
                         n_trainers=4, n_threads=2, batch_size=64,
                         optimizer=optim.adagrad(0.02), seed=0)
        out = sim.run(40)
        w = out["state"].w_stack
        tot = 0.0
        for leaf in jax.tree.leaves(w):
            mean = leaf.mean(axis=0, keepdims=True)
            tot += float(((leaf - mean) ** 2).sum())
        return tot

    d_sync = dispersion("easgd", "shadow", 5)
    d_none = dispersion("easgd", "fixed_rate", 10 ** 9)
    assert d_sync < 0.2 * d_none, (d_sync, d_none)


def test_avg_sync_gap_accounting():
    out = run_cached("easgd", "shadow", gap=5)
    # staggered shadow clocks: average gap ~ configured gap
    assert 3.0 < out["avg_sync_gap"] < 8.0


def test_more_hogwild_threads_mild_quality_drop():
    """Paper Fig 8: more Hogwild worker threads => at most mild loss increase."""
    ev1 = run_cached("easgd", "shadow", threads=1)["eval"]
    ev8 = run_cached("easgd", "shadow", threads=8)["eval"]
    assert ev8 < ev1 * 1.15


def test_hogwild_staleness_converges():
    """m grads from one snapshot != m sequential steps; both must converge."""
    out4 = run_cached("easgd", "shadow", threads=4, iters=40)
    assert out4["end"] < 0.65


def test_one_pass_data_never_repeats():
    sim = HogwildSim(CFG, SyncConfig(), n_trainers=2, n_threads=1, batch_size=16,
                     optimizer=optim.sgd(0.01))
    b1, b2 = sim.make_batch(0), sim.make_batch(1)
    assert not np.array_equal(np.asarray(b1["sparse"]), np.asarray(b2["sparse"]))


def test_threaded_runner_background_sync_runs():
    """Algorithm 1 with real threads: shadow thread syncs while trainers train."""
    r = ThreadedShadowRunner(CFG, SyncConfig(algo="easgd", alpha=0.5), n_trainers=2,
                             batch_size=32, optimizer=optim.adagrad(0.02),
                             sync_sleep_s=0.002)
    out = r.run(25)
    assert out["sync_count"] > 0
    assert out["eps"] > 0
    assert all(np.isfinite(l) for l in out["train_loss"])


def test_threaded_runner_decentralized():
    r = ThreadedShadowRunner(CFG, SyncConfig(algo="ma", alpha=0.5), n_trainers=2,
                             batch_size=32, optimizer=optim.adagrad(0.02),
                             sync_sleep_s=0.002)
    out = r.run(20)
    assert out["sync_count"] > 0


def test_elp_paper_number():
    """Table 1: 20 trainers x 24 Hogwild threads x batch 200 = 96,000 ELP."""
    assert elp(200, 24, 20) == 96000 == PAPER_TABLE1["ShadowSync"]["elp"]


def test_elp_exceeds_prior_art():
    ours = elp(200, 24, 20)
    for name, row in PAPER_TABLE1.items():
        if name != "ShadowSync" and row["elp"] is not None:
            assert ours > row["elp"], name


def test_shadow_sync_delay_tolerated():
    """Longer in-flight delay (stale snapshots) must not break convergence —
    the elastic pull-back is what makes background sync safe (paper §3.3)."""
    base = run_cached("ma", "shadow")["eval"]
    delayed = run_cached("ma", "shadow", delay=4)["eval"]
    assert delayed < base * 1.1

"""Sharding-rule tests + a reduced-mesh dry-run in a subprocess (8 fake devices).

The subprocess is required because XLA locks the host device count at first
init — the main test process must keep seeing 1 CPU device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer as T
from repro.sharding import rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestParamRules:
    def _specs(self, arch):
        cfg = reduced(get_config(arch))
        sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        flat = jax.tree_util.tree_flatten_with_path(sds)[0]
        out = {}
        for path, leaf in flat:
            spec = rules.param_spec(path, leaf, fsdp_axis="data")
            assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
            out["/".join(rules._path_names(path))] = (spec, leaf.shape)
        return out

    def test_dense_rules(self):
        specs = self._specs("granite-20b")
        wq = [v for k, v in specs.items() if k.endswith("mixer/wq")][0]
        assert wq[0][-1] == "model" and wq[0][-2] == "data"
        wo = [v for k, v in specs.items() if k.endswith("mixer/wo")][0]
        assert wo[0][-2] == "model"
        norm = [v for k, v in specs.items() if k.endswith("norm1/scale")][0]
        assert all(s is None for s in norm[0])

    def test_moe_expert_parallel(self):
        specs = self._specs("kimi-k2-1t-a32b")
        wg = [v for k, v in specs.items() if k.endswith("ffn/w_gate") and len(v[1]) == 4][0]
        # (repeats, E, d, f): experts over model axis
        assert wg[0][1] == "model"

    def test_embed_vocab_sharded(self):
        specs = self._specs("minicpm-2b")
        emb = [v for k, v in specs.items() if k.endswith("embed/table")][0]
        assert emb[0][0] == "model"

    def test_replica_axis_prepended(self):
        cfg = reduced(get_config("granite-20b"))
        sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        stacked = jax.tree.map(lambda s: jax.ShapeDtypeStruct((2,) + s.shape, s.dtype), sds)
        flat = jax.tree_util.tree_flatten_with_path(stacked)[0]
        for path, leaf in flat:
            spec = rules.param_spec(path, leaf, fsdp_axis="data", replica_axis="pod")
            assert spec[0] == "pod", path


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re, sys
    import jax, jax.numpy as jnp
    sys.path.insert(0, {src!r})
    import dataclasses
    from repro.configs.base import get_config, reduced
    from repro.core import spmd
    from repro.core.sync import SyncConfig
    from repro.launch import specs as SP
    from repro.sharding import ctx as shctx
    from repro import optim

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced(get_config({arch!r}))
    opt = optim.adagrad(1e-2)

    # shadow-mode train step: 2 replicas on the pod axis
    params = SP.param_structs(cfg, mesh, mode="shadow", n_replicas=2)
    opt_state = SP.opt_structs(opt, params, mesh, replica_axis="pod")
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch = {{"tokens": jax.ShapeDtypeStruct((2, 4, 64), jnp.int32,
              sharding=NamedSharding(mesh, P("pod", "data", None)))}}
    step = spmd.make_train_step(cfg, opt, "shadow")
    with shctx.activation_mesh(mesh, batch_axes=("data",)):
        train_hlo = jax.jit(step).lower(params, opt_state, batch).compile().as_text()

    sync = spmd.make_sync_step(cfg, SyncConfig(algo="ma"))
    sync_hlo = jax.jit(sync).lower(params).compile().as_text()

    def cross_pod_groups(hlo):
        n = 0
        for m in re.finditer(r"replica_groups=\\{{(.*?)\\}}(?:,|\\s)", hlo):
            for grp in re.findall(r"\\{{([\\d,]+)\\}}", m.group(0)):
                ids = [int(x) for x in grp.split(",")]
                if any(i < 4 for i in ids) and any(i >= 4 for i in ids):
                    n += 1
        # iota-style groups: replica_groups=[2,4]<=[8] etc.
        for m in re.finditer(r"replica_groups=\\[(\\d+),(\\d+)\\]<=\\[([\\d,]+)\\]"
                             r"(?:T\\(([\\d,]+)\\))?", hlo):
            rows, cols = int(m.group(1)), int(m.group(2))
            perm = list(range(8))
            src = [int(x) for x in m.group(3).split(",")]
            # reconstruct device order
            import numpy as np
            arr = np.arange(8).reshape(src)
            if m.group(4):
                arr = arr.transpose([int(x) for x in m.group(4).split(",")])
            arr = arr.reshape(rows, cols)
            for row in arr:
                if any(i < 4 for i in row) and any(i >= 4 for i in row):
                    n += 1
        return n

    print(json.dumps({{
        "train_cross_pod": cross_pod_groups(train_hlo),
        "sync_cross_pod": cross_pod_groups(sync_hlo),
    }}))
""")


@pytest.mark.slow
def test_shadow_train_has_no_cross_pod_collectives():
    """THE defining ShadowSync property at the HLO level: train_step contains no
    collective whose group spans pods; sync_step (MA all-reduce) does."""
    script = SUBPROCESS_SCRIPT.format(src=os.path.abspath(SRC), arch="granite-20b")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["train_cross_pod"] == 0, res
    assert res["sync_cross_pod"] > 0, res


@pytest.mark.slow
def test_reduced_mesh_dryrun_moe():
    """MoE (expert-parallel) lowers and compiles on a small 3-axis mesh."""
    script = SUBPROCESS_SCRIPT.format(src=os.path.abspath(SRC), arch="phi3.5-moe-42b-a6.6b")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]


class TestCacheSpecs:
    def test_kv_cache_sharding_decode(self):
        from repro.launch.specs import _cache_sharding

        mesh_shape = {"data": 16, "model": 16}
        spec = _cache_sharding(
            [jax.tree_util.DictKey("k")], jax.ShapeDtypeStruct((52, 128, 32768, 16, 128), jnp.bfloat16),
            mesh_shape)
        assert spec[1] == "data" and spec[3] == "model"

    def test_kv_cache_long_context_b1(self):
        from repro.launch.specs import _cache_sharding

        mesh_shape = {"data": 16, "model": 16}
        spec = _cache_sharding(
            [jax.tree_util.DictKey("k")], jax.ShapeDtypeStruct((9, 1, 524288, 8, 128), jnp.bfloat16),
            mesh_shape)
        # batch=1 unshardable -> sequence sharded over data
        assert spec[2] == "data"

"""End-to-end driver: ~100M-parameter DLRM, a few hundred ShadowSync steps.

    PYTHONPATH=src python examples/train_dlrm_shadowsync.py [--threaded]

The model: 6.1M embedding rows x dim 16 (~98M embedding params) + MLPs. Default
runs the deterministic simulator (4 trainers x 2 threads, 300 one-pass
iterations); --threaded runs the faithful real-thread Algorithm 1 instead
(trainer threads + a continuously-syncing background shadow thread).
"""
import argparse
import dataclasses
import time

import numpy as np

from repro import checkpoint as ckpt
from repro import optim
from repro.configs import dlrm_ctr
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.sync import SyncConfig

# ~100M params: power-law tables totalling ~6.1M rows x dim 16.
CFG_100M = dataclasses.replace(
    dlrm_ctr.CONFIG,
    embedding_dim=16,
    table_sizes=(3_000_000, 1_500_000, 800_000, 400_000, 200_000, 100_000,
                 50_000, 25_000, 12_000, 6_000, 3_000, 1_000, 500, 200),
    n_sparse_features=14,
    multi_hot=2,
    bottom_mlp=(256, 64, 16),
    top_mlp=(256, 64, 1),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--threaded", action="store_true")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = CFG_100M
    n_params = cfg.n_embedding_rows * cfg.embedding_dim
    print(f"DLRM with {n_params/1e6:.0f}M embedding params "
          f"({cfg.n_embedding_rows:,} rows), {cfg.n_sparse_features} features")
    sync_cfg = SyncConfig(algo="easgd", mode="shadow", gap=5, alpha=0.5)
    opt = optim.adagrad(0.02)

    t0 = time.perf_counter()
    if args.threaded:
        runner = ThreadedShadowRunner(cfg, sync_cfg, n_trainers=3, batch_size=128,
                                      optimizer=opt, sync_sleep_s=0.005)
        out = runner.run(args.iters)
        print(f"EPS (real wall clock) = {out['eps']:.0f}; "
              f"avg sync gap {out['avg_sync_gap']:.3f}; "
              f"losses {[round(l, 4) for l in out['train_loss']]}")
    else:
        sim = HogwildSim(cfg, sync_cfg, n_trainers=4, n_threads=2, batch_size=128,
                         optimizer=opt)
        out = sim.run(args.iters, log_every=50)
        ev = sim.evaluate(out["state"], n_batches=10, batch_size=4096)
        print(f"train {np.mean(out['train_loss'][:10]):.5f} -> "
              f"{np.mean(out['train_loss'][-10:]):.5f}; eval {ev:.5f}; "
              f"{args.iters} iters in {time.perf_counter()-t0:.0f}s")
        if args.save:
            st = out["state"]
            ckpt.save(args.save, {"w": st.w_stack, "emb": st.emb_state},
                      metadata={"step": st.step})
            print("checkpoint saved to", args.save)


if __name__ == "__main__":
    main()

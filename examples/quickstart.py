"""Quickstart: train a small DLRM on synthetic CTR data with Shadow-EASGD.

    PYTHONPATH=src python examples/quickstart.py

Runs the deterministic Hogwild simulator: 4 trainers x 2 Hogwild threads,
one-pass data, background EASGD sync — the whole paper in ~60 seconds on CPU.
"""
import numpy as np

from repro import optim
from repro.configs import dlrm_ctr
from repro.core.elp import elp
from repro.core.runners import HogwildSim
from repro.core.sync import SyncConfig

TRAINERS, THREADS, BATCH, ITERS = 4, 2, 128, 150


def main():
    cfg = dlrm_ctr.tiny()
    print(f"DLRM: {cfg.n_sparse_features} categorical features, "
          f"{cfg.n_embedding_rows:,} embedding rows, dim {cfg.embedding_dim}")
    print(f"ELP = {BATCH} batch x {THREADS} hogwild x {TRAINERS} trainers "
          f"= {elp(BATCH, THREADS, TRAINERS):,}")

    sim = HogwildSim(
        cfg,
        SyncConfig(algo="easgd", mode="shadow", gap=5, alpha=0.5),
        n_trainers=TRAINERS, n_threads=THREADS, batch_size=BATCH,
        optimizer=optim.adagrad(0.02),
    )
    out = sim.run(ITERS, log_every=25)
    ev = sim.evaluate(out["state"], n_batches=10, batch_size=4096)
    print(f"\ntrain loss: {np.mean(out['train_loss'][:10]):.5f} -> "
          f"{np.mean(out['train_loss'][-10:]):.5f}")
    print(f"eval loss (replica 0, paper protocol): {ev:.5f}")
    print(f"background syncs: {out['sync_count']} "
          f"(avg gap {out['avg_sync_gap']:.2f} iterations)")


if __name__ == "__main__":
    main()

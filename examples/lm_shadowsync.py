"""ShadowSync beyond the paper: replica-parallel LM training with a background
sync program — the multi-pod SPMD pattern, executed at laptop scale.

    PYTHONPATH=src python examples/lm_shadowsync.py --arch mamba2-780m

Two replicas of a reduced LM train on disjoint Markov streams with NO gradient
exchange; a separate jitted sync_step (Shadow-MA) reconciles them periodically,
exactly as the pod-level deployment would (see src/repro/core/spmd.py).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.core import algorithms, spmd
from repro.core.sync import SyncConfig
from repro.data import tokens as tok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="mamba2-780m")
    ap.add_argument("--algo", choices=list(algorithms.names()), default="ma")
    ap.add_argument("--iters", type=int, default=80)
    ap.add_argument("--gap", type=int, default=5)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    R = 2
    opt = optim.adam(2e-3)
    params = spmd.init_params(cfg, jax.random.PRNGKey(0))
    stack = jax.tree.map(jnp.copy, spmd.stack_replicas(params, R))
    opt_stack = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), opt.init(params))

    train_step = jax.jit(spmd.make_train_step(cfg, opt, "shadow"))
    sync_cfg = SyncConfig(algo=args.algo, alpha=0.5).validate()
    sync_step = jax.jit(spmd.make_sync_step(cfg, sync_cfg))
    algo_state = algorithms.get(args.algo).init_state(params, sync_cfg)

    trans = tok.make_transition(cfg.vocab_size, 0)
    losses = []
    for it in range(args.iters):
        b = tok.gen_batch(trans, 0, it, 8 * R, 64)
        if cfg.family == "audio":  # stubbed conv-frontend embeddings
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(it), (8 * R, cfg.encoder.n_ctx, cfg.d_model)) * 0.1
        if cfg.family == "vlm":  # stubbed vision-tower patch embeddings
            b["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(it), (8 * R, cfg.frontend.n_tokens, cfg.d_model)) * 0.1
        batch = jax.tree.map(lambda x: x.reshape(R, 8, *x.shape[1:]), b)
        stack, opt_stack, loss = train_step(stack, opt_stack, batch)
        losses.append(float(jnp.mean(loss)))
        if (it + 1) % args.gap == 0:
            stack, algo_state = sync_step(stack, algo_state)  # the background program
        if (it + 1) % 20 == 0:
            print(f"iter {it+1}: loss {np.mean(losses[-20:]):.4f}")
    print(f"\n{args.arch}: {np.mean(losses[:5]):.4f} -> {np.mean(losses[-5:]):.4f} "
          f"(2 replicas, Shadow-{args.algo.upper()}, "
          f"zero cross-replica traffic in train_step)")


if __name__ == "__main__":
    main()

"""Batched serving example: prefill a batch of prompts, decode with the cache.

    PYTHONPATH=src python examples/serve_batched.py --arch jamba-1.5-large-398b

Exercises the same prefill/decode_step pair the decode_32k and long_500k dry-run
shapes lower (reduced config, CPU execution).
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()

"""Compare every registered sync algorithm, shadow vs fixed-rate
(paper §4.2-4.3 scaled down). The sweep is driven by the algorithm
registry, so a newly registered algorithm shows up here for free.

    PYTHONPATH=src python examples/compare_sync_algorithms.py
"""
import numpy as np

from repro import optim
from repro.configs import dlrm_ctr
from repro.core import algorithms
from repro.core.runners import HogwildSim
from repro.core.sync import SyncConfig

CFG = dlrm_ctr.tiny()


def run(algo, mode, alpha=0.5):
    sim = HogwildSim(CFG, SyncConfig(algo=algo, mode=mode, gap=5, alpha=alpha),
                     n_trainers=4, n_threads=2, batch_size=128,
                     optimizer=optim.adagrad(0.02))
    out = sim.run(120)
    ev = sim.evaluate(out["state"], n_batches=8, batch_size=2048)
    return float(np.mean(out["train_loss"][-10:])), ev


def main():
    print(f"{'method':16s} {'train':>8s} {'eval':>8s}")
    for algo in algorithms.names():
        tr, ev = run(algo, "shadow")
        print(f"S-{algo.upper():14s} {tr:8.5f} {ev:8.5f}")
        tr, ev = run(algo, "fixed_rate")
        print(f"FR-{algo.upper():13s} {tr:8.5f} {ev:8.5f}")
    tr, ev = run("bmuf", "shadow", alpha=0.9)
    print(f"S-BMUF(a=0.9)    {tr:8.5f} {ev:8.5f}  <- larger elastic step (paper Fig 7)")


if __name__ == "__main__":
    main()

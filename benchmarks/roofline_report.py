"""Roofline report: renders results/dryrun_all.json (written by
`python -m repro.launch.dryrun --all --out results/dryrun_all.json`) as the
EXPERIMENTS.md §Roofline table. Falls back to a fast inline dry-run of two
representative pairs if the sweep output is missing."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_all.json")


def _fmt(t: float) -> str:
    return f"{t*1e3:10.1f}ms"


def render(rows: List[dict]) -> List[Tuple[str, float, str]]:
    out = []
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    single = [r for r in ok if r.get("mesh") == "16x16" and "t_compute" in r]
    multi = [r for r in ok if r.get("mesh") != "16x16"]
    print(f"\n== Roofline ({len(ok)} compiled: {len(single)} single-pod costed, "
          f"{len(multi)} multi-pod lowering-proofs; {len(skipped)} skipped-by-design) ==")
    print(f"  {'arch':22s} {'shape':12s} {'t_comp':>11s} {'t_mem':>11s} "
          f"{'t_coll':>11s}  bottleneck  useful")
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        print(f"  {r['arch']:22s} {r['shape']:12s} "
              f"{_fmt(r['t_compute'])} {_fmt(r['t_memory'])} {_fmt(r['t_collective'])}  "
              f"{r['bottleneck']:10s}  {r['useful_flops_ratio']:.2f}")
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    r["t_compute"] * 1e6,
                    f"bottleneck={r['bottleneck']};useful={r['useful_flops_ratio']:.2f}"))
    print(f"  (multi-pod 2x16x16: {len(multi)} combos lower+compile OK — the pod "
          f"axis shards; roofline terms are single-pod per §Roofline)")
    for r in multi:
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                    "compile-ok(multi-pod)"))
    for r in skipped:
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0, "skipped"))
    return out


def bench_roofline() -> List[Tuple[str, float, str]]:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return render(json.load(f))
    print("\n== Roofline: results/dryrun_all.json missing; run "
          "`python -m repro.launch.dryrun --all --out results/dryrun_all.json` ==")
    return [("roofline/missing", 0.0, "run dryrun --all first")]

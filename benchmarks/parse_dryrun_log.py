"""Fallback: reconstruct dryrun rows from the human-readable sweep log when the
JSON output is missing/partial (e.g. interrupted sweep).

    python benchmarks/parse_dryrun_log.py results/dryrun_all.log results/dryrun_all.json
"""
import json
import re
import sys

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HEAD = re.compile(r"^(OK|SKIP|FAIL)\s+(\S+) x (\S+) x (\S+) \[(\S+)\](?::\s*(.*))?")
ROOF = re.compile(r"t_comp=(-?[\d.]+)ms t_mem=(-?[\d.]+)ms t_coll=(-?[\d.]+)ms -> "
                  r"(\w+)-bound; useful_flops=(-?[\d.]+)")
MEM = re.compile(r"args=([\d.]+)GiB temp=([\d.]+)GiB out=([\d.]+)GiB")


def parse(path):
    rows, cur = [], None
    for line in open(path):
        m = HEAD.match(line)
        if m:
            status, arch, shape, mesh, mode = m.group(1, 2, 3, 4, 5)
            cur = {"arch": arch, "shape": shape, "mesh": mesh, "mode": mode,
                   "status": {"OK": "ok", "SKIP": "skipped", "FAIL": "fail"}[status],
                   "chips": 512 if mesh == "2x16x16" else 256}
            if status == "SKIP":
                cur["reason"] = (m.group(6) or "").strip()
            rows.append(cur)
            continue
        if cur is None:
            continue
        m = ROOF.search(line)
        if m:
            tc, tm, tl = (max(float(x), 0.0) / 1e3 for x in m.group(1, 2, 3))
            cur.update(
                t_compute=tc, t_memory=tm, t_collective=tl,
                bottleneck=m.group(4), useful_flops_ratio=float(m.group(5)),
                flops_per_chip=tc * PEAK_FLOPS_BF16,
                bytes_per_chip=tm * HBM_BW,
                collective_bytes_per_chip=tl * ICI_BW,
            )
        m = MEM.search(line)
        if m:
            gib = 2 ** 30
            cur.update(arg_bytes=int(float(m.group(1)) * gib),
                       temp_bytes=int(float(m.group(2)) * gib),
                       out_bytes=int(float(m.group(3)) * gib))
    return rows


def dedupe_last(rows):
    """Re-run rows append to the log; keep the LAST entry per combo."""
    by_key = {}
    for r in rows:
        by_key[(r["arch"], r["shape"], r["mesh"], r.get("mode"))] = r
    return list(by_key.values())


if __name__ == "__main__":
    rows = dedupe_last(parse(sys.argv[1]))
    with open(sys.argv[2], "w") as f:
        json.dump(rows, f, indent=1)
    print(f"parsed {len(rows)} rows -> {sys.argv[2]}")

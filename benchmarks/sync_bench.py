"""Per-algorithm sync cost: flat replica-space engine vs pytree path.

Algorithms are auto-discovered from the ``core.algorithms`` registry — a
newly registered algorithm gets a benchmark row (and a stream-ratio floor
check against its own ``min_stream_ratio``) without touching this file.

Two numbers per (algo, engine) at DLRM-CTR dense scale (DESIGN.md §3.3):

* wall time of one full background sync cycle (launch snapshot + landing),
  jitted oracle paths on CPU — the Pallas kernels themselves target TPU and
  interpret-mode timing is not meaningful, mirroring kernel_bench.py;
* the derived HBM stream count: analytic bytes moved per sync cycle under
  op-level accounting (each op in the chain reads its inputs and writes its
  outputs once; no cross-op fusion — that fusion is exactly what the flat
  engine's kernels provide). The model itself is algorithm metadata
  (``pytree_sync_bytes`` / ``flat_sync_bytes``).

`--json` writes BENCH_sync.json so the perf trajectory is recorded per PR.

  PYTHONPATH=src python -m benchmarks.sync_bench [--json]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from benchmarks._timing import time_call as _time
from repro.core import algorithms

R = 8  # trainers
ALPHA = 0.5

ALGOS = algorithms.names()

# Acceptance floors: flat must move at least this factor fewer bytes per
# sync. Owned by each algorithm (SyncAlgorithm.min_stream_ratio).
MIN_STREAM_RATIO = {name: algorithms.get(name).min_stream_ratio
                    for name in ALGOS}


# ---------------------------------------------------------------------------
# Analytic HBM-stream accounting (fp32 bytes per full sync cycle) —
# thin wrappers over the registry, kept for test/back-compat imports.
# ---------------------------------------------------------------------------

def pytree_sync_bytes(algo: str, r: int, n: int) -> int:
    return algorithms.get(algo).pytree_sync_bytes(r, n)


def flat_sync_bytes(algo: str, r: int, n: int, *, fired: Optional[int] = None) -> int:
    return algorithms.get(algo).flat_sync_bytes(r, n, fired=fired)


def stream_ratio(algo: str, r: int, n: int) -> float:
    return pytree_sync_bytes(algo, r, n) / flat_sync_bytes(algo, r, n)


# ---------------------------------------------------------------------------
# Wall-time measurement (jitted oracle paths, full-size DLRM dense replicas;
# timer shared with the other benches via benchmarks/_timing.py)
# ---------------------------------------------------------------------------

def bench_sync(json_path: Optional[str] = None) -> List[Tuple[str, float, str]]:
    from repro.configs import dlrm_ctr
    from repro.core import sync as S
    from repro.core.flatspace import LANE, FlatSpace
    from repro.models import dlrm

    cfg = dlrm_ctr.CONFIG  # paper-scale dense MLPs (~0.5M params/replica)
    w0 = dlrm.init_dense(cfg, jax.random.PRNGKey(0))
    fs = FlatSpace.from_tree(w0)
    n = fs.slots

    stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape) + 0.0, w0)
    buf = fs.broadcast(w0, R)
    plane = fs.pack(w0)

    # launch + landing, two jitted calls each — mirrors the runners
    snap_tree = jax.jit(lambda ws: jax.tree.map(jnp.copy, ws))

    print("\n== Background-sync cycle: flat engine vs pytree path "
          f"(R={R}, N={fs.total:,} params -> {n:,} slots) ==")
    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, Dict[str, float]] = {}
    for name in ALGOS:
        algo = algorithms.get(name)
        sc = S.SyncConfig(algo=name, alpha=ALPHA)

        # pytree cycle: deep-copy snapshot + jitted oracle landing
        state_py = algo.init_state(w0, sc)
        land_py = jax.jit(
            lambda ws, st_, snap, _a=algo, _sc=sc: _a.land(ws, st_, snap, None, _sc))
        us_py = _time(snap_tree, stack) + _time(land_py, stack, state_py, stack)

        # flat cycle: the algorithm's non-donating jitted oracle refs
        state_fl = algo.init_state_flat(plane, sc, fs)
        snap_fn, land_fn = algo.flat_ref_fns(sc, fs)
        us_fl = _time(snap_fn, buf)
        snap = snap_fn(buf)
        us_fl += _time(land_fn, buf, state_fl, snap)

        # Same N (padded slots) for both engines so the ratio compares like
        # units; the padding overhead itself is recorded in the JSON config.
        b_py = algo.pytree_sync_bytes(R, n)
        b_fl = algo.flat_sync_bytes(R, n)
        ratio = b_py / b_fl
        assert ratio >= algo.min_stream_ratio, (name, ratio)
        rows.append((f"sync/{name}_pytree", us_py, f"{b_py / 1e6:.1f} MB/sync"))
        rows.append((f"sync/{name}_flat", us_fl,
                     f"{b_fl / 1e6:.1f} MB/sync ({ratio:.2f}x fewer streams)"))
        results[name] = {
            "pytree_us": us_py, "flat_us": us_fl,
            "pytree_bytes": b_py, "flat_bytes": b_fl,
            "stream_ratio": ratio, "wall_speedup": us_py / max(us_fl, 1e-9),
            "snapshot_kind": algo.snapshot_kind,
            "centralized": algo.centralized,
        }
        print(f"  {name:6s}  pytree {us_py:9.1f} us  flat {us_fl:9.1f} us  "
              f"({us_py / max(us_fl, 1e-9):4.2f}x wall)   "
              f"streams {b_py / 1e6:7.1f} -> {b_fl / 1e6:7.1f} MB ({ratio:.2f}x fewer)")

    if json_path:
        payload = {
            "bench": "sync_bench",
            "config": {"R": R, "params_per_replica": fs.total,
                       "flat_slots": n, "padding_overhead": n / fs.total,
                       "alpha": ALPHA, "lane": LANE,
                       "algorithms": list(ALGOS)},
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_sync.json next to the cwd")
    args = ap.parse_args()
    rows = bench_sync(json_path="BENCH_sync.json" if args.json else None)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

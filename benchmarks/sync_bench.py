"""Per-algorithm sync cost: flat replica-space engine vs pytree path.

Two numbers per (algo, engine) at DLRM-CTR dense scale (DESIGN.md §3.3):

* wall time of one full background sync cycle (launch snapshot + landing),
  jitted oracle paths on CPU — the Pallas kernels themselves target TPU and
  interpret-mode timing is not meaningful, mirroring kernel_bench.py;
* the derived HBM stream count: analytic bytes moved per sync cycle under
  op-level accounting (each op in the chain reads its inputs and writes its
  outputs once; no cross-op fusion — that fusion is exactly what the flat
  engine's kernels provide).

`--json` writes BENCH_sync.json so the perf trajectory is recorded per PR.

  PYTHONPATH=src python -m benchmarks.sync_bench [--json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

R = 8  # trainers
ALGOS = ("easgd", "ma", "bmuf")
ALPHA = 0.5

# Acceptance floors: flat must move at least this factor fewer bytes per sync.
MIN_STREAM_RATIO = {"easgd": 1.5, "ma": 2.0, "bmuf": 2.0}


# ---------------------------------------------------------------------------
# Analytic HBM-stream accounting (fp32 bytes per full sync cycle)
# ---------------------------------------------------------------------------

def pytree_sync_bytes(algo: str, r: int, n: int, *, nesterov: bool = False) -> int:
    """Op-level accounting of core/sync.py per background sync cycle.

    N-sized ops: lerp/where read 2 inputs + write 1; mean reads the stack,
    writes a mean; broadcast materializes an R-wide operand for the lerp.
    Launch snapshot is a deep copy of the replica stack (read + write R*N).
    """
    rn = r * n
    if algo == "easgd":
        # copy(2RN) + per-replica scan: lerp_ps(3N) + lerp_wi(3N)
        # + masked keep_ps(3N) + keep_wi(3N)
        slots = 2 * rn + 12 * rn
    elif algo == "ma":
        # copy(2RN) + mean(RN+N) + broadcast(N+RN) + lerp(2RN+RN)
        slots = 2 * rn + (rn + n) + (n + rn) + 3 * rn
    elif algo == "bmuf":
        # MA chain + desc/velocity/w_global updates (r 2N + w N each)
        slots = 2 * rn + (rn + n) + (n + rn) + 3 * rn + 9 * n
        if nesterov:
            slots += 3 * n  # look-ahead op
    else:
        raise ValueError(algo)
    return 4 * slots


def flat_sync_bytes(algo: str, r: int, n: int, *, fired: Optional[int] = None) -> int:
    """Flat engine accounting: one contiguous launch snapshot + one fused
    kernel landing (kernels/{easgd,ma,bmuf}_update)."""
    rn = r * n
    f = r if fired is None else fired
    if algo == "easgd":
        # fired-rows gather(2FN) + round kernel: r(F*N stack + F*N snap + N ps)
        # + w(F*N stack + N ps); un-fired replicas cost nothing, at launch OR
        # landing.
        slots = 2 * f * n + (2 * f * n + n) + (f * n + n)
    elif algo == "ma":
        # launch mean(RN+N) + pull-back kernel(r RN+N, w RN)
        slots = (rn + n) + (2 * rn + n)
    elif algo == "bmuf":
        # launch mean(RN+N) + fused landing(r RN+3N, w RN+2N)
        slots = (rn + n) + (2 * rn + 5 * n)
    else:
        raise ValueError(algo)
    return 4 * slots


def stream_ratio(algo: str, r: int, n: int) -> float:
    return pytree_sync_bytes(algo, r, n) / flat_sync_bytes(algo, r, n)


# ---------------------------------------------------------------------------
# Wall-time measurement (jitted oracle paths, full-size DLRM dense replicas)
# ---------------------------------------------------------------------------

def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_sync(json_path: Optional[str] = None) -> List[Tuple[str, float, str]]:
    from repro.configs import dlrm_ctr
    from repro.core import sync as S
    from repro.core.flatspace import LANE, FlatSpace
    from repro.kernels.bmuf_update.ref import bmuf_update_ref
    from repro.kernels.easgd_update.ref import easgd_round_ref
    from repro.kernels.ma_update.ref import ma_update_ref, replica_mean_ref
    from repro.models import dlrm

    cfg = dlrm_ctr.CONFIG  # paper-scale dense MLPs (~0.5M params/replica)
    w0 = dlrm.init_dense(cfg, jax.random.PRNGKey(0))
    fs = FlatSpace.from_tree(w0)
    n = fs.slots

    stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape) + 0.0, w0)
    buf = fs.broadcast(w0, R)
    plane = fs.pack(w0)
    vel = jnp.zeros_like(plane)
    bmuf_state = S.BMUFState.init(w0)
    all_fired = tuple(range(R))

    # launch + landing, two jitted calls each — mirrors the runners
    snap_tree = jax.jit(lambda ws: jax.tree.map(jnp.copy, ws))
    pytree_land = {
        "easgd": jax.jit(lambda ws, snap: S.easgd_round(ws, w0, ALPHA, snapshot=snap)),
        "ma": jax.jit(lambda ws, snap: S.ma_round(ws, ALPHA, snapshot=snap)),
        "bmuf": jax.jit(lambda ws, snap: S.bmuf_round(ws, bmuf_state, ALPHA, snapshot=snap)),
    }
    fired_idx = jnp.arange(R, dtype=jnp.int32)
    snap_flat_gather = jax.jit(lambda b: b[fired_idx])  # easgd: fired rows only
    snap_flat_mean = jax.jit(replica_mean_ref)
    flat_land = {
        "easgd": jax.jit(lambda b, ps, snap: easgd_round_ref(b, ps, snap, all_fired, ALPHA)),
        "ma": jax.jit(lambda b, mean: ma_update_ref(b, mean, ALPHA)),
        "bmuf": jax.jit(lambda b, mean: bmuf_update_ref(b, mean, plane, vel, ALPHA)),
    }

    print("\n== Background-sync cycle: flat engine vs pytree path "
          f"(R={R}, N={fs.total:,} params -> {n:,} slots) ==")
    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, Dict[str, float]] = {}
    for algo in ALGOS:
        us_py = _time(snap_tree, stack) + _time(pytree_land[algo], stack, stack)

        us_fl = _time(snap_flat_gather if algo == "easgd" else snap_flat_mean, buf)
        if algo == "easgd":
            us_fl += _time(flat_land[algo], buf, plane, buf)
        else:
            mean = snap_flat_mean(buf)
            us_fl += _time(flat_land[algo], buf, mean)

        # Same N (padded slots) for both engines so the ratio compares like
        # units; the padding overhead itself is recorded in the JSON config.
        b_py = pytree_sync_bytes(algo, R, n)
        b_fl = flat_sync_bytes(algo, R, n)
        ratio = b_py / b_fl
        assert ratio >= MIN_STREAM_RATIO[algo], (algo, ratio)
        rows.append((f"sync/{algo}_pytree", us_py, f"{b_py / 1e6:.1f} MB/sync"))
        rows.append((f"sync/{algo}_flat", us_fl,
                     f"{b_fl / 1e6:.1f} MB/sync ({ratio:.2f}x fewer streams)"))
        results[algo] = {
            "pytree_us": us_py, "flat_us": us_fl,
            "pytree_bytes": b_py, "flat_bytes": b_fl,
            "stream_ratio": ratio, "wall_speedup": us_py / max(us_fl, 1e-9),
        }
        print(f"  {algo:6s}  pytree {us_py:9.1f} us  flat {us_fl:9.1f} us  "
              f"({us_py / max(us_fl, 1e-9):4.2f}x wall)   "
              f"streams {b_py / 1e6:7.1f} -> {b_fl / 1e6:7.1f} MB ({ratio:.2f}x fewer)")

    if json_path:
        payload = {
            "bench": "sync_bench",
            "config": {"R": R, "params_per_replica": fs.total,
                       "flat_slots": n, "padding_overhead": n / fs.total,
                       "alpha": ALPHA, "lane": LANE},
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_sync.json next to the cwd")
    args = ap.parse_args()
    rows = bench_sync(json_path="BENCH_sync.json" if args.json else None)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

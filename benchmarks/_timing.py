"""Shared wall-clock helper for the benchmark modules: compile once (first
call, blocked), then average ``iters`` blocked calls, in microseconds."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6

"""Benchmark driver: one function per paper table/figure + kernels + sync + roofline.

Prints human-readable tables followed by a ``name,us_per_call,derived`` CSV
(one row per benchmark entry).

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --only fig5 # one table/figure
  PYTHONPATH=src python -m benchmarks.run --only sync --json  # + BENCH_sync.json
  PYTHONPATH=src python -m benchmarks.run --only emb --json   # + BENCH_emb.json
  PYTHONPATH=src python -m benchmarks.run --only elastic --json  # + BENCH_elastic.json
  PYTHONPATH=src python -m benchmarks.run --only cache --json    # + BENCH_cache.json
  PYTHONPATH=src python -m benchmarks.run --only pipeline --json # + BENCH_pipeline.json
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter: table1|table2|fig5|fig6|fig7|fig8|kernel|sync|emb|elastic|cache|pipeline|roofline")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_sync.json / BENCH_emb.json / BENCH_elastic.json to the cwd")
    args = ap.parse_args()

    from benchmarks.cache_bench import bench_cache
    from benchmarks.elastic_bench import bench_elastic
    from benchmarks.pipeline_bench import bench_pipeline
    from benchmarks.emb_bench import bench_emb
    from benchmarks.kernel_bench import bench_kernels
    from benchmarks.paper_tables import (
        bench_fig5_scaling, bench_fig6_bmuf_ma, bench_fig7_shadow_algos,
        bench_fig8_hogwild, bench_table1_elp, bench_table2_quality,
    )
    from benchmarks.roofline_report import bench_roofline
    from benchmarks.sync_bench import bench_sync

    benches = [
        ("table1", bench_table1_elp),
        ("table2", bench_table2_quality),
        ("fig5", bench_fig5_scaling),
        ("fig6", bench_fig6_bmuf_ma),
        ("fig7", bench_fig7_shadow_algos),
        ("fig8", bench_fig8_hogwild),
        ("kernel", bench_kernels),
        ("sync", lambda: bench_sync(
            json_path="BENCH_sync.json" if args.json else None)),
        ("emb", lambda: bench_emb(
            json_path="BENCH_emb.json" if args.json else None)),
        ("elastic", lambda: bench_elastic(
            json_path="BENCH_elastic.json" if args.json else None)),
        ("cache", lambda: bench_cache(
            json_path="BENCH_cache.json" if args.json else None)),
        ("pipeline", lambda: bench_pipeline(
            json_path="BENCH_pipeline.json" if args.json else None)),
        ("roofline", bench_roofline),
    ]
    rows = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        rows.extend(fn())

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""NestPipe-style step pipelining: overlap, hazards, bitwise parity, speedup.

An embedding-bound DLRM step serializes three phases: assemble the batch's
pooled planes (host routing + hot-tier gathers), run the dense jit, land the
sparse update. DESIGN.md §13's ``StepPipeline`` double-buffers the lookup of
batch k+1 behind batch k's dense compute and update — admitted per shard by
a deterministic read-after-write hazard check over the peeked index stream,
so the pipelined trajectory is BITWISE-identical to the serial one.

Scenarios (wide-table stream: 4 x 50k-row tables, multi-hot 2 — consecutive
batches rarely collide, so the hazard check actually admits overlap):

* ``cached_depth2`` — the shipping configuration and the floored row
  (scripts/check_bench_floors.py): tiered-cache lookups staged one step
  ahead of the dense jit. Floors: step-throughput ratio vs ``depth1``
  >= 1.2, overlap rate >= 0.8, trajectory bitwise == serial. The stream is
  pure in (seed, iteration), so the overlap/hazard counts are exactly
  reproducible — only the wall-clock ratio varies run to run.
* ``uncached_depth2`` — contrast row, NO floor: without the cache the
  lookup is a single fused-jit dispatch, and staging it forces the split
  (non-donating) lookup/dense/update programs — the split overhead eats
  the overlap win. The row documents where pipelining does NOT pay: the
  overlap only buys back wall clock when the staged phase carries real
  host work (routing, hot-tier assembly), which is exactly the
  production-shaped cached path.
* ``worst_case`` — single-row tables: every batch reads the same rows, so
  every step hazards and the pipeline degenerates to counted
  serialization. Floored only on bitwise parity and overlap == 0 (the
  hazard check must refuse to overlap, not break exactness).

``--json`` writes BENCH_pipeline.json; ``--tiny`` shrinks the spans for the
CI smoke (the floored scenario keeps its span — overlap rate is a counted
property of the stream prefix, and the span is already ~1 s).

  PYTHONPATH=src python -m benchmarks.pipeline_bench [--json] [--tiny]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

TABLE_ROWS = 50_000
N_TABLES = 4
MULTI_HOT = 2
N_TRAINERS = 2
BATCH = 4
HOT_ROWS = 2048
LOOKAHEAD = 2
DEPTH = 2
WARMUP_ITERS = 6
MEASURE_ITERS = 40
SIM_SEED = 0

TINY = dict(warmup=3, measure=40, contrast=False)


def _mk_sim(cfg, pipeline, cache):
    from repro import optim
    from repro.core.runners import HogwildSim
    from repro.core.sync import SyncConfig

    return HogwildSim(
        cfg,
        SyncConfig(algo="easgd", mode="shadow", gap=5, engine="flat"),
        n_trainers=N_TRAINERS,
        n_threads=1,
        batch_size=BATCH,
        optimizer=optim.make("adagrad", 0.02),
        seed=SIM_SEED,
        cache=cache,
        pipeline=pipeline,
    )


def _timed_run(cfg, pipeline, cache, warm: int, meas: int):
    """Warm a fresh sim (tracing + cold tiers), then time a measured span."""
    sim = _mk_sim(cfg, pipeline, cache)
    st = sim.run(warm)["state"]
    t0 = time.perf_counter()
    out = sim.run(meas, state=st)
    wall = time.perf_counter() - t0
    return wall / meas * 1e3, out


def bench_pipeline(
    json_path: Optional[str] = None,
    tiny: bool = False,
) -> List[Tuple[str, float, str]]:
    import numpy as np

    from repro.configs import dlrm_ctr
    from repro.core.pipeline import PipelineConfig

    from repro.embeddings.cache import CacheConfig

    warm = TINY["warmup"] if tiny else WARMUP_ITERS
    meas = TINY["measure"] if tiny else MEASURE_ITERS
    contrast = True if not tiny else TINY["contrast"]

    cfg = dlrm_ctr.tiny()
    wide = dataclasses.replace(
        cfg, table_sizes=(TABLE_ROWS,) * N_TABLES,
        n_sparse_features=N_TABLES, multi_hot=MULTI_HOT)
    one = dataclasses.replace(cfg, table_sizes=(1,) * cfg.n_sparse_features)
    pipe_cfg = PipelineConfig(depth=DEPTH)

    print(
        f"\n== Step pipelining: {N_TABLES} x {TABLE_ROWS} rows, multi-hot "
        f"{MULTI_HOT}, {N_TRAINERS} trainers x batch {BATCH}, depth {DEPTH}, "
        f"{warm}+{meas} iters ==",
    )

    def bitwise(a, b) -> bool:
        ea, eb = a["state"].emb_state, b["state"].emb_state
        return bool(
            a["train_loss"] == b["train_loss"]
            and (np.asarray(ea["table"]) == np.asarray(eb["table"])).all()
            and (np.asarray(ea["acc"]) == np.asarray(eb["acc"])).all()
        )

    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, object] = {}

    # floored scenario: tiered-cache lookups staged behind the dense jit
    cache = CacheConfig(hot_rows=HOT_ROWS, lookahead=LOOKAHEAD)
    ms1, out1 = _timed_run(wide, None, cache, warm, meas)
    ms2, out2 = _timed_run(wide, pipe_cfg, cache, warm, meas)
    ps = out2["pipeline_stats"]
    eq = bitwise(out1, out2)
    ratio = ms1 / ms2
    results["cached_depth1"] = {"ms_per_step": ms1}
    results["cached_depth2"] = {
        "ms_per_step": ms2,
        "speedup_vs_depth1": ratio,
        "overlap_rate": ps["overlap_rate"],
        "trajectory_bitwise": eq,
        "pipeline_stats": ps,
        "staged_lookups": out2["cache_stats"]["staged_lookups"],
    }
    rows.append((
        "pipeline/cached_depth2", ms2 * 1e3,
        f"speedup {ratio:.2f}x overlap {ps['overlap_rate']:.3f} bitwise {eq}",
    ))
    print(
        f"  cached: depth1 {ms1:.2f} ms/step -> depth2 {ms2:.2f} ms/step "
        f"({ratio:.2f}x)  overlap {ps['overlap_rate']:.3f}  "
        f"hazards {ps['hazard_serialized']}  staged_lookups "
        f"{out2['cache_stats']['staged_lookups']}  bitwise {eq}",
    )

    # contrast row (no floor): the uncached lookup is one fused dispatch —
    # staging it splits the jit and the split costs more than overlap wins
    if contrast:
        ms1u, out1u = _timed_run(wide, None, None, warm, meas)
        ms2u, out2u = _timed_run(wide, pipe_cfg, None, warm, meas)
        psu = out2u["pipeline_stats"]
        equ = bitwise(out1u, out2u)
        results["uncached_depth1"] = {"ms_per_step": ms1u}
        results["uncached_depth2"] = {
            "ms_per_step": ms2u,
            "speedup_vs_depth1": ms1u / ms2u,
            "overlap_rate": psu["overlap_rate"],
            "trajectory_bitwise": equ,
            "pipeline_stats": psu,
        }
        rows.append((
            "pipeline/uncached_depth2", ms2u * 1e3,
            f"speedup {ms1u / ms2u:.2f}x overlap {psu['overlap_rate']:.3f} "
            f"bitwise {equ}",
        ))
        print(
            f"  uncached (contrast, no floor): depth1 {ms1u:.2f} -> depth2 "
            f"{ms2u:.2f} ms/step ({ms1u / ms2u:.2f}x)  overlap "
            f"{psu['overlap_rate']:.3f}  bitwise {equ}",
        )

    # worst case: all-identical indices — every step hazards, pure serial
    wc_meas = min(meas, 8)
    _, outw1 = _timed_run(one, None, None, 2, wc_meas)
    _, outw2 = _timed_run(one, pipe_cfg, None, 2, wc_meas)
    psw = outw2["pipeline_stats"]
    eqw = bitwise(outw1, outw2)
    results["worst_case"] = {
        "overlap_rate": psw["overlap_rate"],
        "hazard_serialized": psw["hazard_serialized"],
        "trajectory_bitwise": eqw,
    }
    rows.append((
        "pipeline/worst_case", 0.0,
        f"overlap {psw['overlap_rate']:.3f} hazards "
        f"{psw['hazard_serialized']} bitwise {eqw}",
    ))
    print(
        f"  worst case (single-row tables): overlap {psw['overlap_rate']:.3f}"
        f"  hazards {psw['hazard_serialized']}  bitwise {eqw}",
    )

    if json_path:
        payload = {
            "bench": "pipeline_bench",
            "config": {
                "table_rows": TABLE_ROWS,
                "n_tables": N_TABLES,
                "multi_hot": MULTI_HOT,
                "n_trainers": N_TRAINERS,
                "batch": BATCH,
                "hot_rows": HOT_ROWS,
                "lookahead": LOOKAHEAD,
                "depth": DEPTH,
                "warmup_iters": warm,
                "measure_iters": meas,
                "seed": SIM_SEED,
                "tiny": tiny,
            },
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true", help="write BENCH_pipeline.json to the cwd")
    ap.add_argument("--tiny", action="store_true", help="smoke-test spans (CI)")
    args = ap.parse_args()
    rows = bench_pipeline(json_path="BENCH_pipeline.json" if args.json else None, tiny=args.tiny)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""Calibrated fluid throughput model of the paper's training cluster.

This container has one CPU core, so multi-host EPS cannot be *measured*; it is
*modeled* from the paper's own system constants (§4: 25 Gbit Ethernet, 24 worker
threads, sync PSs) and validated against the paper's reported behaviours:

  * FR-EASGD-5 with 2 sync PSs plateaus at ~14 trainers (Fig 5 panel 1);
  * 4 sync PSs removes the plateau (Fig 5 panel 4);
  * FR-EASGD-30 and every ShadowSync variant scale linearly to 20 trainers;
  * S-EASGD's average sync gap grows with the trainer count
    (8.60 ... 12.48 for 15-20 trainers, §4.1.2).

Model:
  Training: each trainer processes EPS_0 examples/s when unimpeded.
  Sync traffic: one EASGD exchange moves 2|w| bytes through a sync PS.
  FR (foreground): every worker THREAD syncs every k iterations, inside the
    training loop => per-example sync demand = 2|w| / (k * batch); training
    throughput is capped by PS bandwidth C = n_ps * 25Gbit/8, and each sync
    adds its transfer latency to the iteration critical path.
  Shadow (background): one shadow thread per trainer syncs continuously;
    training never blocks => EPS = n * EPS_0 always; the PS bandwidth instead
    determines the achievable sync RATE, i.e. the average sync gap grows.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Paper system constants.
ETH_BPS = 25e9 / 8.0  # 25 Gbit Ethernet -> bytes/s per sync PS
THREADS = 24
BATCH = 200

# Calibration: FR-EASGD-5 with 2 sync PSs saturates at ~14 trainers (Fig 5).
# n_sat = C * k * B / (2|w| * EPS_0)  =>  |w| = C*k*B / (2 * n_sat * EPS_0)
EPS_0 = 40_000.0  # per-trainer examples/s (24 threads x batch 200)
_N_SAT, _K_CAL, _NPS_CAL = 14.0, 5.0, 2.0
W_BYTES = (_NPS_CAL * ETH_BPS) * _K_CAL * BATCH / (2.0 * _N_SAT * EPS_0)


@dataclass(frozen=True)
class ClusterModel:
    eps_0: float = EPS_0
    w_bytes: float = W_BYTES
    batch: int = BATCH
    threads: int = THREADS

    def ps_bandwidth(self, n_sync_ps: int) -> float:
        return n_sync_ps * ETH_BPS

    # -- foreground (FR) ----------------------------------------------------
    def fr_eps(self, n_trainers: int, sync_gap: int, n_sync_ps: int) -> float:
        c = self.ps_bandwidth(n_sync_ps)
        # latency term: every k-th iteration stalls for its own 2|w| transfer
        t_iter = self.batch / (self.eps_0 / self.threads)  # per-thread seconds/iter
        t_sync = 2.0 * self.w_bytes / ETH_BPS
        slowdown = t_iter / (t_iter + t_sync / sync_gap)
        linear = n_trainers * self.eps_0 * slowdown
        # bandwidth cap: offered sync load may not exceed PS capacity
        # (every example implies 2|w| / (k * batch) bytes of foreground sync)
        cap = c * sync_gap * self.batch / (2.0 * self.w_bytes)
        return min(linear, cap)

    # -- background (ShadowSync) ---------------------------------------------
    def shadow_eps(self, n_trainers: int) -> float:
        return n_trainers * self.eps_0  # sync is never on the critical path

    def shadow_avg_sync_gap(self, n_trainers: int, n_sync_ps: int) -> float:
        """Iterations a trainer completes between its own background syncs:
        the PS round-robins 2|w|-byte exchanges across n trainers."""
        c = self.ps_bandwidth(n_sync_ps)
        cycle = 2.0 * self.w_bytes * n_trainers / c  # seconds per full round
        iter_rate = self.eps_0 / self.batch  # trainer iterations/s (all threads)
        return max(cycle * iter_rate, 1.0)

    # -- decentralized (MA/BMUF): AllReduce among trainers, no sync PS -------
    def allreduce_eps(self, n_trainers: int, sync_gap: int, foreground: bool) -> float:
        if not foreground:
            return n_trainers * self.eps_0
        # ring all-reduce time grows mildly with n; blocking every k iters
        t_ar = 2.0 * self.w_bytes / ETH_BPS * (n_trainers - 1) / max(n_trainers, 1)
        t_iter = self.batch / (self.eps_0 / self.threads)
        slowdown = t_iter / (t_iter + t_ar / sync_gap)
        return n_trainers * self.eps_0 * slowdown

    # -- Hogwild thread scaling (Fig 8): memory-bandwidth saturation ----------
    def hogwild_eps(self, n_threads: int, n_trainers: int = 1) -> float:
        """12 threads ~ 50% membw, 24 ~ 70% (some trainers 89%), >=24 flat."""
        per_thread = self.eps_0 / self.threads
        # membw ceiling ~ 20 thread-equivalents; ~60% utilized at 12 threads,
        # ~87% at 24 (paper: 50% / 70-89%), asymptotically flat.
        effective = min(float(n_threads), 20.0 * (1.0 - np.exp(-n_threads / 12.0)))
        return n_trainers * per_thread * effective

"""Kernel micro-benchmarks.

Reference paths are timed as jitted XLA on the host. For the sync kernels the
Pallas launches target TPU and interpret-mode timing is not meaningful, so
`derived` records the analytic HBM-traffic saving instead. The embedding-bag
row additionally times the REAL Pallas op and labels it with how it actually
ran (`[compiled]` on TPU, `[interpret]` elsewhere) — no kernel-labeled row is
secretly a reference timing."""
from __future__ import annotations

from typing import List, Tuple

import jax

from benchmarks._timing import time_call as _time


def bench_kernels() -> List[Tuple[str, float, str]]:
    print("\n== Kernel reference-path microbench (CPU oracle timings) ==")
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.backend import on_tpu
    from repro.kernels.embedding_bag.ops import embedding_bag_op
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    table = jax.random.normal(key, (100_000, 64))
    idx = jax.random.randint(key, (4096, 4), 0, 100_000)
    us = _time(jax.jit(embedding_bag_ref), table, idx)
    rows.append(("kernel/embedding_bag_ref", us, "jitted XLA take+sum oracle"))
    print(f"  embedding_bag ref  {us:10.1f} us/call (4096 bags x 4-hot, d=64)")

    # The actual Pallas op, labeled by how it really ran: compiled row-stream
    # kernel on TPU, bag-blocked kernel through the interpreter elsewhere.
    mode = "compiled" if on_tpu() else "interpret"
    us = _time(lambda t, i: embedding_bag_op(t, i), table, idx)
    rows.append((f"kernel/embedding_bag_pallas[{mode}]", us,
                 "fused lookup+pool, one launch"))
    print(f"  embedding_bag op   {us:10.1f} us/call ({mode}; same shape)")

    from repro.kernels.easgd_update.ref import easgd_update_ref

    a = jax.random.normal(key, (8192, 128))
    b = jax.random.normal(jax.random.fold_in(key, 1), (8192, 128))
    us = _time(jax.jit(lambda x, y: easgd_update_ref(x, y, 0.5)), a, b)
    rows.append(("kernel/easgd_update_ref", us, "tpu fused: 4 HBM streams vs 6 unfused"))
    print(f"  easgd_update ref   {us:10.1f} us/call (1M params)")

    from repro.kernels.flash_attention.ref import attention_ref

    q = jax.random.normal(key, (8, 512, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (8, 512, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (8, 512, 64))
    us = _time(jax.jit(attention_ref), q, k, v)
    rows.append(("kernel/flash_attention_ref", us, "tpu: O(S) VMEM vs O(S^2) scores"))
    print(f"  attention ref      {us:10.1f} us/call (8 heads x 512 x 64)")
    return rows

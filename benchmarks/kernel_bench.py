"""Kernel micro-benchmarks: wall time of the jitted reference paths on CPU (the
Pallas kernels themselves target TPU; interpret-mode timing is not meaningful,
so `derived` records the kernel's analytic HBM-traffic saving instead)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> List[Tuple[str, float, str]]:
    print("\n== Kernel reference-path microbench (CPU oracle timings) ==")
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    table = jax.random.normal(key, (100_000, 64))
    idx = jax.random.randint(key, (4096, 4), 0, 100_000)
    us = _time(jax.jit(embedding_bag_ref), table, idx)
    rows.append(("kernel/embedding_bag_ref", us, "tpu: 1 row-stream pass, VMEM pool"))
    print(f"  embedding_bag ref  {us:10.1f} us/call (4096 bags x 4-hot, d=64)")

    from repro.kernels.easgd_update.ref import easgd_update_ref

    a = jax.random.normal(key, (8192, 128))
    b = jax.random.normal(jax.random.fold_in(key, 1), (8192, 128))
    us = _time(jax.jit(lambda x, y: easgd_update_ref(x, y, 0.5)), a, b)
    rows.append(("kernel/easgd_update_ref", us, "tpu fused: 4 HBM streams vs 6 unfused"))
    print(f"  easgd_update ref   {us:10.1f} us/call (1M params)")

    from repro.kernels.flash_attention.ref import attention_ref

    q = jax.random.normal(key, (8, 512, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (8, 512, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (8, 512, 64))
    us = _time(jax.jit(attention_ref), q, k, v)
    rows.append(("kernel/flash_attention_ref", us, "tpu: O(S) VMEM vs O(S^2) scores"))
    print(f"  attention ref      {us:10.1f} us/call (8 heads x 512 x 64)")
    return rows

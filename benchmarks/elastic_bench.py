"""EPS under faults: background ShadowSync vs foreground fixed-rate sync.

The paper's Fig-5 contrast, restated as fault tolerance (paper §1, §3.3 and
DESIGN.md §8.4): with synchronization decoupled from training, a degraded or
dead trainer cannot block the others — the shadow thread just skips dead
slots and the survivors keep their pace. Foreground fixed-rate sync is the
baseline failure mode: every trainer blocks at the sync point, so one
straggler drags the whole cohort to its speed and a crash only "helps"
because the barrier shrinks.

Three scenarios per mode on the real-thread runner (tiny DLRM, R=3):

* ``no_fault``   — healthy cohort (the reference pace).
* ``straggler``  — trainer R-1 sleeps an extra ``STRAGGLER_SLEEP_S`` per
  iteration (a degraded host; NestPipe's observation that at scale SOME
  worker is always degraded).
* ``crash``      — trainer R-1 dies a third of the way in; the run must
  complete and the survivors' windowed EPS should hold.

Per scenario we record total EPS, the trailing-window EPS (the survivors'
pace after a crash — ``EPSMeter``), per-trainer EPS, and wall time.

`--json` writes BENCH_elastic.json so the elasticity trajectory is recorded
per PR; `--tiny` shrinks iterations for the CI smoke.

  PYTHONPATH=src python -m benchmarks.elastic_bench [--json] [--tiny]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

R = 3  # trainers (slot R-1 takes the fault)
ALGO = "easgd"
GAP = 3
STRAGGLER_SLEEP_S = 0.03
BATCH = 64


def _scenarios(iters: int):
    from repro.core.membership import FaultSpec

    return {
        "no_fault": None,
        "straggler": FaultSpec(straggler_sleep_s={R - 1: STRAGGLER_SLEEP_S}),
        "crash": FaultSpec(crash_at={R - 1: max(iters // 3, 1)}),
    }


def bench_elastic(json_path: Optional[str] = None,
                  tiny: bool = False) -> List[Tuple[str, float, str]]:
    import jax

    from repro import optim
    from repro.configs import dlrm_ctr
    from repro.core.runners import ThreadedShadowRunner
    from repro.core.sync import SyncConfig

    cfg = dlrm_ctr.tiny()
    iters = 8 if tiny else 40
    print(f"\n== Elastic EPS: shadow vs fixed_rate under faults "
          f"(R={R}, {iters} iters/trainer, algo={ALGO}, "
          f"straggler +{STRAGGLER_SLEEP_S * 1e3:.0f} ms/iter) ==")
    # warm the jit caches so the first measured scenario does not pay
    # compilation (both modes compile distinct programs)
    for mode in ("shadow", "fixed_rate"):
        ThreadedShadowRunner(
            cfg, SyncConfig(algo=ALGO, mode=mode, gap=GAP, alpha=0.5),
            n_trainers=R, batch_size=BATCH, optimizer=optim.adagrad(0.02),
            sync_sleep_s=0.01).run(2)
    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, Dict[str, Dict[str, float]]] = {}
    for mode in ("shadow", "fixed_rate"):
        results[mode] = {}
        for name, fault in _scenarios(iters).items():
            runner = ThreadedShadowRunner(
                cfg, SyncConfig(algo=ALGO, mode=mode, gap=GAP, alpha=0.5),
                n_trainers=R, batch_size=BATCH, optimizer=optim.adagrad(0.02),
                sync_sleep_s=0.01, fault_spec=fault, eps_window_s=2.0)
            out = runner.run(iters)
            crashed = set((fault.crash_at if fault else {}).keys())
            survivors = [out["per_trainer_eps"][i]
                         for i in range(R) if i not in crashed]
            surv_eps = sum(survivors) / max(len(survivors), 1)
            res = {
                "eps": out["eps"],
                "eps_window": out["eps_window"],
                "survivor_eps": surv_eps,
                "per_trainer_eps": out["per_trainer_eps"],
                "wall_s": out["wall_s"],
                "sync_count": out["sync_count"],
                "iter_count": out["iter_count"],
            }
            results[mode][name] = res
            rows.append((f"elastic/{mode}_{name}", out["wall_s"] * 1e6,
                         f"{out['eps']:.0f} EPS "
                         f"(survivors {surv_eps:.0f}/trainer)"))
            print(f"  {mode:10s} {name:9s}  EPS {out['eps']:7.0f}  "
                  f"window {out['eps_window']:7.0f}  "
                  f"survivor/trainer {surv_eps:7.0f}  "
                  f"wall {out['wall_s']:5.2f}s  syncs {out['sync_count']}")

    sh, fr = results["shadow"], results["fixed_rate"]
    if fr["straggler"]["survivor_eps"] > 0:
        print(f"  straggler contrast: shadow survivors keep "
              f"{sh['straggler']['survivor_eps'] / max(sh['no_fault']['survivor_eps'], 1e-9):.0%}"
              f" of no-fault pace; fixed_rate holds everyone to "
              f"{fr['straggler']['survivor_eps'] / max(fr['no_fault']['survivor_eps'], 1e-9):.0%}")

    if json_path:
        payload = {
            "bench": "elastic_bench",
            "config": {"R": R, "iters_per_trainer": iters, "algo": ALGO,
                       "gap": GAP, "batch_size": BATCH,
                       "straggler_sleep_s": STRAGGLER_SLEEP_S,
                       "crash_at": max(iters // 3, 1), "tiny": tiny},
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_elastic.json to the cwd")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test iteration count (CI)")
    args = ap.parse_args()
    rows = bench_elastic(json_path="BENCH_elastic.json" if args.json else None,
                         tiny=args.tiny)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

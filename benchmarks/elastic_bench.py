"""EPS under faults: background ShadowSync vs foreground fixed-rate sync.

The paper's Fig-5 contrast, restated as fault tolerance (paper §1, §3.3 and
DESIGN.md §8.4): with synchronization decoupled from training, a degraded or
dead trainer cannot block the others — the shadow thread just skips dead
slots and the survivors keep their pace. Foreground fixed-rate sync is the
baseline failure mode: every trainer blocks at the sync point, so one
straggler drags the whole cohort to its speed and a crash only "helps"
because the barrier shrinks.

Four scenarios per mode on the real-thread runner (tiny DLRM, R=3):

* ``no_fault``       — healthy cohort (the reference pace).
* ``straggler``      — trainer R-1 sleeps an extra ``STRAGGLER_SLEEP_S`` per
  iteration for the WHOLE run (a degraded host; NestPipe's observation that
  at scale some worker is always degraded). Controller off: in fixed_rate
  mode the whole cohort is dragged to the straggler's pace.
* ``crash``          — trainer R-1 dies a third of the way in; the run must
  complete and the survivors' windowed EPS should hold.
* ``straggler_auto`` — the SAME degradation, but transient
  (``straggler_until``) and with the closed-loop controller on
  (core/scheduler.py, DESIGN.md §9): per-slot busy-clock EPS meters feed a
  ``StragglerPolicy`` that demotes the straggler out of the sync set (and
  the fixed_rate barrier) once its pace stays below the floor for a full
  window, then re-admits it through the ordinary join bootstrap after the
  degradation ends. The healthy cohort's pace recovers toward the no-fault
  reference — the number CI floors on (scripts/check_bench_floors.py).

``straggler_auto`` self-calibrates its iteration count from the measured
no-fault pace (``AUTO_SPAN_S`` seconds of healthy work), so the controller's
fixed detection latency (meter warm-up + policy window) is small relative to
the run on fast and slow boxes alike — the retention floor means the same
thing everywhere.

Two chaos scenarios exercise the failure domains PR 6 added (DESIGN.md §10),
both at the SAME calibrated span as ``no_fault_ref`` so the retention and
parity denominators are apples-to-apples:

* ``sync_crash`` (shadow mode only) — the shadow/sync thread itself dies
  mid-run. The supervisor must detect the death, restart the thread against
  live membership within the committed recovery deadline, and sync_count
  must STRICTLY increase post-restart (the CI floor: a silently dead sync
  engine is indistinguishable from unsynchronized Hogwild without it).
* ``ps_fail`` — embedding PS 0 fails a quarter of the way in (live state
  lost), serves bounded-staleness snapshot reads and drops retried writes
  while down, then rehydrates from the latest background snapshot after
  ``PS_RECOVER_S``. Floors: recovery observed, healthy throughput retained,
  and final-state parity vs the span-matched no-fault oracle. Parity is
  floored on ``emb_progress_ratio`` — the Adagrad accumulator mass ratio —
  because acc is a monotone, near-deterministic meter of landed updates
  (same batches every run => run-to-run ratio ~1.03), so a shard quietly
  serving its quarter-way snapshot forever shows up as ~0.8 where the raw
  table's Frobenius rel err cannot separate it from ordinary Hogwild
  interleaving noise (~0.35 for BOTH cases, measured); ``emb_rel_err`` is
  kept as a loose sanity ceiling against outright divergence/NaN.

One closed-loop scenario exercises the runtime mode controller
(core/modeswitch.py, DESIGN.md §14), outside the per-mode loop because it
OWNS its mode:

* ``mode_switch`` — start in ``fixed_rate`` with the same transient
  straggler as ``straggler_auto`` and the ``ModeController`` on: busy-EPS
  dispersion blows past ``skew_high`` while the barrier drags the cohort,
  so the controller hands the whole cohort to shadow (barrier drained,
  shadow clocks seeded from the last global sync); once the straggler
  recovers and dispersion falls through ``skew_low``, it runs the GBA-style
  catch-up sync and re-arms the barrier. Floors: the full
  fixed_rate->shadow->fixed_rate cycle happens, the first switch lands
  inside ``TO_SHADOW_MAX_S``, healthy throughput retains the static-shadow
  floor, and a scripted ``HogwildSim`` replay of the same controller is
  bit-identical across two fresh runs (closed-loop, still deterministic).

Per scenario we record total EPS, the trailing-window EPS, per-trainer EPS
(wall and busy-clock), healthy-cohort EPS (faulted slot excluded) and its
retention, wall time, and — for ``straggler_auto`` — the membership event
log with demotion provenance and wall latencies. Retentions are computed
against ``no_fault_ref`` — a no-fault run at the SAME calibrated span — so
the denominator is never a sub-second sample whose scheduler noise could
flip a CI floor.

`--json` writes BENCH_elastic.json so the elasticity trajectory is recorded
per PR; `--tiny` shrinks the legacy scenarios for the CI smoke (the
closed-loop scenario keeps its calibrated length — the controller needs
real wall time).

  PYTHONPATH=src python -m benchmarks.elastic_bench [--json] [--tiny]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

R = 3  # trainers (slot R-1 takes the fault)
ALGO = "easgd"
GAP = 3
BATCH = 64
# Sleep-dominated degradation: the straggler's pace must be visibly below
# the cohort's even on a slow, loaded CI box where per-iteration compute is
# large (compute-bound degradation blurs the contrast).
STRAGGLER_SLEEP_S = 0.25

# Closed-loop profile (straggler_auto).
AUTO_SPAN_S = 10.0       # target seconds of healthy work (calibrates iters)
AUTO_ITERS_MIN, AUTO_ITERS_MAX = 40, 1000
AUTO_UNTIL = 8           # straggler sleeps for its first 8 local iterations
AUTO_EPS_WINDOW_S = 0.5  # per-slot busy-clock meter window
AUTO_POLICY = dict(eps_floor_frac=0.5, readmit_frac=0.75,
                   window_s=0.25, probation_s=0.3, min_active=2)

# Chaos profile (sync_crash / ps_fail — DESIGN.md §10).
SYNC_CRASH_ROUND = 2   # shadow round at which the sync thread dies
PS_RECOVER_S = 0.3     # provisioning delay before the failed PS rehydrates
CHAOS_SUP = dict(heartbeat_deadline_s=1.0, check_interval_s=0.01,
                 backoff_s=0.05, backoff_factor=2.0, max_restarts=3)

# Closed-loop mode switching (mode_switch — DESIGN.md §14). Snappy profile:
# the bench needs both switches inside a ~10 s run, so breach persistence
# and dwell are fractions of a second rather than the conservative library
# defaults. skew_high 2.0 trips on the sleeping straggler's busy-EPS
# collapse; skew_low 1.4 re-arms the barrier once the cohort's spread is
# back near homogeneous.
MODE_SWITCH = dict(skew_high=2.0, skew_low=1.4, window_s=0.15,
                   min_dwell_s=0.4)
MODE_EPS_WINDOW_S = 0.4  # per-slot busy-clock meter window for dispersion
TO_SHADOW_MAX_S = 2.5    # CI floor: detection + handoff wall bound


def _fault_scenarios(iters: int):
    from repro.core.membership import FaultSpec

    return {
        "no_fault": (iters, None, False),
        "straggler": (iters, FaultSpec(
            straggler_sleep_s={R - 1: STRAGGLER_SLEEP_S}), False),
        "crash": (iters, FaultSpec(
            crash_at={R - 1: max(iters // 3, 1)}), False),
    }


def _healthy_eps(out, fault) -> float:
    """Mean per-trainer wall EPS over the slots the fault spec leaves
    untouched — the cohort pace the sync mode is responsible for."""
    faulted = set()
    if fault is not None:
        faulted = (set(fault.crash_at) | set(fault.straggler_sleep_s)
                   | set(fault.join_at))
    healthy = [out["per_trainer_eps"][i] for i in range(R) if i not in faulted]
    return sum(healthy) / max(len(healthy), 1)


def _sim_mode_replay(cfg) -> Dict[str, object]:
    """Deterministic half of the mode_switch contract: run the closed-loop
    controller inside ``HogwildSim`` twice from scratch (fresh controller,
    fresh schedule, fresh sim) over the same scripted rate trace and demand
    bit-identical losses and mode events. The scripted trace mirrors the
    threaded scenario on the iteration clock: slot R-1 runs at a tenth of
    cohort pace for iterations [5, 15), healthy otherwise."""
    from repro import optim
    from repro.core.modeswitch import (ControllerModeSchedule, ModeConfig,
                                       ModeController)
    from repro.core.runners import HogwildSim
    from repro.core.sync import SyncConfig

    def rates(t: int, slot: int) -> float:
        return 0.1 if (slot == R - 1 and 5 <= t < 15) else 1.0

    def run_once():
        ctl = ModeController(ModeConfig(
            skew_high=2.0, skew_low=1.3, window_s=2.0, min_dwell_s=3.0,
            start_mode="fixed_rate"))
        msched = ControllerModeSchedule(ctl, rates, n_slots=R)
        sim = HogwildSim(
            cfg, SyncConfig(algo=ALGO, mode="fixed_rate", gap=GAP, alpha=0.5),
            n_trainers=R, n_threads=2, batch_size=8,
            optimizer=optim.adagrad(0.02), seed=0, mode_schedule=msched)
        return sim.run(30)

    a, b = run_once(), run_once()
    return {
        "mode_events": [list(e) for e in a["mode_events"]],
        "final_mode": a["mode"],
        "trajectory_reproducible": bool(
            a["mode_events"] == b["mode_events"]
            and a["train_loss"] == b["train_loss"]),
    }


def bench_elastic(json_path: Optional[str] = None,
                  tiny: bool = False) -> List[Tuple[str, float, str]]:
    from repro import optim
    from repro.configs import dlrm_ctr
    from repro.core.membership import FaultSpec
    from repro.core.runners import ThreadedShadowRunner
    from repro.core.scheduler import PolicyConfig, StragglerPolicy
    from repro.core.supervision import SupervisorConfig
    from repro.core.sync import SyncConfig

    import numpy as np

    cfg = dlrm_ctr.tiny()
    iters = 24 if tiny else 40
    print(f"\n== Elastic EPS: shadow vs fixed_rate under faults "
          f"(R={R}, {iters} iters/trainer, algo={ALGO}, "
          f"straggler +{STRAGGLER_SLEEP_S * 1e3:.0f} ms/iter) ==")

    def make_runner(mode, fault=None, policy=None, eps_window_s=2.0,
                    mode_controller=None):
        # chaos scenarios get the snappy supervisor profile; everything else
        # keeps the default (supervision on, but never exercised)
        chaos = fault is not None and (fault.sync_crash_at is not None
                                       or bool(fault.ps_fail_at))
        sup_cfg = SupervisorConfig(**CHAOS_SUP) if chaos else None
        return ThreadedShadowRunner(
            cfg, SyncConfig(algo=ALGO, mode=mode, gap=GAP, alpha=0.5),
            n_trainers=R, batch_size=BATCH, optimizer=optim.adagrad(0.02),
            sync_sleep_s=0.01, fault_spec=fault, eps_window_s=eps_window_s,
            straggler_policy=policy, supervisor_config=sup_cfg,
            mode_controller=mode_controller)

    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, Dict[str, Dict[str, object]]] = {}
    auto_iters = {}
    for mode in ("shadow", "fixed_rate"):
        results[mode] = {}
        legacy = _fault_scenarios(iters)
        # span-calibrated runs, AFTER no_fault (see module doc):
        # no_fault_ref anchors every retention denominator over ~AUTO_SPAN_S
        # seconds of work (a sub-second no_fault sample's scheduler noise
        # would flip CI floors); straggler_auto is the same length with the
        # transient straggler + the controller on. no_fault_ref must run
        # BEFORE any scenario that computes a retention against it.
        scenarios = {
            "no_fault": legacy["no_fault"],
            "no_fault_ref": (None, None, False),
            "straggler": legacy["straggler"],
            "crash": legacy["crash"],
            "straggler_auto": (None, FaultSpec(
                straggler_sleep_s={R - 1: STRAGGLER_SLEEP_S},
                straggler_until={R - 1: AUTO_UNTIL}), True),
            # chaos scenarios (DESIGN.md §10) run at the no_fault_ref span:
            # same seeds + same iteration count => the final embedding state
            # is directly comparable to the no-fault oracle. Faults that
            # depend on the calibrated length are built lazily.
            "ps_fail": (None, lambda n: FaultSpec(
                ps_fail_at={0: max(n // 4, 1)},
                ps_recover_after_s=PS_RECOVER_S), False),
        }
        if mode == "shadow":  # fixed_rate has no sync thread to crash
            scenarios["sync_crash"] = (None, lambda n: FaultSpec(
                sync_crash_at=SYNC_CRASH_ROUND), False)
        oracle_emb = None  # no_fault_ref's final packed table (parity ref)
        for name, (n_iters, fault, with_policy) in scenarios.items():
            if n_iters is None:  # calibrate from this mode's no_fault pace
                ref = results[mode]["no_fault"]["healthy_eps"]
                n_iters = auto_iters.setdefault(mode, int(min(
                    AUTO_ITERS_MAX, max(AUTO_ITERS_MIN,
                                        round(AUTO_SPAN_S * ref / BATCH)))))
            if callable(fault):
                fault = fault(n_iters)
            policy = None
            eps_window_s = 2.0
            if with_policy:
                policy = StragglerPolicy(PolicyConfig(**AUTO_POLICY),
                                         n_slots=R)
                eps_window_s = AUTO_EPS_WINDOW_S
            runner = make_runner(mode, fault, policy, eps_window_s)
            # each runner owns fresh jit wrappers: trace OUTSIDE the
            # measured run, or short scenarios are trace-dominated and the
            # controller's meters are blind during its detection window
            runner.warmup()
            out = runner.run(n_iters)
            healthy = _healthy_eps(out, fault)
            res: Dict[str, object] = {
                "eps": out["eps"],
                "eps_window": out["eps_window"],
                "healthy_eps": healthy,
                "per_trainer_eps": out["per_trainer_eps"],
                "per_trainer_eps_busy": out["per_trainer_eps_busy"],
                "wall_s": out["wall_s"],
                "sync_count": out["sync_count"],
                "iter_count": out["iter_count"],
                "iters_per_trainer": n_iters,
            }
            if name not in ("no_fault", "no_fault_ref"):
                ref = results[mode]["no_fault_ref"]["healthy_eps"]
                res["healthy_retention"] = healthy / max(ref, 1e-9)
            if with_policy:
                t0 = out["t_start"]
                res["events"] = [[e.kind, e.slot, e.reason,
                                  round(e.t - t0, 3)]
                                 for e in out["membership_events"]]
                demote = [e for e in out["membership_events"]
                          if e.kind == "leave"]
                readmit = [e for e in out["membership_events"]
                           if e.kind == "activate"]
                res["demote_wall_s"] = (demote[0].t - t0) if demote else None
                res["readmit_wall_s"] = (readmit[0].t - t0) if readmit else None
            if name == "no_fault_ref":
                oracle_emb = out["emb_state"]  # the chaos parity reference
            if name == "sync_crash":
                t0 = out["t_start"]
                sup = out["supervision_events"]
                res["sync_restarts"] = out["sync_restarts"]
                res["sync_count_at_restart"] = out["sync_count_at_restart"]
                res["sync_degraded"] = out["sync_degraded"]
                res["supervision_events"] = [
                    [e.kind, e.name, e.reason, round(e.t - t0, 3)]
                    for e in sup]
                death = [e for e in sup if e.kind in ("death", "stall")]
                restart = [e for e in sup if e.kind == "restart"]
                res["detect_wall_s"] = (death[0].t - t0) if death else None
                res["restart_wall_s"] = (restart[0].t - t0) if restart else None
                res["post_restart_syncs"] = (
                    out["sync_count"] - out["sync_count_at_restart"][0]
                    if out["sync_count_at_restart"] else 0)
            if name == "ps_fail":
                t0 = out["t_start"]
                res["shard_events"] = [
                    [e.kind, e.shard, e.reason, round(e.t - t0, 3)]
                    for e in out["shard_events"]]
                res["dropped_updates"] = out["dropped_updates"]
                res["stale_lookups"] = out["stale_lookups"]
                fails = [e for e in out["shard_events"] if e.kind == "ps_fail"]
                recs = [e for e in out["shard_events"]
                        if e.kind == "ps_recover"]
                res["ps_down_s"] = ((recs[0].t - fails[0].t)
                                    if fails and recs else None)
                # bounded-staleness cost vs the span-matched no-fault
                # oracle (same seeds, same iteration count). The FLOORED
                # metric is the Adagrad accumulator mass ratio — a monotone
                # count of landed update energy that run-to-run Hogwild
                # interleaving barely moves (~1.03) but a never-rehydrated
                # snapshot rollback drags to ~0.8; the table rel err is
                # noise-dominated (~0.35 either way) and kept only as a
                # divergence/NaN sanity ceiling.
                t_ref = np.asarray(oracle_emb["table"], np.float32)
                t_got = np.asarray(out["emb_state"]["table"], np.float32)
                res["emb_rel_err"] = float(
                    np.linalg.norm(t_got - t_ref) /
                    max(np.linalg.norm(t_ref), 1e-9))
                a_ref = float(np.sum(np.asarray(oracle_emb["acc"],
                                                np.float64)))
                a_got = float(np.sum(np.asarray(out["emb_state"]["acc"],
                                                np.float64)))
                res["emb_progress_ratio"] = a_got / max(a_ref, 1e-9)
            results[mode][name] = res
            rows.append((f"elastic/{mode}_{name}", out["wall_s"] * 1e6,
                         f"{out['eps']:.0f} EPS "
                         f"(healthy {healthy:.0f}/trainer)"))
            extra = ""
            if "healthy_retention" in res:
                extra = f"  retention {res['healthy_retention']:.0%}"
            print(f"  {mode:10s} {name:14s}  EPS {out['eps']:7.0f}  "
                  f"window {out['eps_window']:7.0f}  "
                  f"healthy/trainer {healthy:7.0f}  "
                  f"wall {out['wall_s']:5.2f}s  syncs {out['sync_count']}"
                  f"{extra}")
            if with_policy and res["events"]:
                print(f"    {'':10s} events: "
                      + ", ".join(f"{k}@{t:.2f}s" if t is not None else k
                                  for k, _, _, t in res["events"]))
            if name == "sync_crash":
                print(f"    {'':10s} sync thread: restarts "
                      f"{res['sync_restarts']}, detected at "
                      f"{res['detect_wall_s']:.2f}s, restarted at "
                      f"{res['restart_wall_s']:.2f}s, "
                      f"{res['post_restart_syncs']} post-restart syncs")
            if name == "ps_fail":
                down = res["ps_down_s"]
                how = (f"down {down:.2f}s" if down is not None
                       else "shutdown-rehydrated")
                print(f"    {'':10s} PS 0: {how}, dropped "
                      f"{sum(res['dropped_updates'])} updates, "
                      f"{sum(res['stale_lookups'])} stale lookups, "
                      f"progress ratio {res['emb_progress_ratio']:.3f}, "
                      f"emb rel err {res['emb_rel_err']:.4f}")

    # -- mode_switch (DESIGN.md §14): tuning-free sync<->async switching --
    # Start in fixed_rate with a transient straggler and the ModeController
    # on: the barrier drags everyone, busy-EPS dispersion blows past
    # skew_high, and the controller hands the cohort to shadow (barrier
    # drained, shadow clocks seeded from the last global sync). When the
    # straggler recovers, dispersion falls through skew_low and the
    # controller runs the catch-up sync and re-arms the barrier. Floors:
    # the full cycle happens, fixed_rate->shadow lands inside the bounded
    # detection window, and the healthy cohort keeps static-shadow pace.
    from repro.core.modeswitch import ModeConfig, ModeController

    n_iters = auto_iters["shadow"]
    ctl = ModeController(ModeConfig(start_mode="fixed_rate", **MODE_SWITCH))
    runner = make_runner(
        "fixed_rate",
        fault=FaultSpec(straggler_sleep_s={R - 1: STRAGGLER_SLEEP_S},
                        straggler_until={R - 1: AUTO_UNTIL}),
        eps_window_s=MODE_EPS_WINDOW_S, mode_controller=ctl)
    runner.warmup()
    out = runner.run(n_iters)
    t0 = out["t_start"]
    trans = [[round(t - t0, 3), frm, to, why]
             for t, frm, to, why in out["mode_transitions"]]
    cycle = (["fixed_rate"] + [to for _, _, to, _ in trans]) if trans else []
    healthy = _healthy_eps(out, None)  # transient fault: nobody excluded
    ref = results["shadow"]["no_fault_ref"]["healthy_eps"]
    res = {
        "eps": out["eps"],
        "eps_window": out["eps_window"],
        "healthy_eps": healthy,
        "per_trainer_eps": out["per_trainer_eps"],
        "per_trainer_eps_busy": out["per_trainer_eps_busy"],
        "wall_s": out["wall_s"],
        "sync_count": out["sync_count"],
        "iter_count": out["iter_count"],
        "iters_per_trainer": n_iters,
        # retention vs the STATIC shadow reference: the adaptive run must
        # not cost healthy throughput relative to just picking shadow
        "healthy_retention": healthy / max(ref, 1e-9),
        "final_mode": out["mode"],
        "mode_cycle": cycle,
        "mode_transitions": trans,
        "to_shadow_wall_s": trans[0][0] if trans else None,
        "back_wall_s": trans[1][0] if len(trans) > 1 else None,
        "events": [[e.kind, e.slot, e.reason, round(e.t - t0, 3)]
                   for e in out["membership_events"]],
    }
    # Sim replay (the determinism half of the contract): the SAME
    # controller state machine driven by a scripted rate trace inside
    # HogwildSim must produce bit-identical trajectories across two fresh
    # runs — closed-loop mode switching stays reproducible.
    res["sim_replay"] = _sim_mode_replay(cfg)
    results["mode_switch"] = res
    rows.append(("elastic/mode_switch", out["wall_s"] * 1e6,
                 f"{out['eps']:.0f} EPS (cycle {'->'.join(cycle)})"))
    print(f"  {'auto':10s} {'mode_switch':14s}  EPS {out['eps']:7.0f}  "
          f"window {out['eps_window']:7.0f}  "
          f"healthy/trainer {healthy:7.0f}  "
          f"wall {out['wall_s']:5.2f}s  syncs {out['sync_count']}"
          f"  retention {res['healthy_retention']:.0%}")
    for t, frm, to, _ in trans:
        print(f"    {'':10s} {frm} -> {to} at {t:.2f}s")
    print(f"    {'':10s} sim replay: mode_events "
          f"{res['sim_replay']['mode_events']}, reproducible: "
          f"{res['sim_replay']['trajectory_reproducible']}")

    sh, fr = results["shadow"], results["fixed_rate"]
    print(f"  straggler contrast: shadow healthy cohort keeps "
          f"{sh['straggler']['healthy_retention']:.0%} of no-fault pace; "
          f"fixed_rate holds everyone to "
          f"{fr['straggler']['healthy_retention']:.0%} — with the "
          f"closed-loop controller, fixed_rate recovers to "
          f"{fr['straggler_auto']['healthy_retention']:.0%}")

    if json_path:
        payload = {
            "bench": "elastic_bench",
            "config": {"R": R, "iters_per_trainer": iters, "algo": ALGO,
                       "gap": GAP, "batch_size": BATCH,
                       "straggler_sleep_s": STRAGGLER_SLEEP_S,
                       "crash_at": max(iters // 3, 1), "tiny": tiny,
                       "straggler_auto": {
                           "span_s": AUTO_SPAN_S,
                           "iters": auto_iters,
                           "straggler_until": AUTO_UNTIL,
                           "eps_window_s": AUTO_EPS_WINDOW_S,
                           **AUTO_POLICY,
                       },
                       "chaos": {
                           "sync_crash_round": SYNC_CRASH_ROUND,
                           "ps_recover_s": PS_RECOVER_S,
                           "supervisor": CHAOS_SUP,
                       },
                       "mode_switch": {
                           "iters": auto_iters.get("shadow"),
                           "straggler_until": AUTO_UNTIL,
                           "eps_window_s": MODE_EPS_WINDOW_S,
                           "to_shadow_max_s": TO_SHADOW_MAX_S,
                           **MODE_SWITCH,
                       }},
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_elastic.json to the cwd")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test iteration count (CI)")
    args = ap.parse_args()
    rows = bench_elastic(json_path="BENCH_elastic.json" if args.json else None,
                         tiny=args.tiny)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

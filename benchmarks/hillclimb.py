"""§Perf hillclimb driver: runs the baseline + named candidate changes for the
three selected (arch x shape) pairs, printing before/after roofline terms.

Each candidate encodes one hypothesis (see EXPERIMENTS.md §Perf iteration log).

    PYTHONPATH=src python -m benchmarks.hillclimb --pair granite
    PYTHONPATH=src python -m benchmarks.hillclimb --pair kimi
    PYTHONPATH=src python -m benchmarks.hillclimb --pair mamba-decode
"""
import argparse
import json
import os

# MUST precede any jax import (see dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

from repro.launch.dryrun import run_one  # noqa: E402

PAIRS = {
    "granite": ("granite-20b", "train_4k"),
    "kimi": ("kimi-k2-1t-a32b", "train_4k"),
    "mamba-decode": ("mamba2-780m", "long_500k"),
}

# (name, hypothesis, kwargs)
CANDIDATES = {
    "granite": [
        ("baseline", "paper-faithful: FSDP + fp32 grads + K=8 microbatches", {}),
        ("no-fsdp",
         "20B params + adagrad fit un-sharded over data (3.5+7 GiB/chip): "
         "dropping FSDP removes the per-layer fwd/bwd param all-gathers "
         "(~2x layer params/step of AG traffic) at the cost of replicated "
         "param memory. Predict: t_coll down 30-50%, t_mem down, temp up.",
         {"fsdp": False}),
        ("parallel-block",
         "HLO inspection: 2 x f32[16,4096,6144] activation all-reduces per layer "
         "(Megatron-TP) dominate t_coll; a PaLM-style parallel block sums the "
         "attn and ffn partial results BEFORE the model-axis reduce => one AR "
         "per layer. Predict: t_coll down ~40-50%. (Beyond-paper; PaLM showed "
         "quality-neutral at scale.)",
         {"parallel_block": True}),
        ("parallel+no-fsdp",
         "compose with no-fsdp if both help.",
         {"parallel_block": True, "fsdp": False}),
        ("microbatch-16",
         "K=16 halves live activations (temp memory) at ~zero extra traffic; "
         "helps the memory term's activation component.",
         {"n_microbatches": 16}),
        ("save-comm-remat",
         "full remat REPLAYS the forward TP all-reduces inside backward "
         "(HLO shows ~8 residual-stream ARs/layer). Saving the post-collective "
         "activations (checkpoint_name + save_only_these_names) removes the "
         "replayed ARs and the recomputed matmuls feeding them. Predict: "
         "t_coll down ~25%, t_comp down ~20%, temp up.",
         {"remat_policy": "save_comm"}),
        ("parallel+save-comm",
         "compose the two confirmed wins.",
         {"parallel_block": True, "remat_policy": "save_comm"}),
    ],
    "kimi": [
        ("baseline", "paper-faithful: FSDP (mandatory at 1T) + fp32 grads + cap 1.25", {}),
        ("capacity-1.0",
         "capacity factor 1.25 -> 1.0 cuts expert dispatch buffers and the "
         "all-to-all payload by 20%. Predict: t_coll down ~5-10%, t_mem down.",
         {"capacity_factor": 1.0}),
        ("parallel-block",
         "kimi is MoE-every-layer: the attn partial sum and the MoE combine "
         "can share one model-axis reduce per layer (PaLM-style). Predict: "
         "t_coll down 20-40% (the EP all-to-all part is untouched).",
         {"parallel_block": True}),
        ("parallel+cap1.0",
         "compose.",
         {"parallel_block": True, "capacity_factor": 1.0}),
        ("save-comm-remat",
         "same replayed-collective argument as granite, and for MoE the remat "
         "replay repeats the expert all-to-all too. Predict: t_coll down "
         ">=25%.",
         {"remat_policy": "save_comm"}),
        ("best-combo",
         "parallel block + cap 1.0 + save-comm remat.",
         {"parallel_block": True, "capacity_factor": 1.0,
          "remat_policy": "save_comm"}),
    ],
    "mamba-decode": [
        ("baseline", "B=1 decode, state sharded H/model, conv C/model", {}),
        ("no-fsdp",
         "at B=1 decode every param is read once per token; FSDP makes each "
         "read an all-gather over data. Un-sharding params over data turns "
         "param reads into local HBM streams. Predict: t_coll collapses "
         "(params are only 1.5 GB), t_mem ~unchanged.",
         {"fsdp": False}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    arch, shape = PAIRS[args.pair]
    rows = []
    for name, hypothesis, kw in CANDIDATES[args.pair]:
        print(f"\n### {args.pair}/{name}")
        print(f"    hypothesis: {hypothesis}")
        row = run_one(arch, shape, tag_suffix=f" <{name}>", **kw)
        row["candidate"] = name
        row["hypothesis"] = hypothesis
        rows.append(row)
    base = next(r for r in rows if r["candidate"] == "baseline")
    print(f"\n== {args.pair} summary (vs baseline) ==")
    for r in rows:
        if r.get("status") != "ok":
            print(f"  {r['candidate']:16s} FAILED: {r.get('error')}")
            continue
        dc = r["t_collective"] / max(base["t_collective"], 1e-12) - 1
        dm = r["t_memory"] / max(base["t_memory"], 1e-12) - 1
        print(f"  {r['candidate']:16s} t_comp={r['t_compute']*1e3:9.1f}ms "
              f"t_mem={r['t_memory']*1e3:9.1f}ms ({dm:+.0%}) "
              f"t_coll={r['t_collective']*1e3:9.1f}ms ({dc:+.0%}) "
              f"temp={r['temp_bytes']/2**30:6.1f}GiB")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()

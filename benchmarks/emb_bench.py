"""Embedding substrate benchmark: dense-take vs fused kernels vs plan-sharded.

One full embedding cycle (sum-pooled lookup forward + row-sparse Adagrad
backward) through the three paths the runners can take (DESIGN.md §7):

* ``dense_take``  — the pure-jnp oracle: ``jnp.take`` + sum forward, scatter
  chain backward (materializes the (n_items, d) per-occurrence gradient
  broadcast and the (B, F, m, d) gathered vectors).
* ``fused``       — the Pallas ops (``kernels/embedding_bag`` +
  ``kernels/sparse_adagrad``): one launch each way, nothing materialized.
  Wall time is labeled with how the kernel actually ran (compiled on TPU,
  interpreter elsewhere — interpreter wall is NOT a perf claim, mirroring
  kernel_bench.py; the analytic stream model is the portable number).
* ``plan_sharded`` — the ``EmbeddingShards`` engine: LPT bin-packed per-PS
  tables, one fused launch per shard each way (the ThreadedShadowRunner
  path, where per-shard independence also de-serializes Hogwild writes).

The analytic HBM stream model is op-level fp32 accounting like DESIGN.md §3.3
(each op reads its inputs and writes its outputs once; I = bag*hot occurrence
count, G = bag count, U <= I distinct rows touched, d = embedding dim):

* forward  dense-take: gather I + write/read vecs 2I + write pool G = 3I+G;
  fused: stream I rows in, pool G out = I+G.
* backward dense-take: bcast G+I, square 2I, acc scatter 3I, acc gather 2I,
  scale 2I, mul 3I, table scatter 3I = 16I+G floats (xd);
  fused: g blocks I, table rows 2U, acc rows 2U = I+4U.

`--json` writes BENCH_emb.json (the per-PR sparse-path trajectory);
`--tiny` shrinks shapes for the CI smoke.

  PYTHONPATH=src python -m benchmarks.emb_bench [--json] [--tiny]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_call as _time

N_SHARDS = 4


def dense_take_bytes(n_items: int, n_bags: int, d: int) -> int:
    return 4 * d * ((3 * n_items + n_bags) + (16 * n_items + n_bags))


def fused_bytes(n_items: int, n_bags: int, d: int, unique_rows: int) -> int:
    return 4 * d * ((n_items + n_bags) + (n_items + 4 * unique_rows))


def bench_emb(json_path: Optional[str] = None,
              tiny: bool = False) -> List[Tuple[str, float, str]]:
    from repro.configs import dlrm_ctr
    from repro.embeddings import shards
    from repro.embeddings import table as emb
    from repro.kernels.backend import on_tpu

    cfg = dlrm_ctr.tiny(embedding_dim=16 if tiny else 64)
    spec = emb.spec_from_config(cfg)
    B = 64 if tiny else 512
    F, m, d = cfg.n_sparse_features, cfg.multi_hot, cfg.embedding_dim
    n_bags, n_items = B * F, B * F * m

    key = jax.random.PRNGKey(0)
    state = emb.init_tables(spec, key)
    idx = jax.random.randint(
        jax.random.fold_in(key, 1), (B, F, m), 0, 1 << 30
    ) % jnp.asarray(spec.sizes)[None, :, None]
    g = jax.random.normal(jax.random.fold_in(key, 2), (B, F, d))
    rows = np.asarray(emb.global_row_ids(spec, idx)).reshape(-1)
    unique_rows = int(len(np.unique(rows)))
    lr = 0.05

    plan = shards.plan_shards(spec, N_SHARDS, B)
    sh = shards.EmbeddingShards.init(plan, key)

    mode = "compiled" if on_tpu() else "interpret"
    print(f"\n== Embedding cycle: dense-take vs fused[{mode}] vs plan-sharded "
          f"(B={B}, F={F}, m={m}, d={d}, {unique_rows}/{n_items} distinct rows) ==")

    def cyc_dense(state, idx, g):
        pooled = emb.lookup(state, spec, idx, use_pallas=False)
        return pooled, emb.sparse_adagrad_update(state, spec, idx, g, lr)

    def cyc_fused(state, idx, g):
        pooled = emb.lookup(state, spec, idx)
        return pooled, emb.sparse_adagrad_update_fused(state, spec, idx, g, lr)

    def cyc_sharded(states, idx, g):
        pooled = shards.shard_lookup(
            plan, tuple(st["table"] for st in states), idx)
        new = [shards.shard_update(plan, s, states[s], idx, g, lr)
               for s in range(plan.n_shards)]
        return pooled, new

    b_dense = dense_take_bytes(n_items, n_bags, d)
    b_fused = fused_bytes(n_items, n_bags, d, unique_rows)
    ratio = b_dense / b_fused

    rows_out: List[Tuple[str, float, str]] = []
    us_dense = _time(jax.jit(cyc_dense), state, idx, g)
    rows_out.append(("emb/dense_take", us_dense, f"{b_dense / 1e6:.1f} MB/cycle"))
    us_fused = _time(jax.jit(cyc_fused), state, idx, g)
    rows_out.append((f"emb/fused[{mode}]", us_fused,
                     f"{b_fused / 1e6:.1f} MB/cycle ({ratio:.2f}x fewer streams)"))
    us_shard = _time(jax.jit(cyc_sharded), sh.states, idx, g)
    rows_out.append((f"emb/plan_sharded[{mode}]", us_shard,
                     f"{plan.n_shards} PSs, fused per shard, "
                     f"independent Hogwild writes"))
    for name, us, derived in rows_out:
        print(f"  {name:26s} {us:12.1f} us/cycle   {derived}")

    if json_path:
        results: Dict[str, Dict] = {
            "dense_take": {"wall_us": us_dense, "bytes": b_dense},
            "fused": {"wall_us": us_fused, "bytes": b_fused,
                      "stream_ratio": ratio, "mode": mode},
            "plan_sharded": {"wall_us": us_shard, "bytes": b_fused,
                             "n_shards": plan.n_shards, "mode": mode,
                             "bins": [list(b) for b in plan.bins]},
        }
        payload = {
            "bench": "emb_bench",
            "config": {"B": B, "F": F, "m": m, "d": d,
                       "n_items": n_items, "n_bags": n_bags,
                       "unique_rows": unique_rows, "lr": lr,
                       "table_rows": spec.total_rows, "tiny": tiny},
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows_out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_emb.json next to the cwd")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke shapes (small batch/dim)")
    args = ap.parse_args()
    rows = bench_emb(json_path="BENCH_emb.json" if args.json else None,
                     tiny=args.tiny)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""One benchmark per paper table/figure. Each returns a list of CSV rows
(name, us_per_call, derived) and prints a human-readable table.

Quality numbers come from real (scaled-down) one-pass training via HogwildSim;
throughput curves come from the calibrated fluid model (benchmarks/eps_model.py)
— see EXPERIMENTS.md §Paper-validation for the mapping.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.eps_model import ClusterModel
from repro import optim
from repro.configs import dlrm_ctr
from repro.core.elp import PAPER_TABLE1, elp
from repro.core.runners import HogwildSim
from repro.core.sync import SyncConfig

Row = Tuple[str, float, str]

CFG = dlrm_ctr.tiny()
ITERS = 120
TRAINERS, THREADS, BATCH = 4, 2, 64


def _train(algo: str, mode: str, gap: int, *, trainers=TRAINERS, threads=THREADS,
           seed=0, iters=ITERS, alpha=0.5):
    sim = HogwildSim(CFG, SyncConfig(algo=algo, mode=mode, gap=gap, alpha=alpha),
                     n_trainers=trainers, n_threads=threads, batch_size=BATCH,
                     optimizer=optim.adagrad(0.02), seed=seed)
    t0 = time.perf_counter()
    out = sim.run(iters)
    wall = time.perf_counter() - t0
    ev = sim.evaluate(out["state"], n_batches=8, batch_size=2048)
    return {
        "train": float(np.mean(out["train_loss"][-10:])),
        "eval": ev,
        "gap": out["avg_sync_gap"],
        "us_per_iter": wall / iters * 1e6,
    }


def bench_table1_elp() -> List[Row]:
    """Table 1: ELP comparison."""
    print("\n== Table 1: Example Level Parallelism ==")
    rows = []
    ours = elp(200, 24, 20)
    for name, r in PAPER_TABLE1.items():
        e = r["elp"] if r["elp"] is not None else f"{r['replicas']}xB"
        print(f"  {name:14s} batch={str(r['batch']):6s} hog={r['hogwild']:3d} "
              f"rep={r['replicas']:4d} ELP={e}")
        rows.append((f"table1/{name}", 0.0, str(e)))
    assert ours == 96000
    return rows


def bench_table2_quality() -> List[Row]:
    """Table 2: S-EASGD vs FR-EASGD across sync gaps (scaled-down: 4 trainers)."""
    print("\n== Table 2: S-EASGD vs FR-EASGD quality (one-pass CTR, 4 trainers) ==")
    rows = []
    s = _train("easgd", "shadow", gap=5)
    print(f"  S-EASGD      (avg gap {s['gap']:5.2f}) train {s['train']:.5f} eval {s['eval']:.5f}")
    rows.append(("table2/S-EASGD", s["us_per_iter"], f"eval={s['eval']:.5f}"))
    for gap in (5, 10, 30, 100):
        r = _train("easgd", "fixed_rate", gap=gap)
        flag = " <- quality degrades with gap" if gap == 100 else ""
        print(f"  FR-EASGD-{gap:<4d}(gap {gap:5d}) train {r['train']:.5f} eval {r['eval']:.5f}{flag}")
        rows.append((f"table2/FR-EASGD-{gap}", r["us_per_iter"], f"eval={r['eval']:.5f}"))
    return rows


def bench_fig5_scaling() -> List[Row]:
    """Fig 5: EPS scaling + sync-PS saturation (calibrated fluid model)."""
    m = ClusterModel()
    print(f"\n== Fig 5: EPS scaling (model: |w|={m.w_bytes/1e6:.2f}MB, "
          f"EPS0={m.eps_0:.0f}, 25Gbit PSs) ==")
    print("  trainers   S-EASGD   FR-5(2PS)  FR-30(2PS)  FR-5(4PS)   S-gap")
    rows = []
    for n in range(5, 21):
        se = m.shadow_eps(n)
        f5 = m.fr_eps(n, 5, 2)
        f30 = m.fr_eps(n, 30, 2)
        f5_4 = m.fr_eps(n, 5, 4)
        gap = m.shadow_avg_sync_gap(n, 2)
        print(f"  {n:8d} {se:9.0f} {f5:10.0f} {f30:11.0f} {f5_4:10.0f} {gap:7.2f}")
        rows.append((f"fig5/n{n}", 0.0,
                     f"S={se:.0f};FR5_2ps={f5:.0f};FR30={f30:.0f};FR5_4ps={f5_4:.0f};gap={gap:.2f}"))
    # paper-claim checks
    assert m.fr_eps(20, 5, 2) < 0.8 * m.shadow_eps(20), "FR-5/2PS must plateau"
    assert m.fr_eps(20, 5, 4) > 0.95 * m.shadow_eps(20), "4 sync PSs must fix it"
    assert m.fr_eps(20, 30, 2) > 0.95 * m.shadow_eps(20), "FR-30 stays linear"
    gaps = [m.shadow_avg_sync_gap(n, 2) for n in range(15, 21)]
    assert all(b > a for a, b in zip(gaps, gaps[1:])), "S gap grows with n"
    print(f"  S-EASGD avg sync gaps 15..20 trainers: {[round(g,2) for g in gaps]} "
          f"(paper: 8.60..12.48)")
    return rows


def bench_fig6_bmuf_ma() -> List[Row]:
    """Fig 6: BMUF & MA, shadow vs fixed rate — quality + EPS."""
    print("\n== Fig 6: BMUF/MA shadow vs fixed-rate (quality + modeled EPS) ==")
    rows = []
    for algo in ("bmuf", "ma"):
        s = _train(algo, "shadow", gap=5)
        f = _train(algo, "fixed_rate", gap=5)
        print(f"  S-{algo.upper():4s} train {s['train']:.5f} eval {s['eval']:.5f}   "
              f"FR-{algo.upper():4s} train {f['train']:.5f} eval {f['eval']:.5f}")
        rows.append((f"fig6/S-{algo}", s["us_per_iter"], f"eval={s['eval']:.5f}"))
        rows.append((f"fig6/FR-{algo}", f["us_per_iter"], f"eval={f['eval']:.5f}"))
    m = ClusterModel()
    for n in (5, 10, 15, 20):
        print(f"  EPS n={n:2d}: shadow {m.allreduce_eps(n, 5, False):9.0f}  "
              f"FR {m.allreduce_eps(n, 5, True):9.0f} (all linear-ish: no PS bottleneck)")
    return rows


def bench_fig7_shadow_algos() -> List[Row]:
    """Fig 7: S-EASGD vs S-BMUF (2 alphas) vs S-MA."""
    print("\n== Fig 7: ShadowSync algorithms compared ==")
    rows = []
    runs = [
        ("S-EASGD", _train("easgd", "shadow", 5)),
        ("S-BMUF(a=.5)", _train("bmuf", "shadow", 5, alpha=0.5)),
        ("S-BMUF(a=.9)", _train("bmuf", "shadow", 5, alpha=0.9)),
        ("S-MA", _train("ma", "shadow", 5)),
    ]
    for name, r in runs:
        print(f"  {name:14s} train {r['train']:.5f} eval {r['eval']:.5f}")
        rows.append((f"fig7/{name}", r["us_per_iter"], f"eval={r['eval']:.5f}"))
    evals = [r["eval"] for _, r in runs]
    spread = (max(evals) - min(evals)) / min(evals)
    print(f"  spread {spread*100:.2f}% — decentralized variants are on par (paper §4.3)")
    return rows


def bench_fig8_hogwild() -> List[Row]:
    """Fig 8: Hogwild worker-thread sweep — quality (real) + EPS (membw model)."""
    print("\n== Fig 8: Hogwild threads sweep ==")
    m = ClusterModel()
    rows = []
    for threads in (1, 2, 4, 8):
        r = _train("easgd", "shadow", 5, threads=threads, iters=80)
        eps = m.hogwild_eps(threads * 3)  # scale to paper-ish thread counts
        print(f"  threads={threads:2d} train {r['train']:.5f} eval {r['eval']:.5f} "
              f"(modeled EPS @ {threads*3} paper-threads: {eps:.0f})")
        rows.append((f"fig8/threads{threads}", r["us_per_iter"], f"eval={r['eval']:.5f}"))
    sat = [m.hogwild_eps(t) for t in (12, 24, 32, 64)]
    print(f"  modeled EPS 12/24/32/64 threads: {[round(s) for s in sat]} "
          f"(saturates ~24, paper Fig 8 right)")
    assert sat[1] / sat[0] < 1.9  # sub-linear by 24 threads
    assert sat[3] / sat[1] < 1.25  # nearly flat past 24
    return rows

"""Tiered embedding cache: hit rate, stalls, migration traffic, parity.

Production CTR tables don't fit in device memory; DESIGN.md §11's two-tier
``CachedStore`` keeps a fixed hot budget device-resident and lets the shadow
thread's lookahead prefetcher stage cold->hot promotions between syncs. This
bench records the numbers that story stands on, against a **full-device
oracle** — the same stream through the unchanged full-table kernels, which
is both the latency baseline and the bitwise ground truth.

Store scenarios (zipf(``ZIPF_A``) row stream, hot budget = ``HOT_FRAC`` of
the table, row ids PERMUTED so popularity is scattered — the initial
[0, H) placement gets no free alignment with the skew):

* ``lookahead2`` — the shipping configuration: the prefetcher peeks the
  next ``LOOKAHEAD`` queued batches (BagPipe-style; the stream is a pure
  function of the iteration counter) and promotes their miss sets before
  the lookup lands. Floors (scripts/check_bench_floors.py): steady-state
  hit rate >= 0.9, stall fraction <= 0.1, merged() BITWISE equal to the
  oracle after the full lookup+update stream, device residency = HOT_FRAC.
* ``lookahead0`` — prefetch off: every cold row is a counted synchronous
  promotion. The hit rate here is what plain frequency-aware placement
  earns on its own; the gap to ``lookahead2`` is the lookahead's
  contribution. No floor — it's the contrast row.

Steady-state means stats are diffed AFTER ``WARMUP_BATCHES`` rounds, so
the cold-start ramp (everything misses once) doesn't dilute the rates the
floors defend.

Sim scenario: two ``HogwildSim`` runs (tiny DLRM, easgd), cache on vs off.
The cache is a pure placement optimization, so ``trajectory_bitwise`` — the
loss stream AND the final packed table/accumulator bitwise equal — must be
True (floored). This is the acceptance contract: checkpoints, the sync
oracle, and eval are cache-invisible.

``--json`` writes BENCH_cache.json; ``--tiny`` shrinks shapes and spans for
the CI smoke.

  PYTHONPATH=src python -m benchmarks.cache_bench [--json] [--tiny]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

N_ROWS = 4096
DIM = 32
HOT_FRAC = 0.25
ZIPF_A = 1.05
BATCH = 64  # bags per batch
MULTI_HOT = 4  # rows per bag
LOOKAHEAD = 2
WARMUP_BATCHES = 8
MEASURE_BATCHES = 48
EMB_LR = 0.05
SIM_ITERS = 10

TINY = dict(n_rows=1024, batch=32, warmup=4, measure=16, sim_iters=5)


def bench_cache(
    json_path: Optional[str] = None,
    tiny: bool = False,
) -> List[Tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import optim
    from repro.configs import dlrm_ctr
    from repro.core.runners import HogwildSim
    from repro.core.sync import SyncConfig
    from repro.embeddings.cache import CacheConfig, CachedStore
    from repro.kernels.embedding_bag.ops import embedding_bag_op
    from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op

    n = TINY["n_rows"] if tiny else N_ROWS
    B = TINY["batch"] if tiny else BATCH
    warm = TINY["warmup"] if tiny else WARMUP_BATCHES
    meas = TINY["measure"] if tiny else MEASURE_BATCHES
    total = warm + meas
    H = int(round(HOT_FRAC * n))
    print(
        f"\n== Tiered cache: zipf({ZIPF_A}) stream over {n} rows, "
        f"hot budget {H} ({HOT_FRAC:.0%}), {B}x{MULTI_HOT} ids/batch, "
        f"{warm}+{meas} batches ==",
    )

    # zipf(ZIPF_A) over n rows, ids permuted so popularity rank carries no
    # relation to row id (the initial [0, H) placement earns nothing)
    rng = np.random.default_rng(0)
    p = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** ZIPF_A
    p /= p.sum()
    perm = rng.permutation(n)
    batches = []
    for _ in range(total + LOOKAHEAD):
        batches.append(perm[rng.choice(n, size=(B, MULTI_HOT), p=p)])
    grads = []
    for _ in range(total):
        grads.append(np.asarray(rng.standard_normal((B, DIM)), np.float32) * 0.1)

    key = jax.random.PRNGKey(0)
    state = {
        "table": jax.random.normal(key, (n, DIM), jnp.float32),
        "acc": jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n, DIM))) * 0.1,
    }

    # full-device oracle: same stream through the unchanged full-table
    # kernels — the latency baseline AND the bitwise ground truth
    ref_t, ref_a = state["table"], state["acc"]
    t0 = time.perf_counter()
    for t in range(total):
        idx = jnp.asarray(batches[t])
        embedding_bag_op(ref_t, idx).block_until_ready()
        g = jnp.asarray(grads[t])
        ref_t, ref_a = sparse_adagrad_op(ref_t, ref_a, idx, g, lr=EMB_LR)
    jax.block_until_ready(ref_t)
    oracle_us = (time.perf_counter() - t0) / total * 1e6
    row_bytes = 2 * 4 * DIM  # f32 table + acc

    rows: List[Tuple[str, float, str]] = []
    results: Dict[str, object] = {}
    for la in (LOOKAHEAD, 0):
        store = CachedStore(state, CacheConfig(hot_rows=H, lookahead=la))
        base = store.stats.as_dict()
        t0 = time.perf_counter()
        for t in range(total):
            if t == warm:  # steady state from here: diff stats, restart clock
                base = store.stats.as_dict()
                t0 = time.perf_counter()
            if la:
                store.prefetch([batches[t + j] for j in range(la)])
            jax.block_until_ready(store.lookup(batches[t]))
            store.update(batches[t], jnp.asarray(grads[t]), EMB_LR)
        jax.block_until_ready(store.state.hot["table"])
        us = (time.perf_counter() - t0) / meas * 1e6
        d = {k: v - base[k] for k, v in store.stats.as_dict().items()}
        merged = store.merged()
        table_eq = bool((np.asarray(merged["table"]) == np.asarray(ref_t)).all())
        acc_eq = bool((np.asarray(merged["acc"]) == np.asarray(ref_a)).all())
        bitwise = table_eq and acc_eq
        hit_rate = d["hit_rows"] / max(d["hit_rows"] + d["miss_rows"], 1)
        stall_frac = d["stall_lookups"] / max(d["lookups"], 1)
        res = {
            "hit_rate": hit_rate,
            "stall_fraction": stall_frac,
            "migrated_bytes_per_batch": (d["bytes_h2d"] + d["bytes_d2h"]) / meas,
            "prefetch_rows": d["prefetch_rows"],
            "evict_rows": d["evict_rows"],
            "writeback_rows": d["writeback_rows"],
            "stall_lookups": d["stall_lookups"],
            "update_conflicts": d["update_conflicts"],
            "dropped_updates": d["dropped_updates"],
            "us_per_batch": us,
            "oracle_us_per_batch": oracle_us,
            "device_bytes_frac": H / n,
            "bitwise_vs_oracle": bitwise,
        }
        results[f"lookahead{la}"] = res
        derived = f"hit {hit_rate:.3f} stall {stall_frac:.3f} bitwise {bitwise}"
        rows.append((f"cache/lookahead{la}", us, derived))
        mig_kb = res["migrated_bytes_per_batch"] / 1e3
        mig_rows = (d["bytes_h2d"] + d["bytes_d2h"]) // row_bytes // meas
        print(
            f"  lookahead={la}: hit rate {hit_rate:.3f}  stall fraction "
            f"{stall_frac:.3f}  migrated {mig_kb:.1f} KB/batch "
            f"({mig_rows} rows/batch)  {us:.0f} us/batch "
            f"(oracle {oracle_us:.0f})  bitwise {bitwise}",
        )
    print(
        f"  device residency: {H}/{n} rows = {H / n:.0%} of the "
        f"full-device oracle's footprint",
    )

    # trajectory parity: the cache must be invisible to training itself
    cfg = dlrm_ctr.tiny()
    iters = TINY["sim_iters"] if tiny else SIM_ITERS
    sc = SyncConfig(algo="easgd", gap=4, delay=1, engine="flat")

    def run(cache):
        return HogwildSim(
            cfg,
            sc,
            n_trainers=2,
            n_threads=2,
            batch_size=16,
            optimizer=optim.adagrad(0.02),
            seed=1,
            cache=cache,
        ).run(iters)

    out_u = run(None)
    out_c = run(CacheConfig(hot_frac=HOT_FRAC, lookahead=LOOKAHEAD))
    eu = out_u["state"].emb_state
    ec = out_c["state"].emb_state
    loss_eq = out_u["train_loss"] == out_c["train_loss"]
    table_eq = bool((np.asarray(eu["table"]) == np.asarray(ec["table"])).all())
    acc_eq = bool((np.asarray(eu["acc"]) == np.asarray(ec["acc"])).all())
    traj = bool(loss_eq and table_eq and acc_eq)
    cs = out_c["cache_stats"]
    sim_hits = cs["hit_rows"] / max(cs["hit_rows"] + cs["miss_rows"], 1)
    results["sim"] = {
        "trajectory_bitwise": traj,
        "iters": iters,
        "hit_rate": sim_hits,
        "stall_lookups": cs["stall_lookups"],
        "cache_stats": cs,
    }
    rows.append(("cache/sim_parity", 0.0, f"trajectory_bitwise {traj} hit {sim_hits:.3f}"))
    print(
        f"  sim: cache-on trajectory bitwise == cache-off: {traj} "
        f"(hit rate {sim_hits:.3f}, {cs['stall_lookups']} stalls)",
    )

    if json_path:
        payload = {
            "bench": "cache_bench",
            "config": {
                "n_rows": n,
                "dim": DIM,
                "hot_rows": H,
                "hot_frac": HOT_FRAC,
                "zipf_a": ZIPF_A,
                "batch": B,
                "multi_hot": MULTI_HOT,
                "lookahead": LOOKAHEAD,
                "warmup_batches": warm,
                "measure_batches": meas,
                "sim_iters": iters,
                "tiny": tiny,
            },
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true", help="write BENCH_cache.json to the cwd")
    ap.add_argument("--tiny", action="store_true", help="smoke-test shapes (CI)")
    args = ap.parse_args()
    rows = bench_cache(json_path="BENCH_cache.json" if args.json else None, tiny=args.tiny)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

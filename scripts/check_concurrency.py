#!/usr/bin/env python
"""Machine-checked concurrency contracts over the free-threaded sync stack.

Runs the DESIGN.md §12 static analyzer (guarded-by / swap-publish /
no-blocking-under-lock / unannotated-shared-state) over ``src/repro`` and
exits non-zero on any violation. CI runs this next to the test suite; a
contract regression fails the build before it can flake a threaded test.

    python scripts/check_concurrency.py              # check the tree
    python scripts/check_concurrency.py --self-test  # prove each contract
                                                     # class still detects a
                                                     # seeded violation
    python scripts/check_concurrency.py --explain    # code legend
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.contracts import CODES  # noqa: E402
from repro.analysis.static_check import check_path, check_source  # noqa: E402

# One deliberately-broken snippet per contract class. The self-test seeds
# each through the analyzer and fails if the expected code is NOT reported —
# the analyzer itself is under test, so a refactor that quietly blinds a
# pass cannot land green.
_SEEDED = {
    "GB01": """
import threading

class Meter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0  # guarded-by: _lock

    def add(self, n):
        self.total += n  # store outside the lock
""",
    "SP01": """
import threading

class Table:
    def __init__(self):
        self._lock = threading.Lock()
        # swap-published
        self.state = {"v": 0}

    def bump(self):
        self.state["v"] = 1  # in-place element write, not a rebind
""",
    "BL01": """
import threading
import time

class Worker:
    def __init__(self):
        self._lock = threading.Lock()

    def step(self):
        with self._lock:
            time.sleep(0.1)
""",
    "SH01": """
import threading

class Runner:
    def __init__(self):
        self.count = 0

    def start(self):
        t = threading.Thread(target=self.body)
        t.start()

    def body(self):
        self.count += 1

    def read(self):
        self.count += 1
        return self.count
""",
    "CT01": """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.v = 0  # hogwild-race: maybe
""",
}


def self_test() -> int:
    failed = []
    for code, src in sorted(_SEEDED.items()):
        got = {v.code for v in check_source(src, f"<seeded-{code}>")}
        status = "detected" if code in got else "MISSED"
        print(f"  {code}: seeded violation {status} (reported: {sorted(got)})")
        if code not in got:
            failed.append(code)
    if failed:
        print(f"self-test FAILED: {failed} not detected")
        return 1
    print(f"self-test passed: all {len(_SEEDED)} contract classes detect")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(_ROOT, "src", "repro")],
                    help="files or directories to check (default: src/repro)")
    ap.add_argument("--self-test", action="store_true",
                    help="seed one violation per contract class and verify "
                         "the analyzer reports each")
    ap.add_argument("--explain", action="store_true",
                    help="print the violation-code legend and exit")
    args = ap.parse_args(argv)
    if args.explain:
        for code, what in sorted(CODES.items()):
            print(f"  {code}  {what}")
        return 0
    if args.self_test:
        return self_test()
    violations = []
    for path in args.paths:
        violations.extend(check_path(path))
    for v in violations:
        print(v.render())
    if violations:
        print(f"{len(violations)} concurrency-contract violation(s)")
        return 1
    print("concurrency contracts: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())

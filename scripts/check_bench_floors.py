#!/usr/bin/env python
"""Assert committed performance floors against freshly generated BENCH_*.json.

CI used to check only that the benchmark JSONs *parse* — a regression that
halved throughput retention merged green. This script encodes the floors the
repo's benchmarks exist to defend:

* ``BENCH_sync.json``   — every registered sync algorithm's flat-engine HBM
  stream ratio (pytree bytes / flat bytes) stays >= 2.2x (DESIGN.md §3).
* ``BENCH_emb.json``    — the fused embedding path moves >= 5x fewer bytes
  than dense-take (3.5x on the CI tiny shapes; DESIGN.md §7.1).
* ``BENCH_elastic.json`` — the elasticity story (DESIGN.md §8-9):
  - shadow-mode healthy cohort keeps >= 85% of no-fault pace under a
    straggler (background sync never blocks on a degraded host);
  - with the closed-loop controller on (``straggler_auto``), the fixed_rate
    cohort ALSO recovers to >= 85% — the controller demotes the straggler
    out of the barrier within its detection window and the event log shows
    the full ``leave -> join -> activate`` cycle with demotion provenance;
  - mode-switch floors (DESIGN.md §14): the closed-loop ``mode_switch``
    scenario must complete the full fixed_rate -> shadow -> fixed_rate
    cycle, land the first switch inside the committed detection window,
    keep healthy throughput at the static-shadow floor, and replay
    bit-identically in ``HogwildSim`` (closed-loop, still deterministic);
  - chaos floors (DESIGN.md §10): ``sync_crash`` must show the supervisor
    detecting the dead shadow thread and restarting it within the committed
    recovery deadline, with sync_count STRICTLY increasing afterwards (a
    silently dead sync engine degenerates to unsynchronized Hogwild — the
    exact failure this PR exists to catch); ``ps_fail`` must show the failed
    embedding PS rehydrating from its background snapshot, the healthy
    cohort's throughput retained while it was down, and the final embedding
    table within the committed bounded-staleness distance of the span-
    matched no-fault oracle.
* ``BENCH_cache.json`` — the tiered embedding cache (DESIGN.md §11):
  - with the lookahead prefetcher on and a 25% hot budget on the zipf
    stream, steady-state hit rate >= 0.9 and stall fraction <= 0.1 (the
    shadow thread stages promotions before lookups land);
  - the store replays its whole lookup+update stream BITWISE equal to the
    full-device oracle, and a cached ``HogwildSim`` trajectory (loss stream
    + final packed table/acc) is bitwise-identical to the uncached run —
    the cache-invisibility contract checkpoints and the sync oracle depend
    on;
  - device residency stays at the committed hot fraction (the whole point:
    a table bigger than the box), and nothing is silently lost — zero
    dropped updates with every shard healthy.
* ``BENCH_pipeline.json`` — NestPipe-style step pipelining (DESIGN.md §13):
  - the cached depth-2 scenario keeps >= 1.2x step throughput over the
    serial depth-1 run (staging the hot-tier assembly behind the dense jit
    must actually buy back wall clock);
  - the hazard check admits overlap on >= 0.8 of the wide-table stream's
    shard-steps (a too-conservative check silently degenerates to serial
    and the throughput floor alone might pass on noise);
  - the pipelined trajectory is BITWISE-identical to serial — in the
    overlapping scenario AND in the all-indices-identical worst case,
    where overlap must be exactly 0 (the hazard check refuses to reorder
    conflicting steps rather than break exactness).

Stream-ratio floors are analytic (byte counts, machine-independent); the
elastic floors are wall-clock ratios of equal-length runs, which is why
``elastic_bench`` self-calibrates the ``straggler_auto`` span and the floors
are set well below the ~0.9+ both fast and slow boxes produce.

Inside GitHub Actions the script additionally emits one ``::error``
annotation per failed floor — anchored to the BENCH_*.json it was checked
against, so the failure shows up inline on the PR diff — and appends a
markdown verdict table (floor, committed value, measured value, margin) to
the job's ``$GITHUB_STEP_SUMMARY``. Both are no-ops when run locally.

Usage (CI regenerates the JSONs first — see .github/workflows/ci.yml):

    PYTHONPATH=src python scripts/check_bench_floors.py [--dir .]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import List, Optional

SYNC_STREAM_RATIO_MIN = 2.2
EMB_STREAM_RATIO_MIN = 5.0
EMB_STREAM_RATIO_MIN_TINY = 3.5
SHADOW_STRAGGLER_RETENTION_MIN = 0.85
AUTO_RETENTION_MIN = 0.85
AUTO_DEMOTE_WALL_MAX_S = 2.5
# Chaos floors (DESIGN.md §10). Recovery deadline: crash at shadow round ~2
# (well under 1s in), death detection is one supervisor check interval, the
# first restart backoff is 50 ms — 2.5 s is an order of magnitude of slack
# for a loaded CI box. Final-state parity is floored on the Adagrad
# accumulator mass ratio: acc is a monotone, near-deterministic meter of
# landed update energy (run-to-run Hogwild interleaving moves it ~3%;
# measured 1.03 on this config), while a PS quietly serving its quarter-way
# snapshot forever drags it to ~0.8 — 0.9 separates with margin on both
# sides. The raw table's Frobenius rel err CANNOT make that call (measured
# ~0.35 for a healthy recovery AND for the catastrophic rollback — pure
# interleaving noise), so it is kept only as a loose ceiling against
# outright divergence or NaN.
SYNC_RESTART_WALL_MAX_S = 2.5
SYNC_CRASH_RETENTION_MIN = 0.80
PS_FAIL_RETENTION_MIN = 0.75
PS_FAIL_EMB_PROGRESS_MIN = 0.9
PS_FAIL_EMB_REL_ERR_MAX = 0.6
# Tiered-cache floors (DESIGN.md §11). Hit rate: with the prefetcher
# peeking the queued batches the working set is resident before the lookup
# lands, so the shipping config measures ~1.0 (and the lookahead=0 contrast
# row ~0.6-0.7 from frequency placement alone) — 0.9 separates "lookahead
# works" from "LFU alone" with margin on both sides. Stall fraction floors
# the same property from the latency side. The bitwise floors are exact by
# construction (placement must not change a single bit) so any slack would
# only hide a real bug. hot_frac tolerance covers integer rounding of the
# row budget.
CACHE_HIT_RATE_MIN = 0.9
CACHE_STALL_FRACTION_MAX = 0.1
CACHE_HOT_FRAC_TOL = 0.01
# Step-pipelining floors (DESIGN.md §13). The speedup floor is set well
# under the ~2x a healthy box measures (the staged phase is host routing +
# hot-tier assembly — workload-relative, so slow CI boxes keep the ratio).
# Overlap rate is a COUNTED property of the deterministic (seed, iteration)
# stream — 0.825 exactly on this config — so 0.8 is a behavior pin, not a
# timing margin. Bitwise floors are exact by construction.
PIPELINE_SPEEDUP_MIN = 1.2
PIPELINE_OVERLAP_MIN = 0.8
# Mode-switch floors (DESIGN.md §14). The cycle floor pins the behavior
# (the controller must take the cohort to shadow under transient skew AND
# bring it back); the detection wall bounds meter warm-up + breach window +
# handoff on a loaded CI box (measured ~0.5 s on a healthy one); retention
# reuses the static-shadow bar — adapting must not cost healthy throughput
# vs just picking shadow; the replay floor is exact by construction (the
# sim drives the same state machine from a scripted trace, so a single
# differing bit means the closed loop lost determinism).
MODE_SWITCH_RETENTION_MIN = SHADOW_STRAGGLER_RETENTION_MIN
MODE_TO_SHADOW_WALL_MAX_S = 2.5
MODE_CYCLE = ["fixed_rate", "shadow", "fixed_rate"]


@dataclass
class FloorRow:
    """One floor verdict, structured so CI can render annotations and the
    step-summary table without re-parsing the human-readable message."""

    ok: bool
    msg: str          # the full PASS/FAIL line (console output)
    name: str         # short floor identifier, e.g. "elastic/shadow/straggler retention"
    committed: str    # the committed bound, rendered (e.g. ">= 0.85")
    measured: str     # the fresh measurement, rendered
    margin: str       # signed distance from the bound ("" when non-numeric)
    file: str         # the BENCH_*.json this floor was checked against


class Floors:
    def __init__(self) -> None:
        self.rows: List[FloorRow] = []
        self._file = ""

    def bench(self, file: str) -> None:
        """Set the BENCH_*.json context for subsequent checks (annotation
        anchor in CI)."""
        self._file = file

    def check(
        self,
        ok: bool,
        msg: str,
        *,
        name: Optional[str] = None,
        floor: object = None,
        measured: object = None,
        op: str = ">=",
    ) -> None:
        """Record one floor verdict. ``floor``/``measured``/``op`` are
        optional structure for the CI summary table: when both are numeric
        the margin is the signed distance INTO the passing region (positive
        == passing with room, for ``>=``, ``<=`` and ``==`` alike)."""
        if name is None:
            name = msg.split(":", 1)[0]
        committed = "" if floor is None else f"{op} {_render(floor)}"
        shown = "missing" if (measured is None and floor is not None) else _render(measured)
        margin = ""
        if isinstance(floor, (int, float)) and isinstance(measured, (int, float)):
            if op == ">=":
                margin = f"{measured - floor:+.3g}"
            elif op == "<=":
                margin = f"{floor - measured:+.3g}"
        self.rows.append(FloorRow(ok, msg, name, committed, shown, margin, self._file))

    @property
    def passes(self) -> List[str]:
        return [r.msg for r in self.rows if r.ok]

    @property
    def failures(self) -> List[str]:
        return [r.msg for r in self.rows if not r.ok]


def _render(v: object) -> str:
    if v is None:
        return ""
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def check_sync(d: dict, fl: Floors) -> None:
    results = d["results"]
    fl.check(len(results) >= 4, f"sync: {len(results)} algorithms benched (>= 4)")
    for algo, row in sorted(results.items()):
        ratio = row["stream_ratio"]
        fl.check(
            ratio >= SYNC_STREAM_RATIO_MIN,
            f"sync/{algo}: stream_ratio {ratio:.2f} >= {SYNC_STREAM_RATIO_MIN}",
            name=f"sync/{algo} stream ratio",
            floor=SYNC_STREAM_RATIO_MIN, measured=ratio,
        )


def check_emb(d: dict, fl: Floors) -> None:
    tiny = bool(d["config"].get("tiny"))
    floor = EMB_STREAM_RATIO_MIN_TINY if tiny else EMB_STREAM_RATIO_MIN
    ratio = d["results"]["fused"]["stream_ratio"]
    fl.check(ratio >= floor, f"emb/fused: stream_ratio {ratio:.2f} >= {floor}",
             name="emb/fused stream ratio", floor=floor, measured=ratio)
    fl.check(
        d["results"]["plan_sharded"]["bytes"] <= d["results"]["dense_take"]["bytes"],
        "emb/plan_sharded: moves no more bytes than dense_take",
    )


def _check_auto_events(mode: str, row: dict, slot: int, fl: Floors) -> None:
    events = row.get("events") or []
    kinds = [e[0] for e in events if e[1] == slot]
    fl.check(
        kinds[:3] == ["leave", "join", "activate"],
        f"elastic/{mode}/straggler_auto: slot {slot} event log is "
        f"leave -> join -> activate (got {kinds})",
    )
    leaves = [e for e in events if e[0] == "leave" and e[1] == slot]
    provenance = bool(leaves) and "straggler" in leaves[0][2]
    fl.check(
        provenance,
        f"elastic/{mode}/straggler_auto: demotion carries straggler provenance",
    )
    demote_wall = row.get("demote_wall_s")
    fl.check(
        demote_wall is not None and demote_wall <= AUTO_DEMOTE_WALL_MAX_S,
        f"elastic/{mode}/straggler_auto: demoted in {demote_wall}s "
        f"(<= {AUTO_DEMOTE_WALL_MAX_S}s — within the detection window)",
        name=f"elastic/{mode}/straggler_auto demote wall",
        floor=AUTO_DEMOTE_WALL_MAX_S, measured=demote_wall, op="<=",
    )
    fl.check(
        row.get("readmit_wall_s") is not None,
        f"elastic/{mode}/straggler_auto: re-admitted after the degradation ended",
    )


def _check_sync_crash(row: dict, fl: Floors) -> None:
    fl.check(
        row.get("sync_restarts", 0) >= 1,
        f"elastic/shadow/sync_crash: supervisor restarted the dead sync "
        f"thread ({row.get('sync_restarts')} restart(s))",
    )
    post = row.get("post_restart_syncs", 0)
    fl.check(
        post >= 1,
        f"elastic/shadow/sync_crash: sync_count strictly increased after "
        f"restart (+{post} syncs — a dead sync engine is unsynchronized "
        f"Hogwild otherwise)",
    )
    wall = row.get("restart_wall_s")
    fl.check(
        wall is not None and wall <= SYNC_RESTART_WALL_MAX_S,
        f"elastic/shadow/sync_crash: detected + restarted in {wall}s "
        f"(<= {SYNC_RESTART_WALL_MAX_S}s recovery deadline)",
        name="elastic/shadow/sync_crash restart wall",
        floor=SYNC_RESTART_WALL_MAX_S, measured=wall, op="<=",
    )
    fl.check(
        not row.get("sync_degraded", False),
        "elastic/shadow/sync_crash: one crash never exhausts the restart "
        "budget",
    )
    ret = row.get("healthy_retention", 0.0)
    fl.check(
        ret >= SYNC_CRASH_RETENTION_MIN,
        f"elastic/shadow/sync_crash: healthy retention {ret:.2f} >= "
        f"{SYNC_CRASH_RETENTION_MIN} (training never blocks on the sync "
        f"engine, dead or alive)",
        name="elastic/shadow/sync_crash retention",
        floor=SYNC_CRASH_RETENTION_MIN, measured=ret,
    )


def _check_ps_fail(mode: str, row: dict, ps_recover_s: float, fl: Floors) -> None:
    kinds = [e[0] for e in (row.get("shard_events") or [])]
    fl.check(
        kinds.count("ps_fail") >= 1 and kinds.count("ps_recover") >= 1,
        f"elastic/{mode}/ps_fail: shard failed and rehydrated from snapshot "
        f"(events: {kinds})",
    )
    down = row.get("ps_down_s")
    fl.check(
        down is not None and down <= ps_recover_s + 2.0,
        f"elastic/{mode}/ps_fail: shard back within {down}s "
        f"(<= provisioning delay {ps_recover_s}s + 2s slack)",
    )
    stale = sum(row.get("stale_lookups") or [0])
    fl.check(
        stale >= 1,
        f"elastic/{mode}/ps_fail: snapshot served {stale} bounded-staleness "
        f"lookups while the shard was down (trainers never blocked)",
    )
    ret = row.get("healthy_retention", 0.0)
    fl.check(
        ret >= PS_FAIL_RETENTION_MIN,
        f"elastic/{mode}/ps_fail: healthy retention {ret:.2f} >= "
        f"{PS_FAIL_RETENTION_MIN} (retry-then-drop beats blocking)",
        name=f"elastic/{mode}/ps_fail retention",
        floor=PS_FAIL_RETENTION_MIN, measured=ret,
    )
    prog = row.get("emb_progress_ratio")
    fl.check(
        prog is not None and prog >= PS_FAIL_EMB_PROGRESS_MIN,
        f"elastic/{mode}/ps_fail: Adagrad acc mass ratio "
        f"{prog if prog is None else round(prog, 4)} >= "
        f"{PS_FAIL_EMB_PROGRESS_MIN} vs the no-fault oracle (the bounded-"
        f"staleness parity bound: a never-rehydrated snapshot measures ~0.8)",
        name=f"elastic/{mode}/ps_fail progress ratio",
        floor=PS_FAIL_EMB_PROGRESS_MIN, measured=prog,
    )
    err = row.get("emb_rel_err")
    fl.check(
        err is not None and err <= PS_FAIL_EMB_REL_ERR_MAX,
        f"elastic/{mode}/ps_fail: table rel err "
        f"{err if err is None else round(err, 5)} <= "
        f"{PS_FAIL_EMB_REL_ERR_MAX} (divergence/NaN sanity ceiling; "
        f"~0.35 of Hogwild interleaving noise is expected)",
        name=f"elastic/{mode}/ps_fail rel err",
        floor=PS_FAIL_EMB_REL_ERR_MAX, measured=err, op="<=",
    )


def _check_mode_switch(row: dict, to_shadow_max_s: float, fl: Floors) -> None:
    cycle = row.get("mode_cycle") or []
    fl.check(
        cycle[: len(MODE_CYCLE)] == MODE_CYCLE,
        f"elastic/mode_switch: full {' -> '.join(MODE_CYCLE)} cycle "
        f"(got {cycle}) — transient skew sends the cohort to shadow, "
        f"recovery re-arms the barrier",
        name="elastic/mode_switch cycle",
        floor=" -> ".join(MODE_CYCLE), measured=" -> ".join(cycle), op="==",
    )
    wall = row.get("to_shadow_wall_s")
    fl.check(
        wall is not None and wall <= to_shadow_max_s,
        f"elastic/mode_switch: fixed_rate -> shadow in {wall}s "
        f"(<= {to_shadow_max_s}s — meter warm-up + breach window + handoff)",
        name="elastic/mode_switch detection wall",
        floor=to_shadow_max_s, measured=wall, op="<=",
    )
    back = row.get("back_wall_s")
    fl.check(
        back is not None,
        f"elastic/mode_switch: returned to fixed_rate after the straggler "
        f"recovered (at {back}s)",
        name="elastic/mode_switch return switch",
        floor="switch observed", measured=back, op="==",
    )
    ret = row.get("healthy_retention", 0.0)
    fl.check(
        ret >= MODE_SWITCH_RETENTION_MIN,
        f"elastic/mode_switch: healthy retention {ret:.2f} >= "
        f"{MODE_SWITCH_RETENTION_MIN} vs static shadow (adapting the mode "
        f"never costs healthy throughput)",
        name="elastic/mode_switch retention",
        floor=MODE_SWITCH_RETENTION_MIN, measured=ret,
    )
    rep = row.get("sim_replay") or {}
    fl.check(
        len(rep.get("mode_events") or []) >= 2,
        f"elastic/mode_switch: sim replay drove a full switch cycle "
        f"(mode_events: {rep.get('mode_events')})",
        name="elastic/mode_switch sim cycle",
        floor=2, measured=len(rep.get("mode_events") or []),
    )
    fl.check(
        bool(rep.get("trajectory_reproducible")),
        "elastic/mode_switch: closed-loop sim trajectory bit-identical "
        "across two fresh runs (losses AND mode events — the determinism "
        "contract)",
        name="elastic/mode_switch sim determinism",
        floor=True, measured=rep.get("trajectory_reproducible"), op="==",
    )


def check_elastic(d: dict, fl: Floors) -> None:
    results = d["results"]
    slot = d["config"]["R"] - 1
    ps_recover_s = (d["config"].get("chaos") or {}).get("ps_recover_s", 0.3)
    for mode in ("shadow", "fixed_rate"):
        scenarios = set(results[mode])
        want = {"no_fault", "no_fault_ref", "straggler", "crash", "straggler_auto", "ps_fail"}
        if mode == "shadow":
            want |= {"sync_crash"}
        fl.check(
            want <= scenarios,
            f"elastic/{mode}: all scenarios present (missing: "
            f"{sorted(want - scenarios)})",
        )
    fl.check(
        "mode_switch" in results,
        "elastic/mode_switch: closed-loop mode-switch scenario present",
        name="elastic/mode_switch present",
    )
    to_shadow_max_s = (d["config"].get("mode_switch") or {}).get(
        "to_shadow_max_s", MODE_TO_SHADOW_WALL_MAX_S)
    _check_mode_switch(results.get("mode_switch") or {}, to_shadow_max_s, fl)
    _check_sync_crash(results["shadow"].get("sync_crash") or {}, fl)
    for mode in ("shadow", "fixed_rate"):
        _check_ps_fail(mode, results[mode].get("ps_fail") or {}, ps_recover_s, fl)
    ret = results["shadow"]["straggler"]["healthy_retention"]
    fl.check(
        ret >= SHADOW_STRAGGLER_RETENTION_MIN,
        f"elastic/shadow/straggler: healthy retention {ret:.2f} >= "
        f"{SHADOW_STRAGGLER_RETENTION_MIN} (background sync shields the cohort)",
        name="elastic/shadow/straggler retention",
        floor=SHADOW_STRAGGLER_RETENTION_MIN, measured=ret,
    )
    for mode in ("shadow", "fixed_rate"):
        ret = results[mode]["straggler_auto"]["healthy_retention"]
        fl.check(
            ret >= AUTO_RETENTION_MIN,
            f"elastic/{mode}/straggler_auto: healthy retention {ret:.2f} >= "
            f"{AUTO_RETENTION_MIN} (closed-loop controller recovers the cohort)",
            name=f"elastic/{mode}/straggler_auto retention",
            floor=AUTO_RETENTION_MIN, measured=ret,
        )
        _check_auto_events(mode, results[mode]["straggler_auto"], slot, fl)


def check_cache(d: dict, fl: Floors) -> None:
    cfg = d["config"]
    la = cfg.get("lookahead", 2)
    hot = d["results"][f"lookahead{la}"]
    hit = hot["hit_rate"]
    fl.check(
        hit >= CACHE_HIT_RATE_MIN,
        f"cache/lookahead{la}: steady-state hit rate {hit:.3f} >= "
        f"{CACHE_HIT_RATE_MIN} (25% hot budget, zipf({cfg.get('zipf_a')}) — "
        f"the prefetcher stages the working set before lookups land)",
        name=f"cache/lookahead{la} hit rate",
        floor=CACHE_HIT_RATE_MIN, measured=hit,
    )
    stall = hot["stall_fraction"]
    fl.check(
        stall <= CACHE_STALL_FRACTION_MAX,
        f"cache/lookahead{la}: stall fraction {stall:.3f} <= "
        f"{CACHE_STALL_FRACTION_MAX} (cold hits beating the horizon stay "
        f"rare)",
        name=f"cache/lookahead{la} stall fraction",
        floor=CACHE_STALL_FRACTION_MAX, measured=stall, op="<=",
    )
    for name in (f"lookahead{la}", "lookahead0"):
        row = d["results"][name]
        fl.check(
            bool(row["bitwise_vs_oracle"]),
            f"cache/{name}: lookup+update stream BITWISE equal to the "
            f"full-device oracle (placement never changes a bit)",
        )
        fl.check(
            row.get("dropped_updates", 1) == 0,
            f"cache/{name}: zero dropped updates with every shard healthy",
        )
    frac = hot["device_bytes_frac"]
    want = cfg.get("hot_frac", 0.25)
    fl.check(
        abs(frac - want) <= CACHE_HOT_FRAC_TOL,
        f"cache/lookahead{la}: device residency {frac:.3f} == committed "
        f"hot_frac {want} (the table stays bigger than the box)",
    )
    fl.check(
        bool(d["results"]["sim"]["trajectory_bitwise"]),
        "cache/sim: cached training trajectory (loss stream + final packed "
        "table/acc) bitwise-identical to the uncached run",
    )


def check_pipeline(d: dict, fl: Floors) -> None:
    hot = d["results"]["cached_depth2"]
    speedup = hot["speedup_vs_depth1"]
    fl.check(
        speedup >= PIPELINE_SPEEDUP_MIN,
        f"pipeline/cached_depth2: step throughput {speedup:.2f}x >= "
        f"{PIPELINE_SPEEDUP_MIN}x vs serial depth 1 (staging the hot-tier "
        f"assembly behind the dense jit buys back wall clock)",
        name="pipeline/cached_depth2 speedup",
        floor=PIPELINE_SPEEDUP_MIN, measured=speedup,
    )
    overlap = hot["overlap_rate"]
    fl.check(
        overlap >= PIPELINE_OVERLAP_MIN,
        f"pipeline/cached_depth2: overlap rate {overlap:.3f} >= "
        f"{PIPELINE_OVERLAP_MIN} on the wide-table stream (the hazard "
        f"check admits real overlap instead of degenerating to serial)",
        name="pipeline/cached_depth2 overlap rate",
        floor=PIPELINE_OVERLAP_MIN, measured=overlap,
    )
    fl.check(
        bool(hot["trajectory_bitwise"]),
        "pipeline/cached_depth2: pipelined trajectory BITWISE-identical to "
        "serial (loss stream + final packed table/acc)",
    )
    fl.check(
        hot.get("staged_lookups", 0) > 0,
        f"pipeline/cached_depth2: {hot.get('staged_lookups')} lookups went "
        f"through the staged hot-tier entry point (the overlap is real, "
        f"not a stats artifact)",
    )
    wc = d["results"]["worst_case"]
    fl.check(
        wc["overlap_rate"] == 0.0 and wc["hazard_serialized"] > 0,
        f"pipeline/worst_case: all-identical indices fully serialize "
        f"(overlap {wc['overlap_rate']}, {wc['hazard_serialized']} hazards "
        f"— the hazard check refuses to reorder conflicting steps)",
    )
    fl.check(
        bool(wc["trajectory_bitwise"]),
        "pipeline/worst_case: worst-case trajectory stays bitwise-identical",
    )


def _annotate(fl: Floors) -> None:
    """One GitHub ``::error`` annotation per failed floor, anchored to the
    bench JSON it was checked against — the failure renders inline on the
    PR instead of only in a log nobody scrolls. No-op outside Actions."""
    if not os.environ.get("GITHUB_ACTIONS"):
        return
    for r in fl.rows:
        if not r.ok:
            print(f"::error file={r.file},title=bench floor: {r.name}::{r.msg}")


def _step_summary(fl: Floors) -> None:
    """Append the verdict table to the job's ``$GITHUB_STEP_SUMMARY`` so the
    committed-vs-measured margins are readable from the Actions UI without
    opening the raw log. No-op when the env var is unset (local runs)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    npass, nfail = len(fl.passes), len(fl.failures)
    verdict = "all floors hold" if nfail == 0 else f"{nfail} floor(s) BROKEN"
    esc = lambda s: s.replace("|", "\\|")  # noqa: E731 — table-cell escape
    lines = [
        "## Bench floors",
        "",
        f"**{npass} passed, {nfail} failed — {verdict}**",
        "",
        "| floor | committed | measured | margin | verdict |",
        "|---|---|---|---|---|",
    ]
    for r in fl.rows:
        lines.append(
            f"| {esc(r.name)} | {esc(r.committed) or '—'} "
            f"| {esc(r.measured) or '—'} | {r.margin or '—'} "
            f"| {'✅ pass' if r.ok else '❌ FAIL'} |")
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".", help="directory holding BENCH_*.json")
    ap.add_argument(
        "--skip",
        default="",
        help="comma-separated benches to skip (sync,emb,elastic,cache,pipeline)",
    )
    args = ap.parse_args()
    skip = {s for s in args.skip.split(",") if s}
    checks = {
        "sync": check_sync,
        "emb": check_emb,
        "elastic": check_elastic,
        "cache": check_cache,
        "pipeline": check_pipeline,
    }
    fl = Floors()
    for name, fn in checks.items():
        if name in skip:
            continue
        fl.bench(f"BENCH_{name}.json")
        path = os.path.join(args.dir, f"BENCH_{name}.json")
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            fl.check(False, f"{name}: unreadable {path}: {e}")
            continue
        try:
            fn(payload, fl)
        except Exception as e:  # any payload-shape surprise is a FAIL, not a crash
            fl.check(False, f"{name}: malformed payload ({type(e).__name__}: {e})")
    for msg in fl.passes:
        print(f"  PASS  {msg}")
    for msg in fl.failures:
        print(f"  FAIL  {msg}")
    _annotate(fl)
    _step_summary(fl)
    print(
        f"bench floors: {len(fl.passes)} passed, {len(fl.failures)} failed",
        file=sys.stderr,
    )
    return 1 if fl.failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh builders. Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — ShadowSync
    replicas live on the pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

"""End-to-end training launcher (laptop-scale execution).

Two entry modes:
  dlrm  — the paper's system: n trainers x m Hogwild threads on synthetic CTR,
          ShadowSync or fixed-rate sync, EASGD/MA/BMUF. Deterministic HogwildSim
          by default; --threaded runs the real-thread Algorithm-1 runner.
  lm    — ShadowSync applied to a small LM (any --arch, reduced config) on a
          Markov token stream: replicas train independently, a host shadow loop
          dispatches the separate sync_step program in the background.

Examples:
  PYTHONPATH=src python -m repro.launch.train dlrm --algo easgd --mode shadow \
      --trainers 4 --threads 4 --iters 300
  PYTHONPATH=src python -m repro.launch.train dlrm --threaded --crash-at 2:50 \
      --straggler 1:0.02 --iters 200          # fault-injection harness
  PYTHONPATH=src python -m repro.launch.train dlrm --membership-schedule \
      "fail@60:2,join@100:2" --iters 200      # deterministic elasticity
  PYTHONPATH=src python -m repro.launch.train dlrm --threaded \
      --sync-crash-at 2 --ps-fail-at 0:50 --iters 200   # chaos drill: the
      # supervisor restarts the dead sync thread, PS 0 serves its snapshot
      # while down and rehydrates (DESIGN.md §10)
  PYTHONPATH=src python -m repro.launch.train lm --arch minicpm-2b --replicas 2 \
      --iters 100 --sync-gap 5
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import dlrm_ctr
from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.core import algorithms, spmd
from repro.core.elp import elp
from repro.core.membership import FaultSpec
from repro.core.modeswitch import ModeConfig, ModeController
from repro.core.pipeline import PipelineConfig
from repro.core.runners import HogwildSim, ThreadedShadowRunner
from repro.core.scheduler import PolicyConfig, StragglerPolicy
from repro.core.sync import SyncConfig
from repro.embeddings.cache import CacheConfig


def _parse_slot_map(spec, cast):
    """ "slot:value,slot:value" -> {int: cast}."""
    out = {}
    if spec:
        for part in spec.split(","):
            slot, val = part.split(":")
            out[int(slot)] = cast(val)
    return out


def _parse_schedule(spec):
    """ "kind@iter:slot,..." -> [(iter, kind, slot)] (e.g. "fail@60:2")."""
    events = []
    if spec:
        for part in spec.split(","):
            kind, rest = part.split("@")
            it, slot = rest.split(":")
            events.append((int(it), kind, int(slot)))
    return events or None


def run_dlrm(args) -> dict:
    cfg = dlrm_ctr.tiny(embedding_dim=args.embedding_dim) if args.tiny else dlrm_ctr.CONFIG
    # Contradictory flags fail loudly, naming BOTH sides (silently ignoring
    # one is how a benchmark measures the wrong thing):
    if args.auto_mode and args.mode is not None:
        raise SystemExit(
            "--auto-mode and --mode contradict: --auto-mode hands the "
            f"shadow/fixed_rate choice to the ModeController, but --mode "
            f"{args.mode} pins it. Drop --mode (auto runs start in "
            "fixed_rate and switch on measured dispersion) or drop "
            "--auto-mode."
        )
    if args.straggler_until and not args.straggler:
        raise SystemExit(
            "--straggler-until without --straggler does nothing: "
            "--straggler-until bounds the injected sleep that --straggler "
            "declares, and no slot has one. Add --straggler "
            '"slot:seconds" or drop --straggler-until.'
        )
    # Auto-mode runs start in fixed_rate (the homogeneous-cohort choice —
    # best quality) and let the controller earn shadow from dispersion.
    mode = "fixed_rate" if args.auto_mode else (args.mode or "shadow")
    sync_cfg = SyncConfig(
        algo=args.algo, mode=mode, gap=args.sync_gap, alpha=args.alpha, delay=args.sync_delay
    )
    opt = optim.make(args.optimizer, args.lr)
    # Tiered embedding cache (DESIGN.md §11): --cache-rows N keeps only N
    # rows of each store device-resident; --lookahead K peeks K queued
    # batches so the background prefetcher hides the cold misses.
    cache = None
    if args.cache_rows is not None:
        cache = CacheConfig(hot_rows=args.cache_rows, lookahead=args.lookahead)
    # Step pipelining (DESIGN.md §13): --pipeline-depth 2 double-buffers the
    # embedding lookups behind a read-after-write hazard check — bitwise the
    # same trajectory, overlapped wall clock.
    if args.pipeline_depth < 1:
        raise SystemExit(f"--pipeline-depth must be >= 1, got {args.pipeline_depth}")
    pipeline = PipelineConfig(depth=args.pipeline_depth) if args.pipeline_depth > 1 else None
    print(
        f"DLRM {'tiny' if args.tiny else 'full'}: {cfg.n_sparse_features} sparse features, "
        f"{cfg.n_embedding_rows:,} embedding rows; "
        f"ELP = {elp(args.batch_size, args.threads, args.trainers):,}"
        + (f"; cache hot_rows={args.cache_rows} lookahead={args.lookahead}" if cache else "")
        + (f"; pipeline depth={args.pipeline_depth}" if pipeline else "")
    )
    if args.auto_demote and not args.threaded:
        raise SystemExit(
            "--auto-demote requires --threaded: the deterministic sim has no "
            "real pace to measure — script one with "
            "core.scheduler.StragglerSchedule instead"
        )
    if args.auto_mode and not args.threaded:
        raise SystemExit(
            "--auto-mode requires --threaded: the deterministic sim has no "
            "real dispersion to measure — script one with "
            "core.modeswitch.ControllerModeSchedule instead"
        )
    chaos = (
        args.sync_crash_at is not None
        or args.sync_stall_at is not None
        or args.ps_fail_at
        or args.raise_at
    )
    if chaos and not args.threaded:
        raise SystemExit(
            "--sync-crash-at/--sync-stall-at/--ps-fail-at/--raise-at are "
            "chaos injections into the REAL threads — they require --threaded"
        )
    if args.threaded:
        fault = FaultSpec(
            straggler_sleep_s=_parse_slot_map(args.straggler, float),
            straggler_until=_parse_slot_map(args.straggler_until, int),
            crash_at=_parse_slot_map(args.crash_at, int),
            join_at=_parse_slot_map(args.join_at, int),
            raise_at=_parse_slot_map(args.raise_at, int),
            sync_crash_at=args.sync_crash_at,
            sync_stall_at=args.sync_stall_at,
            sync_stall_s=args.sync_stall_s,
            ps_fail_at=_parse_slot_map(args.ps_fail_at, int),
            ps_recover_after_s=args.ps_recover_after,
        )
        policy = None
        if args.auto_demote:
            # hysteresis: re-admission demands strictly more than marginal
            # health (readmit_frac > eps_floor_frac, or the policy rejects
            # the config as flap-prone) — readmit_frac may exceed 1.0,
            # meaning "beat the live median"
            policy = StragglerPolicy(
                PolicyConfig(
                    eps_floor_frac=args.eps_floor,
                    readmit_frac=max(args.eps_floor * 1.5, 0.75),
                    probation_s=args.probation,
                ),
                n_slots=args.trainers,
            )
        mode_ctl = None
        if args.auto_mode:
            # tuning-free sync<->async switching (DESIGN.md §14): hysteresis
            # bands + min-dwell keep the cohort from flapping between modes
            mode_ctl = ModeController(
                ModeConfig(
                    skew_high=args.skew_high,
                    skew_low=args.skew_low,
                    min_dwell_s=args.mode_dwell,
                    window_s=args.mode_window,
                    start_mode=mode,
                )
            )
        runner = ThreadedShadowRunner(
            cfg,
            sync_cfg,
            n_trainers=args.trainers,
            batch_size=args.batch_size,
            optimizer=opt,
            seed=args.seed,
            sync_sleep_s=args.sync_sleep,
            fault_spec=fault,
            straggler_policy=policy,
            cache=cache,
            pipeline=pipeline,
            mode_controller=mode_ctl,
        )
        out = runner.run(args.iters)
        if args.auto_mode:
            print(
                f"mode: final={out['mode']} switches="
                + str(
                    [
                        (round(t - out["t_start"], 3), f"{frm}->{to}")
                        for t, frm, to, _ in out["mode_transitions"]
                    ]
                )
            )
        if out["cache_stats"]:
            cs = out["cache_stats"]
            hits = cs["hit_rows"] / max(cs["hit_rows"] + cs["miss_rows"], 1)
            print(
                f"cache: hit_rate={hits:.3f} stalls={cs['stall_lookups']}"
                f"/{cs['lookups']} prefetched={cs['prefetch_rows']} "
                f"migrated={(cs['bytes_h2d'] + cs['bytes_d2h'])/1e6:.2f}MB"
            )
        if out.get("pipeline_stats"):
            ps = out["pipeline_stats"]
            print(
                f"pipeline: overlap_rate={ps['overlap_rate']:.3f} "
                f"hazard_serialized={ps['hazard_serialized']} "
                f"drains={ps['drains']}"
            )
        print(
            f"EPS={out['eps']:.0f} (window {out['eps_window']:.0f})  "
            f"avg_sync_gap={out['avg_sync_gap']:.2f} "
            f"iters/trainer={out['iter_count']} "
            f"final train loss per trainer={[round(l,4) for l in out['train_loss']]}"
        )
        if out["membership_events"]:
            print(
                "membership:",
                [
                    (e.kind, e.slot) + ((e.reason,) if e.reason else ())
                    for e in out["membership_events"]
                ],
            )
        if out["supervision_events"]:
            print("supervision:", [(e.kind, e.name, e.reason) for e in out["supervision_events"]])
            print(
                f"  sync_restarts={out['sync_restarts']} "
                f"degraded={out['sync_degraded']} "
                f"final_foreground_sync={out['final_foreground_sync']}"
            )
        if out["shard_events"]:
            print(
                "embedding PS:",
                [
                    (e.kind, e.shard) + ((e.reason,) if e.reason else ())
                    for e in out["shard_events"]
                ],
            )
            print(
                f"  dropped_updates={out['dropped_updates']} "
                f"stale_lookups={out['stale_lookups']}"
            )
        return {
            k: v
            for k, v in out.items()
            if k
            not in ("w", "emb_state", "membership_events", "supervision_events", "shard_events")
        }
    sim = HogwildSim(
        cfg,
        sync_cfg,
        n_trainers=args.trainers,
        n_threads=args.threads,
        batch_size=args.batch_size,
        optimizer=opt,
        seed=args.seed,
        schedule=_parse_schedule(args.membership_schedule),
        cache=cache,
        pipeline=pipeline,
    )
    st0 = None
    if args.restore:
        st0 = sim.load_state(args.restore)
        print(f"elastic restore <- {args.restore} (step {st0.step}, " f"now R={sim.R})")
    t0 = time.perf_counter()
    out = sim.run(args.iters, log_every=args.log_every, state=st0)
    wall = time.perf_counter() - t0
    ev = sim.evaluate(out["state"], n_batches=args.eval_batches)
    examples = out["examples"]
    print(
        f"train loss {np.mean(out['train_loss'][:10]):.5f} -> "
        f"{np.mean(out['train_loss'][-10:]):.5f}; eval {ev:.5f}; "
        f"avg_sync_gap {out['avg_sync_gap']:.2f}; EPS(sim wall) {examples/wall:.0f}"
    )
    if "cache_stats" in out:
        cs = out["cache_stats"]
        hits = cs["hit_rows"] / max(cs["hit_rows"] + cs["miss_rows"], 1)
        print(
            f"cache: hit_rate={hits:.3f} stalls={cs['stall_lookups']}"
            f"/{cs['lookups']} prefetched={cs['prefetch_rows']} "
            f"migrated={(cs['bytes_h2d'] + cs['bytes_d2h'])/1e6:.2f}MB"
        )
    if out.get("pipeline_stats"):
        ps = out["pipeline_stats"]
        print(
            f"pipeline: overlap_rate={ps['overlap_rate']:.3f} "
            f"hazard_serialized={ps['hazard_serialized']} "
            f"drains={ps['drains']}"
        )
    if args.save:
        # engine-independent elastic checkpoint: dense replicas as the named
        # pytree (not the flat engine's packed buffer) + opaque algo state
        sim.save_state(args.save, out["state"])
        print(f"checkpoint -> {args.save}")
    return {
        "final_train": float(np.mean(out["train_loss"][-10:])),
        "eval": ev,
        "avg_sync_gap": out["avg_sync_gap"],
    }


def run_lm(args) -> dict:
    from repro.data import tokens as tok

    cfg = reduced(get_config(args.arch))
    opt = optim.make(args.optimizer, args.lr)
    R = args.replicas
    sync_cfg = SyncConfig(algo=args.algo, alpha=args.alpha).validate()
    key = jax.random.PRNGKey(args.seed)
    params = spmd.init_params(cfg, key)
    stack = spmd.stack_replicas(params, R)
    stack = jax.tree.map(jnp.copy, stack)
    opt_stack = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape).copy(), opt.init(params)
    )
    train_step = jax.jit(spmd.make_train_step(cfg, opt, "shadow"))
    sync_step = jax.jit(spmd.make_sync_step(cfg, sync_cfg))
    # Opaque per-algorithm state (sync-PS copy, momentum, counter, or None).
    algo_state = algorithms.get(args.algo).init_state(params, sync_cfg)

    trans = tok.make_transition(cfg.vocab_size, seed=args.seed)
    losses = []
    t0 = time.perf_counter()
    for it in range(args.iters):
        b = tok.gen_batch(trans, args.seed, it, args.batch_size * R, args.seq_len)
        batch = jax.tree.map(lambda x: x.reshape(R, args.batch_size, *x.shape[1:]), b)
        stack, opt_stack, loss = train_step(stack, opt_stack, batch)
        losses.append(float(jnp.mean(loss)))
        # Background cadence (host loop quantization of the shadow thread).
        if (it + 1) % args.sync_gap == 0:
            stack, algo_state = sync_step(stack, algo_state)
    wall = time.perf_counter() - t0
    print(
        f"{args.arch} x{R} replicas [{args.algo}]: loss "
        f"{np.mean(losses[:5]):.4f} -> {np.mean(losses[-5:]):.4f} "
        f"({args.iters} iters, {wall:.1f}s, "
        f"EPS {args.iters*args.batch_size*R/wall:.1f})"
    )
    return {"loss_start": float(np.mean(losses[:5])), "loss_end": float(np.mean(losses[-5:]))}


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("dlrm")
    d.add_argument("--algo", choices=list(algorithms.names()), default="easgd")
    d.add_argument(
        "--mode",
        choices=["shadow", "fixed_rate"],
        default=None,
        help="pin the sync mode (default shadow). Contradicts "
        "--auto-mode, which owns the choice at runtime",
    )
    d.add_argument("--trainers", type=int, default=4)
    d.add_argument("--threads", type=int, default=4)
    d.add_argument("--batch-size", type=int, default=128)
    d.add_argument("--iters", type=int, default=200)
    d.add_argument("--sync-gap", type=int, default=5)
    d.add_argument("--sync-delay", type=int, default=1)
    d.add_argument("--sync-sleep", type=float, default=0.0)
    d.add_argument("--alpha", type=float, default=0.5)
    d.add_argument("--lr", type=float, default=0.02)
    d.add_argument("--optimizer", default="adagrad")
    d.add_argument("--embedding-dim", type=int, default=16)
    d.add_argument("--tiny", action="store_true", default=True)
    d.add_argument("--full", dest="tiny", action="store_false")
    d.add_argument("--threaded", action="store_true")
    d.add_argument("--eval-batches", type=int, default=10)
    d.add_argument("--log-every", type=int, default=50)
    d.add_argument("--seed", type=int, default=0)
    d.add_argument("--save", default=None)
    d.add_argument(
        "--restore", default=None, help="elastic restore: checkpoint R may differ from --trainers"
    )
    d.add_argument(
        "--membership-schedule",
        default=None,
        help='deterministic elasticity (sim): "fail@60:2,join@100:2"',
    )
    d.add_argument("--crash-at", default=None, help='threaded fault injection: "slot:iter,..."')
    d.add_argument("--join-at", default=None, help='threaded mid-run join: "slot:iter,..."')
    d.add_argument(
        "--straggler", default=None, help='threaded straggler sleep seconds: "slot:0.02,..."'
    )
    d.add_argument(
        "--straggler-until",
        default=None,
        help="end of the straggler sleep, per slot local iteration:"
        ' "slot:40,..." (absent = degraded all run)',
    )
    # chaos injection into the supervised failure domains (--threaded only;
    # DESIGN.md §10): the supervisor detects/restarts/recovers, the run
    # report prints the supervision + PS event logs
    d.add_argument(
        "--raise-at",
        default=None,
        help='chaos: raise inside trainer threads, "slot:iter,..."'
        " — the run re-raises with slot provenance",
    )
    d.add_argument(
        "--sync-crash-at",
        type=int,
        default=None,
        help="chaos: kill the shadow/sync thread at this round "
        "(mode=shadow); the supervisor restarts it",
    )
    d.add_argument(
        "--sync-stall-at",
        type=int,
        default=None,
        help="chaos: wedge the shadow thread at this round; the "
        "supervisor detects the stale heartbeat and replaces "
        "it (the zombie is generation-fenced)",
    )
    d.add_argument(
        "--sync-stall-s", type=float, default=10.0, help="how long the wedged shadow thread sleeps"
    )
    d.add_argument(
        "--ps-fail-at",
        default=None,
        help='chaos: kill embedding PS shards, "shard:iter,..." — '
        "lookups serve the background snapshot, updates "
        "retry-then-drop, recovery rehydrates",
    )
    d.add_argument(
        "--ps-recover-after",
        type=float,
        default=0.25,
        help="provisioning delay before a failed PS rehydrates " "from its snapshot",
    )
    d.add_argument(
        "--auto-demote",
        action="store_true",
        help="closed-loop straggler controller (threaded only): "
        "demote a slot whose busy-clock EPS falls below "
        "--eps-floor x live median, re-admit after probation",
    )
    d.add_argument(
        "--eps-floor",
        type=float,
        default=0.5,
        help="demotion floor as a fraction of the live median EPS",
    )
    d.add_argument(
        "--probation",
        type=float,
        default=1.0,
        help="seconds a demoted slot must probe healthy before " "re-admission",
    )
    d.add_argument(
        "--auto-mode",
        action="store_true",
        help="tuning-free sync<->async switching (threaded only, "
        "DESIGN.md §14): start fixed_rate, switch the whole "
        "cohort to shadow when busy-EPS dispersion crosses "
        "--skew-high, and back once it falls to --skew-low",
    )
    d.add_argument(
        "--skew-high",
        type=float,
        default=2.0,
        help="dispersion above which fixed_rate hands off to "
        "shadow (max/median busy-EPS spread)",
    )
    d.add_argument(
        "--skew-low",
        type=float,
        default=1.3,
        help="dispersion at/below which shadow hands back to "
        "fixed_rate (must be < --skew-high: hysteresis)",
    )
    d.add_argument(
        "--mode-dwell",
        type=float,
        default=2.0,
        help="seconds a freshly entered mode is held regardless " "of the signal (anti-flap)",
    )
    d.add_argument(
        "--mode-window",
        type=float,
        default=0.5,
        help="seconds a dispersion breach must persist before " "the controller acts on it",
    )
    d.add_argument(
        "--cache-rows",
        type=int,
        default=None,
        help="tiered embedding cache: device-resident hot rows "
        "per store (absent = whole table on device)",
    )
    d.add_argument(
        "--lookahead",
        type=int,
        default=2,
        help="batches the background prefetcher peeks ahead "
        "(0 = no prefetch; cold rows stall synchronously)",
    )
    d.add_argument(
        "--pipeline-depth",
        type=int,
        default=1,
        help="step-pipeline depth (DESIGN.md §13): 2 double-"
        "buffers hazard-checked embedding lookups one step "
        "ahead; 1 = serial (bitwise-identical either way)",
    )

    l = sub.add_parser("lm")
    l.add_argument("--arch", choices=list(ARCH_IDS), default="minicpm-2b")
    l.add_argument("--algo", choices=list(algorithms.names()), default="easgd")
    l.add_argument("--replicas", type=int, default=2)
    l.add_argument("--batch-size", type=int, default=8)
    l.add_argument("--seq-len", type=int, default=128)
    l.add_argument("--iters", type=int, default=60)
    l.add_argument("--sync-gap", type=int, default=5)
    l.add_argument("--alpha", type=float, default=0.5)
    l.add_argument("--lr", type=float, default=1e-3)
    l.add_argument("--optimizer", default="adam")
    l.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    out = run_dlrm(args) if args.cmd == "dlrm" else run_lm(args)
    print(json.dumps(out, default=float))


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x input-shape) combination on
the production meshes, print memory/cost analysis, and emit roofline terms.

Usage:
    python -m repro.launch.dryrun --arch granite-20b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun.json
    python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k --multi-pod --mode shadow
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.sharding import ctx as shctx
from repro.core import spmd
from repro.core.sync import SyncConfig
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro import optim
from repro.roofline import analysis as RA

# Archs whose serve_step at 500k context is sub-quadratic (DESIGN.md §4).
LONG_CONTEXT_OK = {"mamba2-780m", "jamba-1.5-large-398b", "phi3-medium-14b"}


def resolve_config(arch: str, shape_name: str):
    if arch == "phi3-medium-14b" and shape_name == "long_500k":
        from repro.configs.phi3_medium_14b import CONFIG_SWA

        return CONFIG_SWA  # sliding-window variant (DESIGN.md §4)
    return get_config(arch)


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "full-attention arch: 500k dense KV decode is quadratic-cost (skip per DESIGN.md)"
    return None


def build(
    cfg,
    shape_name: str,
    mesh,
    *,
    mode: str = "syncdp",
    optimizer: str = "adagrad",
    n_replicas: int = 2,
    n_microbatches: int = 8,
    shape_override=None,
    fsdp: bool = True,
    grad_dtype: str = "float32",
    remat_policy: str = "full",
):
    """Returns (step_fn, args_sds tuple, donate).

    ``fsdp`` / ``grad_dtype`` / ``n_microbatches`` are the §Perf hillclimb knobs
    (see EXPERIMENTS.md §Perf iteration log)."""
    shape = shape_override or INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        opt = optim.make(optimizer, 1e-3)
        params = SP.param_structs(cfg, mesh, mode=mode, n_replicas=n_replicas, fsdp=fsdp)
        opt_state = SP.opt_structs(
            opt, params, mesh, fsdp=fsdp, replica_axis="pod" if mode == "shadow" else None
        )
        batch = SP.train_batch_structs(cfg, shape, mesh, mode=mode, n_replicas=n_replicas)
        step = spmd.make_train_step(
            cfg,
            opt,
            mode,
            n_microbatches=n_microbatches,
            grad_dtype=grad_dtype,
            remat_policy=remat_policy,
        )
        return step, (params, opt_state, batch), (0, 1)
    if shape.kind == "prefill":
        params = SP.param_structs(cfg, mesh, mode="syncdp", fsdp=fsdp)
        batch = SP.train_batch_structs(cfg, shape, mesh, mode="syncdp")
        step = spmd.make_prefill_step(cfg, shape.seq_len)
        return step, (params, batch), ()
    # decode
    params = SP.param_structs(cfg, mesh, mode="syncdp", fsdp=fsdp)
    cache = SP.cache_structs(cfg, shape.global_batch, shape.seq_len, mesh)
    db = SP.decode_batch_structs(cfg, shape, mesh)
    step = spmd.make_decode_step(cfg)
    return step, (params, cache, db["token"], db["pos"]), (1,)


def build_sync_step(arch: str, mesh, *, algo: str = "easgd", n_replicas: int = 2):
    """The background program (ShadowSync's own artifact). Uniform across the
    algorithm registry: sync_step(params_stack, algo_state)."""
    cfg = get_config(arch)
    sync_cfg = SyncConfig(algo=algo).validate()
    params = SP.param_structs(cfg, mesh, mode="shadow", n_replicas=n_replicas)
    state = SP.sync_state_structs(sync_cfg, SP.param_structs(cfg, mesh, mode="syncdp"), mesh)
    sync = spmd.make_sync_step(cfg, sync_cfg)
    return sync, (params, state), (0, 1)


def _depth_variant(cfg, n_units: int):
    """Same arch with n_units unit-repeats of depth (for cost extrapolation)."""
    import dataclasses

    unit = len(cfg.layer_pattern)
    upd = {"n_layers": unit * n_units}
    if cfg.encoder is not None:
        upd["encoder"] = dataclasses.replace(cfg.encoder, n_layers=n_units)
    return dataclasses.replace(cfg, **upd)


def _batch_axes(mesh, mode):
    if mode != "shadow" and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def _compile_cost(
    cfg,
    shape_name,
    mesh,
    *,
    mode,
    optimizer,
    shape_override=None,
    fsdp=True,
    grad_dtype="float32",
    remat_policy="full",
):
    from repro.models.layers import set_unroll_scans

    step, args, donate = build(
        cfg,
        shape_name,
        mesh,
        mode=mode,
        optimizer=optimizer,
        n_microbatches=1,
        shape_override=shape_override,
        fsdp=fsdp,
        grad_dtype=grad_dtype,
        remat_policy=remat_policy,
    )
    set_unroll_scans(True)
    try:
        with shctx.activation_mesh(mesh, batch_axes=_batch_axes(mesh, mode)):
            compiled = jax.jit(step, donate_argnums=donate).lower(*args).compile()
    finally:
        set_unroll_scans(False)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    colls = RA.collective_bytes(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(sum(colls.values())),
    )


def extrapolate_cost(
    cfg, shape_name, mesh, *, mode, optimizer, fsdp=True, grad_dtype="float32", remat_policy="full"
):
    """XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, ignoring
    trip count; roofline costs therefore come from small fully-UNROLLED probe
    compiles, fit and extrapolated (EXPERIMENTS.md §Dry-run methodology):

    - depth: cost = a + b * n_units (probes at 1- and 2-unit depth);
    - prefill_32k additionally extrapolates over sequence with a bilinear model
      per depth coefficient, cost_S = u*S + v*S^2, fit from S=4k and S=8k probes
      (unrolling 128 SSD chunks / 32 attention chunks at 32k directly is
      prohibitively slow to compile). Attention is the only quadratic-in-S term;
      everything else is linear, so the 2-point quadratic fit is exact for the
      model family."""
    import dataclasses as _dc

    unit = len(cfg.layer_pattern)
    repeats = cfg.n_layers // unit
    shape = INPUT_SHAPES[shape_name]

    def cost(n_units, seq=None):
        c = _depth_variant(cfg, n_units)
        ov = _dc.replace(shape, seq_len=seq) if seq else None
        return _compile_cost(
            c,
            shape_name,
            mesh,
            mode=mode,
            optimizer=optimizer,
            shape_override=ov,
            fsdp=fsdp,
            grad_dtype=grad_dtype,
            remat_policy=remat_policy,
        )

    if shape.kind == "prefill" and shape.seq_len > 8192:
        s1, s2, s_full = 4096, 8192, shape.seq_len
        c11, c12 = cost(1, s1), cost(1, s2)
        if repeats == 1:
            c21, c22 = c11, c12
        else:
            c21, c22 = cost(2, s1), cost(2, s2)

        def fit(f1, f2, s1, s2, s):
            v = (f2 / s2 - f1 / s1) / (s2 - s1)
            u = f1 / s1 - v * s1
            return u * s + v * s * s

        out = []
        for i in range(3):  # flops, bytes, collective bytes
            layer1, layer2 = c21[i] - c11[i], c22[i] - c12[i]
            base1, base2 = c11[i] - layer1, c12[i] - layer2
            layer_full = fit(layer1, layer2, s1, s2, s_full) if repeats > 1 else 0.0
            base_full = fit(base1, base2, s1, s2, s_full)
            total = base_full + repeats * (
                layer_full if repeats > 1 else fit(c11[i], c12[i], s1, s2, s_full) - base_full
            )
            out.append(max(total, 0.0))
        return tuple(out)

    if repeats == 1:
        return cost(1)
    c1, c2 = cost(1), cost(2)
    # clamp: a slightly negative fitted slope (constant-dominated programs,
    # e.g. tiny-model decode) must not extrapolate below zero
    return tuple(max(f1 + (f2 - f1) * (repeats - 1), 0.0) for f1, f2 in zip(c1, c2))


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mode: str = "syncdp",
    optimizer: str = "adagrad",
    verbose: bool = True,
    sync_algo: Optional[str] = None,
    extrapolate: bool = True,
    fsdp: bool = True,
    grad_dtype: str = "float32",
    n_microbatches: int = 8,
    capacity_factor: Optional[float] = None,
    parallel_block: bool = False,
    remat_policy: str = "full",
    tag_suffix: str = "",
) -> Dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = should_skip(arch, shape_name)
    tag = f"{arch} x {shape_name} x {mesh_name} [{sync_algo or mode}]{tag_suffix}"
    if skip:
        if verbose:
            print(f"SKIP  {tag}: {skip}")
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "mode": mode,
            "status": "skipped",
            "reason": skip,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        cfg = resolve_config(arch, shape_name)
        import dataclasses as _dc

        if capacity_factor is not None and cfg.moe is not None:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, capacity_factor=capacity_factor))
        if parallel_block:
            cfg = _dc.replace(cfg, parallel_block=True)
        if sync_algo:
            step, args, donate = build_sync_step(arch, mesh, algo=sync_algo)
        else:
            step, args, donate = build(
                cfg,
                shape_name,
                mesh,
                mode=mode,
                optimizer=optimizer,
                fsdp=fsdp,
                grad_dtype=grad_dtype,
                n_microbatches=n_microbatches,
                remat_policy=remat_policy,
            )
        with shctx.activation_mesh(mesh, batch_axes=_batch_axes(mesh, mode)):
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        mf = RA.model_flops_estimate(cfg, INPUT_SHAPES[shape_name]) if not sync_algo else 0.0
        r = RA.analyze(
            compiled,
            arch=arch,
            shape=shape_name,
            mesh_name=mesh_name,
            mode=(f"sync:{sync_algo}" if sync_algo else mode),
            chips=chips,
            model_flops=mf,
        )
        raw = (r.flops_per_chip, r.bytes_per_chip, r.collective_bytes_per_chip)
        # Roofline terms are reported for the single-pod mesh only (§Roofline);
        # the multi-pod pass proves lowering + records memory.
        if multi_pod:
            extrapolate = False
        if extrapolate and not sync_algo:
            fl, by, co = extrapolate_cost(
                cfg,
                shape_name,
                mesh,
                mode=mode,
                optimizer=optimizer,
                fsdp=fsdp,
                grad_dtype=grad_dtype,
                remat_policy=remat_policy,
            )
            r.flops_per_chip, r.bytes_per_chip, r.collective_bytes_per_chip = fl, by, co
            r.notes = (
                r.notes
                + " cost depth-extrapolated (scan trip-count fix); " f"raw flops/chip={raw[0]:.3e}"
            ).strip()
        row = r.row()
        row.update(status="ok", compile_s=round(time.time() - t0, 1))
        if verbose:
            mem = compiled.memory_analysis()
            print(f"OK    {tag}  compile={row['compile_s']}s")
            print(
                f"      mem/device: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
                f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
                f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB"
            )
            print(
                f"      roofline: t_comp={r.t_compute*1e3:.2f}ms "
                f"t_mem={r.t_memory*1e3:.2f}ms t_coll={r.t_collective*1e3:.2f}ms "
                f"-> {r.bottleneck}-bound; useful_flops={r.useful_flops_ratio:.2f}"
            )
            print(f"      collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in r.collectives.items() if v} }")
        return row
    except Exception as e:
        if verbose:
            print(f"FAIL  {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "mode": mode,
            "status": "fail",
            "error": f"{type(e).__name__}: {e}",
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", choices=["syncdp", "shadow"], default="syncdp")
    ap.add_argument(
        "--sync-algo",
        choices=["easgd", "ma", "bmuf"],
        default=None,
        help="lower the background sync_step instead of train/serve",
    )
    ap.add_argument("--optimizer", default="adagrad")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    # §Perf hillclimb knobs (see benchmarks/hillclimb.py, EXPERIMENTS.md §Perf)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--grad-dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--parallel-block", action="store_true")
    ap.add_argument("--remat-policy", choices=["full", "save_comm"], default="full")
    args = ap.parse_args()

    rows = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rows.append(
                    run_one(
                        arch,
                        shape,
                        multi_pod=mp,
                        mode=args.mode,
                        optimizer=args.optimizer,
                        sync_algo=args.sync_algo,
                        fsdp=not args.no_fsdp,
                        grad_dtype=args.grad_dtype,
                        n_microbatches=args.microbatches,
                        capacity_factor=args.capacity_factor,
                        parallel_block=args.parallel_block,
                        remat_policy=args.remat_policy,
                    )
                )
                if args.out:  # incremental: survive interruption
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(rows, f, indent=1, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"wrote {len(rows)} rows to {args.out}")
    n_ok = sum(r.get("status") == "ok" for r in rows)
    n_skip = sum(r.get("status") == "skipped" for r in rows)
    n_fail = sum(r.get("status") == "fail" for r in rows)
    print(f"\nSummary: {n_ok} ok, {n_skip} skipped, {n_fail} failed / {len(rows)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Batched serving launcher: prefill a batch of prompts, then decode greedily.

Runs a reduced config end-to-end on CPU (the full configs are exercised via the
dry-run only). Demonstrates the prefill -> decode_step cache handoff that the
decode_32k / long_500k dry-run shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --batch 4 \
      --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.core import spmd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    # independent streams: reusing one key would correlate the prompts (and
    # the vlm/audio prefix noise) with the weight init
    k_init, k_prompt, k_prefix = jax.random.split(key, 3)
    params = spmd.init_params(cfg, k_init)
    n_prefix = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
    s_max = n_prefix + args.prompt_len + args.gen
    B = args.batch

    prompts = jax.random.randint(k_prompt, (B, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = (
            jax.random.normal(k_prefix, (B, cfg.frontend.n_tokens, cfg.d_model)) * 0.1
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k_prefix, (B, cfg.encoder.n_ctx, cfg.d_model)) * 0.1

    prefill = jax.jit(spmd.make_prefill_step(cfg, s_max))
    decode = jax.jit(spmd.make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.asarray(n_prefix + args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(
        f"{args.arch}: prefill {B}x{args.prompt_len} in {t_prefill*1e3:.1f}ms; "
        f"decoded {args.gen-1} steps in {t_decode*1e3:.1f}ms "
        f"({(args.gen-1)*B/t_decode:.1f} tok/s batched)"
    )
    print("first sequence:", gen[0][:16], "...")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct input builders for every (arch x input-shape x mesh x mode).

Nothing here allocates: params/optimizer/cache structures come from
``jax.eval_shape`` and are annotated with NamedShardings from sharding/rules.py.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core import spmd
from repro.sharding import rules


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, P(*spec)))


def _annotate(tree_sds, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds,
        shardings,
    )


def batch_axes(mesh, mode: str) -> Tuple[str, ...]:
    has_pod = "pod" in mesh.axis_names
    if mode == "shadow":
        return ("data",)  # replica dim carries the pod axis
    return ("pod", "data") if has_pod else ("data",)


def param_structs(
    cfg: ArchConfig, mesh, *, mode: str = "syncdp", fsdp: bool = True, n_replicas: int = 2
) -> Any:
    sds = jax.eval_shape(lambda: spmd.init_params(cfg, jax.random.PRNGKey(0)))
    replica_axis = None
    if mode == "shadow":
        sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct((n_replicas,) + s.shape, s.dtype), sds)
        replica_axis = "pod"
    shardings = rules.build_param_specs(
        sds, mesh, fsdp_axis="data" if fsdp else None, replica_axis=replica_axis
    )
    return _annotate(sds, shardings)


def opt_structs(opt, params_sds, mesh, *, replica_axis=None, fsdp: bool = True) -> Any:
    sds = jax.eval_shape(opt.init, params_sds)
    shardings = rules.build_param_specs(
        sds, mesh, fsdp_axis="data" if fsdp else None, replica_axis=replica_axis
    )
    return _annotate(sds, shardings)


def sync_state_structs(sync_cfg, params_sds, mesh, *, fsdp: bool = True) -> Any:
    """Sharded structs for a registered sync algorithm's opaque state (the
    sync-PS copy, momentum buffers, a counter, or None), derived from the
    SINGLE-replica param structs — whatever the algorithm's ``init_state``
    builds, sharded like optimizer state."""
    from repro.core import algorithms

    algo = algorithms.get(sync_cfg.algo)
    sds = jax.eval_shape(lambda p: algo.init_state(p, sync_cfg), params_sds)
    if sds is None:
        return None
    shardings = rules.build_param_specs(
        sds, mesh, fsdp_axis="data" if fsdp else None, replica_axis=None
    )
    return _annotate(sds, shardings)


def train_batch_structs(
    cfg: ArchConfig, shape: InputShape, mesh, *, mode: str = "syncdp", n_replicas: int = 2
) -> Dict[str, Any]:
    bx = batch_axes(mesh, mode)
    ax = bx if len(bx) > 1 else bx[0]
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def tok_spec(b, s_text):
        if mode == "shadow":
            return _sds((n_replicas, b // n_replicas, s_text), jnp.int32, mesh, ("pod", ax, None))
        return _sds((b, s_text), jnp.int32, mesh, (ax, None))

    if cfg.family == "vlm":
        n_img = cfg.frontend.n_tokens
        s_text = S - n_img
        batch = {"tokens": tok_spec(B, s_text)}
        if mode == "shadow":
            batch["prefix_embeds"] = _sds(
                (n_replicas, B // n_replicas, n_img, cfg.d_model),
                dtype,
                mesh,
                ("pod", ax, None, None),
            )
        else:
            batch["prefix_embeds"] = _sds((B, n_img, cfg.d_model), dtype, mesh, (ax, None, None))
        return batch
    if cfg.family == "audio":
        n_ctx = cfg.encoder.n_ctx
        batch = {"tokens": tok_spec(B, S)}
        if mode == "shadow":
            batch["frames"] = _sds(
                (n_replicas, B // n_replicas, n_ctx, cfg.d_model),
                dtype,
                mesh,
                ("pod", ax, None, None),
            )
        else:
            batch["frames"] = _sds((B, n_ctx, cfg.d_model), dtype, mesh, (ax, None, None))
        return batch
    return {"tokens": tok_spec(B, S)}


def _cache_sharding(path, leaf, mesh_shape) -> P:
    names = rules._path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)
    data_n, model_n = mesh_shape.get("data", 1), mesh_shape.get("model", 1)
    spec = [None] * nd
    if nd >= 2 and shape[1] % data_n == 0 and shape[1] >= data_n:
        spec[1] = "data"
        data_used = True
    else:
        data_used = False
    if name in ("k", "v") and nd == 5:  # (L, B, S, kv, hd)
        if not data_used and shape[2] % data_n == 0:
            spec[2] = "data"
        if shape[3] % model_n == 0:
            spec[3] = "model"
        elif shape[4] % model_n == 0:
            spec[4] = "model"
    elif name == "ssm" and nd == 5:  # (L, B, H, p, n)
        if shape[2] % model_n == 0:
            spec[2] = "model"
    elif name == "conv" and nd == 4:  # (L, B, K, C)
        if shape[3] % model_n == 0:
            spec[3] = "model"
    return P(*spec)


def cache_structs(cfg: ArchConfig, batch: int, s_max: int, mesh) -> Any:
    sds = jax.eval_shape(lambda: spmd.init_cache(cfg, batch, s_max))
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    shardings = jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _cache_sharding(path, leaf, mesh_shape)),
        sds,
    )
    return _annotate(sds, shardings)


def decode_batch_structs(cfg: ArchConfig, shape: InputShape, mesh) -> Dict[str, Any]:
    B = shape.global_batch
    data_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    tok_spec = ("data",) if B % data_n == 0 and B >= data_n else (None,)
    return {
        "token": _sds((B,), jnp.int32, mesh, tok_spec),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }

"""Architecture configuration system.

Every assigned architecture gets one module in this package exporting CONFIG.
``get_config(name)`` resolves by arch id, ``reduced(cfg)`` produces the smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

ARCH_IDS = (
    "mamba2-780m",
    "jamba-1.5-large-398b",
    "granite-34b",
    "phi3-medium-14b",
    "kimi-k2-1t-a32b",
    "minicpm-2b",
    "llava-next-34b",
    "whisper-base",
    "granite-20b",
    "phi3.5-moe-42b-a6.6b",
)

_MODULE_FOR = {
    "mamba2-780m": "mamba2_780m",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "granite-34b": "granite_34b",
    "phi3-medium-14b": "phi3_medium_14b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "minicpm-2b": "minicpm_2b",
    "llava-next-34b": "llava_next_34b",
    "whisper-base": "whisper_base",
    "granite-20b": "granite_20b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "dlrm-ctr": "dlrm_ctr",
}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # Every ``every``-th layer is MoE (1 = all layers). Jamba uses 2.
    every: int = 1
    n_shared_experts: int = 0
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 1e-3
    dt_max: float = 1e-1


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) models."""

    n_layers: int = 6
    n_ctx: int = 1500  # frames after the (stubbed) conv frontend


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend: input_specs() provides precomputed embeddings."""

    kind: str  # "vision" | "audio"
    n_tokens: int  # patch/frame embeddings prepended / consumed


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Per-layer kind pattern, tiled over n_layers: 'A' attention, 'M' mamba.
    layer_pattern: str = "A"
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # PaLM-style parallel block: y = x + mixer(norm(x)) + ffn(norm(x)).
    # Beyond-paper perf variant: both branches' partial sums share ONE
    # tensor-parallel all-reduce per layer instead of two (see §Perf).
    parallel_block: bool = False
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.layer_pattern
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.n_layers])

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        # Jamba convention: MoE on odd layer indices when every=2.
        return (i % self.moe.every) == (self.moe.every - 1)

    def supports_long_context(self) -> bool:
        """True when serve_step at 500k context is sub-quadratic."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders (whisper: enc-dec)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    updates = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=64,
        dtype="float32",
    )
    updates["n_kv_heads"] = min(cfg.n_kv_heads, updates["n_heads"])
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 256),
            # Dropless in smoke tests so decode == forward exactly.
            capacity_factor=float(cfg.moe.n_experts),
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 32), headdim=32, chunk=32
        )
    if cfg.encoder is not None:
        updates["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, n_ctx=64)
    if cfg.frontend is not None:
        updates["frontend"] = dataclasses.replace(cfg.frontend, n_tokens=16)
    if cfg.sliding_window is not None:
        updates["sliding_window"] = min(cfg.sliding_window, 64)
    # Keep the hybrid pattern but 2 layers: one mamba + one attention.
    if cfg.family == "hybrid":
        updates["layer_pattern"] = "MA"
    return dataclasses.replace(cfg, **updates)

"""whisper-base — enc-dec, conv/mel frontend is a stub [arXiv:2212.04356].

The TRANSFORMER backbone only: 6 encoder + 6 decoder layers; input_specs() provides
precomputed frame embeddings (1500 frames = 30 s at 50 Hz after the conv stack).
"""
from repro.configs.base import ArchConfig, EncoderConfig, FrontendConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder=EncoderConfig(n_layers=6, n_ctx=1500),
    frontend=FrontendConfig(kind="audio", n_tokens=1500),
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    source="arXiv:2212.04356 (Whisper base)",
)

"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219].

Beyond-paper extra: we expose a sliding-window variant (window 4096) so this dense
arch can run the long_500k decode shape sub-quadratically (see DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,  # GQA kv=10
    d_ff=17920,
    vocab_size=100352,
    source="arXiv:2404.14219 (Phi-3 Medium)",
)

# Sliding-window variant used only for the long_500k decode shape.
CONFIG_SWA = dataclasses.replace(CONFIG, sliding_window=4096)

"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,  # ssm heads: expand*d_model/headdim = 2*1536/64
    n_kv_heads=48,
    d_ff=0,  # attn-free, no MLP block (mamba2 block is the mixer+ff in one)
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, n_groups=1, chunk=256),
    layer_pattern="M",
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2, SSD)",
)

"""DLRM CTR model — the paper's own architecture [arXiv:1906.00091 / ShadowSync §3].

Criteo-like: 13 dense features, 26 categorical features. Table sizes follow a
power-law mix so the embedding-PS bin-packing layer has real work to do.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-ctr"
    family: str = "dlrm"
    n_dense_features: int = 13
    n_sparse_features: int = 26
    embedding_dim: int = 64
    # Rows per categorical table (power-law: a few huge, many small).
    table_sizes: Tuple[int, ...] = (
        4_000_000, 2_000_000, 1_000_000, 800_000, 400_000, 200_000,
        100_000, 100_000, 60_000, 60_000, 40_000, 40_000, 20_000,
        20_000, 10_000, 10_000, 10_000, 4_000, 4_000, 2_000,
        2_000, 1_000, 1_000, 500, 200, 100,
    )
    # Multi-hot lookups per feature (pooled).
    multi_hot: int = 4
    bottom_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 256, 1)
    interaction: str = "dot"  # pairwise dot-product interaction
    dtype: str = "float32"
    source: str = "arXiv:1906.00091 (DLRM); ShadowSync paper §3"

    @property
    def n_embedding_rows(self) -> int:
        return sum(self.table_sizes)


CONFIG = DLRMConfig()


def tiny(embedding_dim: int = 16) -> DLRMConfig:
    """Laptop-scale DLRM used by tests/examples."""
    from dataclasses import replace

    return replace(
        CONFIG,
        embedding_dim=embedding_dim,
        table_sizes=(1000, 800, 600, 400, 200, 100, 50, 20),
        n_sparse_features=8,
        multi_hot=2,
        bottom_mlp=(64, embedding_dim),
        top_mlp=(64, 32, 1),
    )

"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,  # GQA kv=8
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=128, n_groups=8, chunk=256),
    # 1 attention layer per 8 (1:7 mamba:attn interleave), attn at position 3.
    layer_pattern="MMMAMMMM",
    source="arXiv:2403.19887 (Jamba-1.5)",
)

"""llava-next-34b — VLM backbone, anyres tiling; vision tower is a stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf]. The LANGUAGE backbone only — input_specs()
provides precomputed patch embeddings (anyres: base 576 + 4 tiles x 576 = 2880).
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,  # GQA kv=8
    d_ff=20480,
    vocab_size=64000,
    frontend=FrontendConfig(kind="vision", n_tokens=2880),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (LLaVA-NeXT, anyres)",
)

"""Activation-sharding constraint context.

Model code is mesh-agnostic; it calls ``constrain(x, ("batch", None, "model"))``
with logical axis tokens. When a mesh context is active (set by the launcher
around tracing), these resolve to ``jax.lax.with_sharding_constraint`` hints;
otherwise they are identity — tests and the laptop-scale runners never see a mesh.

Tokens: "batch" -> the batch mesh axes, "model" -> the tensor-parallel axis,
None -> unconstrained. Non-divisible dims silently drop the constraint.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_tls = threading.local()


@dataclass
class _Ctx:
    mesh: object
    batch_axes: Tuple[str, ...]
    model_axis: str


@contextlib.contextmanager
def activation_mesh(mesh, *, batch_axes: Sequence[str] = ("data",),
                    model_axis: str = "model"):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = _Ctx(mesh, tuple(batch_axes), model_axis)
    try:
        yield
    finally:
        _tls.ctx = prev


def active() -> Optional[_Ctx]:
    return getattr(_tls, "ctx", None)


def _axis_size(mesh, ax) -> int:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(ax, tuple):
        return int(np.prod([shape[a] for a in ax]))
    return shape[ax]


def constrain(x, spec: Sequence) -> jax.Array:
    ctx = active()
    if ctx is None:
        return x
    assert len(spec) == x.ndim, (spec, x.shape)
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            ax = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
        elif s == "model":
            ax = ctx.model_axis
        else:
            ax = s
        if ax is not None and (dim < _axis_size(ctx.mesh, ax) or dim % _axis_size(ctx.mesh, ax)):
            ax = None
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*resolved)))

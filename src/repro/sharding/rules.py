"""Parameter/activation PartitionSpec rules.

Baseline layout ("megatron + fsdp"): the tensor-parallel dim of every matmul
weight shards over ``model``; the other dim shards over ``fsdp_axis`` (usually
``data``) for the giant archs so params/optimizer state fit. Experts shard over
``model`` (expert parallelism). Specs are right-aligned so jnp-stacked layer
params (leading repeats/replica dims) inherit trailing rules.

ShadowSync mode adds a leading replica dim sharded over the replica axis
(``pod`` for LLM-scale, ``data`` for DLRM-scale); see core/spmd.py.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# name -> spec for the *trailing* dims of the weight.
# (tp = model axis slot, fsdp = fsdp axis slot)
_RULES = {
    # attention / generic matmuls: (d_in, d_out_tp)
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # embeddings / unembedding: vocab over model
    "table": ("tp", None),
    "w": ("fsdp", "tp"),  # lm_head / projector
    # mamba2
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "norm_scale": ("tp",),
    # moe expert stacks: experts over model
    "router": (None, None),
    # small per-head vectors: replicate
    "dt_bias": (None,),
    "A_log": (None,),
    "D": (None,),
    # norms / biases: replicate
    "scale": (None,),
    "bias": (None,),
    "b": (None,),
}

# MoE expert weights are 3D (E, d, f): override the 2D rule.
_MOE_RULES = {
    "w_gate": ("tp", "fsdp", None),
    "w_up": ("tp", "fsdp", None),
    "w_down": ("tp", None, "fsdp"),
}


def _resolve(slots, model_axis, fsdp_axis):
    out = []
    for s in slots:
        if s == "tp":
            out.append(model_axis)
        elif s == "fsdp":
            out.append(fsdp_axis)
        else:
            out.append(None)
    return tuple(out)


def _path_names(path) -> list:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return names


def _divisible(dim: Optional[int], shape, spec, mesh_shape) -> tuple:
    """Drop sharding on axes the dim doesn't divide into (GSPMD pads otherwise;
    padding giant vocab dims is fine, padding tiny head dims is wasteful)."""
    out = []
    for size, ax in zip(shape[-len(spec):] if spec else (), spec):
        if ax is None:
            out.append(None)
            continue
        n = int(np.prod([mesh_shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
        out.append(ax if (size >= n and size % n == 0) else None)
    return tuple(out)


def param_spec(path, leaf, *, model_axis="model", fsdp_axis=None,
               mesh_shape=None, replica_axis=None) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    # MoE expert weights are (E, d, f) per layer => ndim >= 4 once jnp-stacked
    # over unit repeats (the only way these trees are built).
    in_moe = "ffn" in names and leaf.ndim >= 4 and name in _MOE_RULES
    slots = _MOE_RULES[name] if in_moe else _RULES.get(name, None)
    if slots is None:
        base = (None,) * leaf.ndim
    else:
        base = _resolve(slots, model_axis, fsdp_axis)
    # Right-align: leading stacked dims (unit repeats) replicate...
    lead = leaf.ndim - len(base)
    spec = (None,) * lead + base
    if mesh_shape is not None:
        spec = (None,) * lead + _divisible(None, leaf.shape, base, mesh_shape)
    # ...unless this pytree carries a leading replica dim.
    if replica_axis is not None and leaf.ndim >= 1:
        spec = (replica_axis,) + spec[1:]
    return P(*spec)


def build_param_specs(params: Any, mesh, *, model_axis="model", fsdp_axis=None,
                      replica_axis=None) -> Any:
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            param_spec(path, leaf, model_axis=model_axis, fsdp_axis=fsdp_axis,
                       mesh_shape=mesh_shape, replica_axis=replica_axis),
        ),
        params,
    )


def kv_cache_spec(leaf_shape, mesh_shape, *, batch_axis="data", model_axis="model") -> P:
    """Serving-cache sharding. Attention KV leaves are (repeats, B, S, kv, hd);
    mamba ssm state (repeats, B, H, p, n); conv state (repeats, B, K, C).
    Shard batch over ``data`` when divisible, else shard the length/head dim;
    shard kv-heads (or head_dim for MQA) over ``model`` when divisible."""
    nd = len(leaf_shape)
    data_n, model_n = mesh_shape[batch_axis], mesh_shape[model_axis]
    spec = [None] * nd
    b = leaf_shape[1] if nd >= 2 else 1
    if nd >= 2 and b % data_n == 0 and b >= data_n:
        spec[1] = batch_axis
        data_used = True
    else:
        data_used = False
    if nd == 5:  # (repeats, B, S, kv, hd) attn  OR (repeats, B, H, p, n) ssm
        # heuristically: dim2 large => S (attn); shard the widest shardable dim
        if not data_used and leaf_shape[2] % data_n == 0:
            spec[2] = batch_axis
        if leaf_shape[3] % model_n == 0:
            spec[3] = model_axis
        elif leaf_shape[4] % model_n == 0:
            spec[4] = model_axis
    elif nd == 4:  # (repeats, B, K, C) conv state
        if leaf_shape[3] % model_n == 0:
            spec[3] = model_axis
    return P(*spec)


def batch_spec(kind: str, *, replica_axis=None, batch_axes=("data",)) -> P:
    """Token batches: batch dim over the data axes (plus pod in baseline mode)."""
    if replica_axis is not None:
        return P(replica_axis, batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return P(ax, None)

"""Pure-jnp oracle for the embedding-bag kernel."""
import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """table: (rows, d); idx: (n_bags, m) -> (n_bags, d) sum-pooled, fp32."""
    return jnp.sum(jnp.take(table, idx, axis=0).astype(jnp.float32), axis=1)

"""Jitted public wrapper for the embedding-bag kernel (pads d to the TPU lane
width, flattens arbitrary bag batch dims, falls back to the oracle off-TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

LANE = 128


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def embedding_bag_op(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """table: (rows, d); idx: (..., m) -> (..., d) sum-pooled lookups."""
    if not use_pallas:
        out = embedding_bag_ref(table, idx.reshape(-1, idx.shape[-1]))
        return out.reshape(*idx.shape[:-1], table.shape[-1])
    d = table.shape[-1]
    pad = (-d) % LANE
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    flat_idx = idx.reshape(-1, idx.shape[-1]).astype(jnp.int32)
    out = embedding_bag(table, flat_idx, interpret=resolve_interpret(interpret))
    if pad:
        out = out[:, :d]
    return out.reshape(*idx.shape[:-1], d)

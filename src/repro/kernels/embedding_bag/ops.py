"""Jitted public wrapper for the embedding-bag kernels.

Flattens arbitrary bag batch dims and picks the grid strategy per backend: the
row-streaming kernel compiled on TPU (the table never has to fit in VMEM), the
bag-blocked kernel through the interpreter elsewhere (coarse grid — the
interpreter's cost is per grid step). Both are the same fused lookup+pool
launch; ``strategy`` forces one explicitly and ``use_pallas=False`` falls back
to the pure-jnp oracle.

d is padded to the TPU lane width ONLY on the compiled path — the interpreter
has no lane constraint, and the pad/slice would copy the whole table per call.
Compiled TPU deployments should size d to a multiple of 128 so the per-call
pad vanishes there too."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret, resolve_strategy
from repro.kernels.embedding_bag.embedding_bag import (
    embedding_bag,
    embedding_bag_blocked,
)
from repro.kernels.embedding_bag.ref import embedding_bag_ref

LANE = 128


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret", "strategy", "block_bags")
)
def embedding_bag_op(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    strategy: str | None = None,
    block_bags: int = 512,
) -> jnp.ndarray:
    """table: (rows, d); idx: (..., m) -> (..., d) sum-pooled lookups."""
    if not use_pallas:
        out = embedding_bag_ref(table, idx.reshape(-1, idx.shape[-1]))
        return out.reshape(*idx.shape[:-1], table.shape[-1])
    d = table.shape[-1]
    interp = resolve_interpret(interpret)
    pad = 0 if interp else (-d) % LANE
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    flat_idx = idx.reshape(-1, idx.shape[-1]).astype(jnp.int32)
    if resolve_strategy(strategy, tpu="stream", fallback="block") == "stream":
        out = embedding_bag(table, flat_idx, interpret=interp)
    else:
        n_bags = flat_idx.shape[0]
        bb = min(block_bags, n_bags)
        bag_pad = (-n_bags) % bb
        if bag_pad:  # padded bags look up row 0 and are sliced off below
            flat_idx = jnp.pad(flat_idx, ((0, bag_pad), (0, 0)))
        out = embedding_bag_blocked(
            table, flat_idx, block_bags=bb, interpret=interp
        )[:n_bags]
    if pad:
        out = out[:, :d]
    return out.reshape(*idx.shape[:-1], d)

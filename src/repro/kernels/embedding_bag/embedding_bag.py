"""Pallas TPU kernel: fused embedding-bag (gather + sum-pool).

The paper's embedding PSs spend their cycles on exactly this op (lookup + partial
pooling, §3.1). TPU adaptation: instead of CPU random-access RAM reads, we
scalar-prefetch the row ids and let the BlockSpec index_map stream one table row
per grid step HBM->VMEM, accumulating the pool in the revisited output block.
Grid = (n_bags, multi_hot); the output block for bag ``n`` is revisited across the
``m`` axis (sequential TPU grid), so accumulation needs no scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """table: (rows, d); idx: (n_bags, m) int32 global row ids -> (n_bags, d) sums.

    d should be a multiple of 128 on real TPU; the ops.py wrapper pads."""
    n_bags, m = idx.shape
    _, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bags, m),
        in_specs=[
            pl.BlockSpec((1, d), lambda n, j, idx_ref: (idx_ref[n, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda n, j, idx_ref: (n, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(idx, table)

"""Pallas TPU kernels: fused embedding-bag (gather + sum-pool).

The paper's embedding PSs spend their cycles on exactly this op (lookup + partial
pooling, §3.1). Two grid strategies over the same semantics (DESIGN.md §7):

* ``embedding_bag`` — row-streaming. Scalar-prefetch the row ids and let the
  BlockSpec index_map stream one table row per grid step HBM->VMEM, accumulating
  the pool in the revisited output block. Grid = (n_bags, multi_hot); the output
  block for bag ``n`` is revisited across the ``m`` axis (sequential TPU grid),
  so accumulation needs no scratch. The table never has to fit in VMEM — this is
  the production-scale path, compiled on TPU.

* ``embedding_bag_blocked`` — bag-blocked. Grid = (n_bags / block_bags,); the
  table is a single VMEM-resident block and each grid step gathers + pools a
  whole block of bags in-body. Requires the (shard's) table to fit in VMEM, and
  is the off-TPU interpret path: the Pallas interpreter's per-grid-step cost is
  a buffer copy, so the coarse grid keeps the fused op fast everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """table: (rows, d); idx: (n_bags, m) int32 global row ids -> (n_bags, d) sums.

    d should be a multiple of 128 on real TPU; the ops.py wrapper pads."""
    n_bags, m = idx.shape
    _, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bags, m),
        in_specs=[
            pl.BlockSpec((1, d), lambda n, j, idx_ref: (idx_ref[n, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda n, j, idx_ref: (n, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(idx, table)


def _blocked_kernel(idx_ref, table_ref, out_ref):
    b = pl.program_id(0)
    ids = idx_ref[b]  # (block_bags, m) row ids from SMEM
    block_bags, m = ids.shape
    vecs = jnp.take(table_ref[...], ids.reshape(-1), axis=0)
    vecs = vecs.reshape(block_bags, m, -1).astype(jnp.float32)
    out_ref[...] = jnp.sum(vecs, axis=1)


def embedding_bag_blocked(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    block_bags: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """table: (rows, d); idx: (n_bags, m) int32 -> (n_bags, d) sums.

    n_bags must be a multiple of ``block_bags`` (the ops.py wrapper pads); the
    whole table is one resident block, so rows * d must fit in VMEM — fine for
    plan-sharded tables and for the interpreter, not for a monolithic
    production table (use ``embedding_bag`` there)."""
    n_bags, m = idx.shape
    rows, d = table.shape
    assert n_bags % block_bags == 0, (n_bags, block_bags)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bags // block_bags,),
        in_specs=[pl.BlockSpec((rows, d), lambda b, idx_ref: (0, 0))],
        out_specs=pl.BlockSpec((block_bags, d), lambda b, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        _blocked_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(idx.reshape(n_bags // block_bags, block_bags, m), table)

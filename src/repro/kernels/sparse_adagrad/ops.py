"""Jitted public wrapper for the fused sparse-Adagrad kernels.

Flattens (bag, hot) occurrences and picks the grid strategy per backend: the
row-streaming kernel (occurrences sorted by row so duplicates form consecutive
revisited-block runs) compiled on TPU, the occurrence-blocked kernel through
the interpreter elsewhere. Padded occurrences point at row 0 of a zero
gradient row appended to ``g_pooled`` — an exact no-op under
accumulate-then-rsqrt-step semantics. ``use_pallas=False`` falls back to the
pure-jnp oracle.

d / row-count are padded to lane/sublane multiples ONLY on the compiled path —
the interpreter has no tiling constraint, and the pad/slice would copy the
whole table per call. Compiled TPU deployments should size d to a multiple of
128 so the per-call pad vanishes there too."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret, resolve_strategy
from repro.kernels.sparse_adagrad.ref import sparse_adagrad_ref
from repro.kernels.sparse_adagrad.sparse_adagrad import (
    sparse_adagrad_blocked,
    sparse_adagrad_rows,
)

LANE = 128
SUBLANE = 8


@functools.partial(
    jax.jit,
    static_argnames=("lr", "eps", "use_pallas", "interpret", "strategy",
                     "block_items"),
)
def sparse_adagrad_op(
    table: jnp.ndarray,
    acc: jnp.ndarray,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    *,
    lr: float,
    eps: float = 1e-8,
    use_pallas: bool = True,
    interpret: bool | None = None,
    strategy: str | None = None,
    block_items: int = 1024,
):
    """table: (n_rows, d); acc: (n_rows, d) fp32; idx: (..., m) row ids;
    g_pooled: (..., d) pooled grads, bag dims matching idx's.
    Returns (new_table, new_acc)."""
    if not use_pallas:
        return sparse_adagrad_ref(
            table, acc, idx.reshape(-1, idx.shape[-1]),
            g_pooled.reshape(-1, g_pooled.shape[-1]), lr, eps)
    n_rows, d = table.shape
    m = idx.shape[-1]
    flat_idx = idx.reshape(-1, m).astype(jnp.int32)
    g = g_pooled.reshape(-1, d)
    n_bags = flat_idx.shape[0]

    interp = resolve_interpret(interpret)
    pad_d = 0 if interp else (-d) % LANE
    pad_r = 0 if interp else (-n_rows) % SUBLANE
    if pad_d or pad_r:
        table = jnp.pad(table, ((0, pad_r), (0, pad_d)))
        acc = jnp.pad(acc, ((0, pad_r), (0, pad_d)))
    if pad_d:
        g = jnp.pad(g, ((0, 0), (0, pad_d)))

    # Occurrence lists: row id + owning bag per (bag, hot) pair, plus a zero
    # gradient row for padded occurrences (bag id n_bags -> g row of zeros).
    rows = flat_idx.reshape(-1)
    bags = jnp.repeat(jnp.arange(n_bags, dtype=jnp.int32), m)
    g = jnp.concatenate([g.astype(jnp.float32), jnp.zeros((1, g.shape[1]))])
    n_items = rows.shape[0]

    if resolve_strategy(strategy, tpu="rows", fallback="block") == "rows":
        order = jnp.argsort(rows)  # duplicates become consecutive runs
        new_table, new_acc = sparse_adagrad_rows(
            table, acc.astype(jnp.float32), rows[order], bags[order], g,
            lr=lr, eps=eps, interpret=interp)
    else:
        bi = min(block_items, n_items)
        pad_i = (-n_items) % bi
        if pad_i:
            rows = jnp.pad(rows, (0, pad_i))  # row 0 x zero grad: exact no-op
            bags = jnp.pad(bags, (0, pad_i), constant_values=n_bags)
        new_table, new_acc = sparse_adagrad_blocked(
            table, acc.astype(jnp.float32), rows, bags, g,
            lr=lr, eps=eps, block_items=bi, interpret=interp)

    if pad_d or pad_r:
        new_table = new_table[:n_rows, :d]
        new_acc = new_acc[:n_rows, :d]
    return new_table, new_acc

"""Pallas TPU kernels: fused row-sparse Adagrad scatter (the embedding backward).

One launch applies the whole backward for a batch of multi-hot bags: the
accumulator update ``acc[r] += g^2`` and the rsqrt-scaled row add
``table[r] -= lr * rsqrt(acc_final[r] + eps) * g`` — with duplicate-row
ACCUMULATE semantics matching the pytree oracle exactly: every occurrence's
``g^2`` lands in the accumulator first, and the row step is scaled by that
FINAL accumulator (``embeddings.table.sparse_adagrad_update`` computes the
same thing via scatter-add + gather). The pooled gradient of a bag is read
straight from ``g_pooled`` — the (n_items, d) per-occurrence broadcast the
unfused path materializes never exists.

Two grid strategies over the same semantics (DESIGN.md §7):

* ``sparse_adagrad_rows`` — row-streaming. Occurrences arrive SORTED BY ROW
  (the ops.py wrapper sorts), so duplicates form consecutive grid steps and
  the revisited table/acc output blocks stay VMEM-resident across a run. A
  VMEM scratch accumulates the run's gradient sum; every step rewrites the
  resident table block with the current partial step, so the final (correct)
  write is the one flushed to HBM. Tables stay in HBM — one (1, d) row block
  moves per grid step. Aliased in/out: untouched rows are never streamed.
  This is the production-scale path, compiled on TPU.

* ``sparse_adagrad_blocked`` — occurrence-blocked. Grid = (n_items / block,);
  table, acc, and g_pooled are VMEM-resident blocks, each step scatter-adds a
  block of occurrences in-body, and the last step applies the fused row
  update for the whole table at once. Requires the (shard's) table to fit in
  VMEM; this is the off-TPU interpret path (the interpreter's per-grid-step
  cost is a buffer copy, so the coarse grid keeps it fast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rows_kernel(rows_ref, bags_ref, g_ref, table_ref, acc_ref,
                 out_table_ref, out_acc_ref, sum_ref, *, lr: float, eps: float):
    i = pl.program_id(0)
    # First occurrence of a row's (sorted, hence consecutive) run: seed the
    # resident acc block from HBM and zero the run's gradient-sum scratch.
    first = (i == 0) | (rows_ref[i] != rows_ref[jnp.maximum(i - 1, 0)])
    g = g_ref[...].astype(jnp.float32)  # (1, d) pooled grad of this bag

    @pl.when(first)
    def _():
        out_acc_ref[...] = acc_ref[...]
        sum_ref[...] = jnp.zeros_like(sum_ref)

    out_acc_ref[...] += g * g
    sum_ref[...] += g
    # Rewritten every step of the run; only the last (full-sum, final-acc)
    # write survives the flush — exactly the oracle's final-acc scaling.
    scale = lr * jax.lax.rsqrt(out_acc_ref[...] + eps)
    out_table_ref[...] = (
        table_ref[...].astype(jnp.float32) - scale * sum_ref[...]
    ).astype(out_table_ref.dtype)


def sparse_adagrad_rows(
    table: jnp.ndarray,
    acc: jnp.ndarray,
    rows: jnp.ndarray,
    bags: jnp.ndarray,
    g_pooled: jnp.ndarray,
    *,
    lr: float,
    eps: float = 1e-8,
    interpret: bool = False,
):
    """table: (n_rows, d); acc: (n_rows, d) fp32; rows/bags: (n_items,) int32
    sorted by row; g_pooled: (n_bags, d). Returns (new_table, new_acc);
    rows not referenced are bit-identical (aliased in/out)."""
    n_items = rows.shape[0]
    _, d = table.shape
    row_spec = pl.BlockSpec((1, d), lambda i, rows_ref, bags_ref: (rows_ref[i], 0))
    bag_spec = pl.BlockSpec((1, d), lambda i, rows_ref, bags_ref: (bags_ref[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_items,),
        in_specs=[bag_spec, row_spec, row_spec],
        out_specs=[row_spec, row_spec],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_rows_kernel, lr=lr, eps=eps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, table.dtype),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
        ],
        # operand order incl. scalar prefetch: (rows, bags, g, table, acc)
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(rows, bags, g_pooled, table, acc)


def _blocked_kernel(rows_ref, bags_ref, g_ref, table_ref, acc_ref,
                    out_table_ref, out_acc_ref, sum_ref,
                    *, lr: float, eps: float, n_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_acc_ref[...] = acc_ref[...]
        out_table_ref[...] = table_ref[...]
        sum_ref[...] = jnp.zeros_like(sum_ref)

    ids = rows_ref[i]  # (block_items,) row ids of this occurrence block
    g = jnp.take(g_ref[...], bags_ref[i], axis=0).astype(jnp.float32)
    out_acc_ref[...] = out_acc_ref[...].at[ids].add(g * g)
    sum_ref[...] = sum_ref[...].at[ids].add(g)

    @pl.when(i == n_blocks - 1)
    def _():
        # All g^2 landed: one vectorized final-acc-scaled step for every row
        # (untouched rows have sum 0 — their step is exactly zero).
        scale = lr * jax.lax.rsqrt(out_acc_ref[...] + eps)
        out_table_ref[...] = (
            table_ref[...].astype(jnp.float32) - scale * sum_ref[...]
        ).astype(out_table_ref.dtype)


def sparse_adagrad_blocked(
    table: jnp.ndarray,
    acc: jnp.ndarray,
    rows: jnp.ndarray,
    bags: jnp.ndarray,
    g_pooled: jnp.ndarray,
    *,
    lr: float,
    eps: float = 1e-8,
    block_items: int = 1024,
    interpret: bool = False,
):
    """Same contract as ``sparse_adagrad_rows`` but rows/bags need not be
    sorted; n_items must be a multiple of ``block_items`` (the ops.py wrapper
    pads with zero-gradient occurrences)."""
    n_items = rows.shape[0]
    n_rows, d = table.shape
    n_bags = g_pooled.shape[0]
    assert n_items % block_items == 0, (n_items, block_items)
    n_blocks = n_items // block_items
    table_spec = pl.BlockSpec((n_rows, d), lambda i, rows_ref, bags_ref: (0, 0))
    g_spec = pl.BlockSpec((n_bags, d), lambda i, rows_ref, bags_ref: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks,),
        in_specs=[g_spec, table_spec, table_spec],
        out_specs=[table_spec, table_spec],
        scratch_shapes=[pltpu.VMEM((n_rows, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_blocked_kernel, lr=lr, eps=eps, n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, table.dtype),
            jax.ShapeDtypeStruct(acc.shape, jnp.float32),
        ],
        # operand order incl. scalar prefetch: (rows, bags, g, table, acc)
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(rows.reshape(n_blocks, block_items), bags.reshape(n_blocks, block_items),
      g_pooled, table, acc)

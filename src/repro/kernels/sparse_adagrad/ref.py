"""Pure-jnp oracle for the fused sparse-Adagrad kernels.

Array-level mirror of ``embeddings.table.sparse_adagrad_update``: duplicate
rows scatter-ADD into the accumulator, and every occurrence's row step is
scaled by the FINAL accumulator (scatter-add first, gather after)."""
import jax
import jax.numpy as jnp


def sparse_adagrad_ref(
    table: jnp.ndarray,
    acc: jnp.ndarray,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    lr: float,
    eps: float = 1e-8,
):
    """table: (n_rows, d); acc: (n_rows, d) fp32; idx: (n_bags, m) row ids;
    g_pooled: (n_bags, d). Returns (new_table, new_acc)."""
    n_bags, m = idx.shape
    rows = idx.reshape(-1)  # (n_bags * m,) occurrence order: bag-major
    g = jnp.repeat(g_pooled.astype(jnp.float32), m, axis=0)
    acc = acc.at[rows].add(g * g)
    scale = lr * jax.lax.rsqrt(acc.at[rows].get() + eps)
    table = table.at[rows].add((-scale * g).astype(table.dtype))
    return table, acc

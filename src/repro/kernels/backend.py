"""Backend detection shared by all kernel wrappers.

The Pallas kernels target TPU; everywhere else they run through the Pallas
interpreter (numerically identical, jit-compatible). The backend is probed
once per process — wrappers default ``interpret=None`` and resolve it here
instead of hardcoding ``interpret=True``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax


@functools.lru_cache(maxsize=None)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> compiled Pallas on TPU, interpreter elsewhere."""
    return (not on_tpu()) if interpret is None else bool(interpret)


def resolve_strategy(strategy: Optional[str], *, tpu: str, fallback: str) -> str:
    """Pick a kernel grid strategy per backend: ``tpu`` names the
    fine-grid streaming kernel compiled on TPU, ``fallback`` the
    coarse-grid variant that stays fast through the interpreter (its
    per-grid-step cost is a buffer copy). Explicit ``strategy`` wins."""
    if strategy is None:
        return tpu if on_tpu() else fallback
    if strategy not in (tpu, fallback):
        raise ValueError(
            f"unknown kernel strategy {strategy!r}; expected {tpu!r} or "
            f"{fallback!r}")
    return strategy

"""Pure-jnp oracle for the fused interaction kernel (== models.dlrm.interact's
dot part)."""
import jax.numpy as jnp
import numpy as np


def interaction_ref(z: jnp.ndarray) -> jnp.ndarray:
    """z: (B, F, d) -> (B, F*(F-1)/2) upper-triangle pairwise dots."""
    gram = jnp.einsum("bfd,bgd->bfg", z.astype(jnp.float32), z.astype(jnp.float32))
    iu, ju = np.triu_indices(z.shape[1], k=1)
    return gram[:, iu, ju]

"""Jitted wrapper: pads the batch to the tile size; oracle fallback off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.interaction.interaction import interaction
from repro.kernels.interaction.ref import interaction_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "batch_tile"))
def interaction_op(z: jnp.ndarray, *, use_pallas: bool = True,
                   interpret: bool | None = None, batch_tile: int = 128) -> jnp.ndarray:
    if not use_pallas:
        return interaction_ref(z)
    B = z.shape[0]
    pad = (-B) % batch_tile if B >= batch_tile else 0
    if pad:
        z = jnp.pad(z, ((0, pad), (0, 0), (0, 0)))
    out = interaction(z, batch_tile=batch_tile, interpret=resolve_interpret(interpret))
    return out[:B]

"""Pallas TPU kernel: fused DLRM pairwise-dot interaction.

The paper identifies the interaction layers as the trainers' memory-bandwidth
hotspot (§4.4: 24 Hogwild threads saturate DRAM at ~70-89% utilization). The
naive path materializes the full (B, F+1, F+1) Gram matrix in HBM and then
gathers its upper triangle; this kernel computes z @ z^T on the MXU per batch
tile and writes ONLY the flattened upper-triangle features — one HBM pass in,
one compact pass out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(z_ref, iu_ref, ju_ref, out_ref):
    z = z_ref[...].astype(jnp.float32)  # (bt, F, d)
    gram = jax.lax.dot_general(z, z, (((2,), (2,)), ((0,), (0,))))  # (bt, F, F)
    # Gather the upper triangle (i < j) with a precomputed index pair.
    flat = gram.reshape(z.shape[0], -1)
    idx = iu_ref[...] * z.shape[1] + ju_ref[...]
    out_ref[...] = flat[:, idx].astype(out_ref.dtype)


def interaction(z: jnp.ndarray, *, batch_tile: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """z: (B, F, d) feature vectors -> (B, F*(F-1)/2) pairwise dots (i<j)."""
    B, F, d = z.shape
    assert B % batch_tile == 0 or B < batch_tile, (B, batch_tile)
    bt = min(batch_tile, B)
    iu, ju = np.triu_indices(F, k=1)
    n_pairs = len(iu)
    return pl.pallas_call(
        _kernel,
        grid=(B // bt,),
        in_specs=[
            pl.BlockSpec((bt, F, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_pairs,), lambda i: (0,)),
            pl.BlockSpec((n_pairs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, n_pairs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_pairs), jnp.float32),
        interpret=interpret,
    )(z, jnp.asarray(iu, jnp.int32), jnp.asarray(ju, jnp.int32))

"""Pallas TPU kernels: fused Model-Averaging sync (Algorithm 3) on flat
replica space.

The pytree path is a mean -> broadcast -> lerp chain: it streams the stack
once for the mean, materializes an R-wide broadcast, and streams the stack
again (read + write) for the elastic pull-back — five stack-sized HBM
streams per sync plus per-leaf launch overhead (DESIGN.md §3.3).

Flat MA splits along the paper's launch/landing boundary instead:

* ``replica_mean`` (launch time) — one grid pass that folds the replica
  axis into a revisited VMEM accumulator: read R*N, write N. Because the
  landing only ever consumes the snapshot's *mean*, this IS the launch
  snapshot for decentralized algorithms — N floats instead of R*N.
* ``ma_update`` (landing) — one grid pass applying the elastic pull-back:
  the mean plane stays VMEM-resident per block while every replica streams
  by once — read R*N + N, write R*N.

Elastic membership (DESIGN.md §8): ``replica_mean_rows`` / ``ma_update_rows``
are the active-mask variants. The live row ids arrive via scalar prefetch
(PrefetchScalarGridSpec) and drive the stack block index maps, so a dead slot
is never fetched and never written — zero HBM traffic — and the mean divides
by the LIVE count. The landing aliases the stack in/out, so dead rows keep
their buffer contents bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flatspace import LANE


def _mean_kernel(stack_ref, out_ref):
    i = pl.program_id(1)
    R = pl.num_programs(1)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += stack_ref[0].astype(jnp.float32)

    @pl.when(i == R - 1)
    def _():
        out_ref[...] *= 1.0 / R


def replica_mean(stack: jnp.ndarray, *, block: int = 256,
                 interpret: bool = False) -> jnp.ndarray:
    """(R, n, 128) replica buffer -> (n, 128) fp32 mean, one launch."""
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    stack_spec = pl.BlockSpec((1, block, LANE), lambda j, i: (i, j, 0))
    out_spec = pl.BlockSpec((block, LANE), lambda j, i: (j, 0))
    return pl.pallas_call(
        _mean_kernel,
        grid=(n // block, R),
        in_specs=[stack_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, LANE), jnp.float32),
        interpret=interpret,
    )(stack)


def _mean_rows_kernel(rows_ref, stack_ref, out_ref):
    del rows_ref  # consumed by the index maps
    i = pl.program_id(1)
    A = pl.num_programs(1)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += stack_ref[0].astype(jnp.float32)

    @pl.when(i == A - 1)
    def _():
        out_ref[...] *= 1.0 / A


def replica_mean_rows(stack: jnp.ndarray, rows: jnp.ndarray, *,
                      block: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Mean of the LIVE rows of a (R, n, 128) buffer, one launch.

    ``rows``: (A,) int32 active replica ids. Dead rows are never fetched
    (their blocks are not in any index map) and the mean divides by A, the
    live count — the elastic-membership denominator.
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    A = rows.shape[0]
    assert A >= 1, "replica_mean_rows needs at least one live row"
    stack_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, i, rows_ref: (rows_ref[i], j, 0)
    )
    out_spec = pl.BlockSpec((block, LANE), lambda j, i, rows_ref: (j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // block, A),
        in_specs=[stack_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _mean_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, LANE), jnp.float32),
        interpret=interpret,
    )(rows, stack)


def _ma_rows_kernel(rows_ref, stack_ref, mean_ref, out_ref, *, alpha: float):
    del rows_ref  # consumed by the index maps
    wi = stack_ref[0].astype(jnp.float32)
    g = mean_ref[...]
    out_ref[0] = ((1.0 - alpha) * wi + alpha * g).astype(out_ref.dtype)


def ma_update_rows(stack: jnp.ndarray, mean: jnp.ndarray, rows: jnp.ndarray,
                   alpha: float, *, block: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """Elastic pull-back of only the LIVE rows toward ``mean``, one launch.

    Rows not in ``rows`` are never fetched or written; the in/out aliasing
    keeps them bit-identical in the returned buffer.
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    A = rows.shape[0]
    assert A >= 1, "ma_update_rows needs at least one live row"
    stack_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, i, rows_ref: (rows_ref[i], j, 0)
    )
    mean_spec = pl.BlockSpec((block, LANE), lambda j, i, rows_ref: (j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // block, A),
        in_specs=[stack_spec, mean_spec],
        out_specs=stack_spec,
    )
    return pl.pallas_call(
        functools.partial(_ma_rows_kernel, alpha=alpha),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(stack.shape, stack.dtype),
        # operand order incl. scalar prefetch: (rows, stack, mean)
        input_output_aliases={1: 0},
        interpret=interpret,
    )(rows, stack, mean)


def _ma_kernel(stack_ref, mean_ref, out_ref, *, alpha: float):
    wi = stack_ref[0].astype(jnp.float32)
    g = mean_ref[...]
    out_ref[0] = ((1.0 - alpha) * wi + alpha * g).astype(out_ref.dtype)


def ma_update(stack: jnp.ndarray, mean: jnp.ndarray, alpha: float, *,
              block: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Elastic pull-back of every replica toward ``mean``, one launch."""
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    stack_spec = pl.BlockSpec((1, block, LANE), lambda j, i: (i, j, 0))
    mean_spec = pl.BlockSpec((block, LANE), lambda j, i: (j, 0))
    return pl.pallas_call(
        functools.partial(_ma_kernel, alpha=alpha),
        grid=(n // block, R),
        in_specs=[stack_spec, mean_spec],
        out_specs=stack_spec,
        out_shape=jax.ShapeDtypeStruct(stack.shape, stack.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(stack, mean)

"""Pure-jnp oracles for the fused MA kernels (== core.sync.ma_round on flat
replica buffers)."""
import jax.numpy as jnp


def replica_mean_ref(stack: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(stack.astype(jnp.float32), axis=0)


def ma_update_ref(stack: jnp.ndarray, mean: jnp.ndarray, alpha: float) -> jnp.ndarray:
    wi = stack.astype(jnp.float32)
    out = (1.0 - alpha) * wi + alpha * mean[None].astype(jnp.float32)
    return out.astype(stack.dtype)


def replica_mean_rows_ref(stack: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Mean over the live rows only — the elastic-membership denominator."""
    return jnp.mean(stack[rows].astype(jnp.float32), axis=0)


def ma_update_rows_ref(stack: jnp.ndarray, mean: jnp.ndarray,
                       rows: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """Pull only the live rows toward ``mean``; dead rows are untouched."""
    sub = stack[rows].astype(jnp.float32)
    new = (1.0 - alpha) * sub + alpha * mean[None].astype(jnp.float32)
    return stack.at[rows].set(new.astype(stack.dtype))

"""Pure-jnp oracles for the fused MA kernels (== core.sync.ma_round on flat
replica buffers)."""
import jax.numpy as jnp


def replica_mean_ref(stack: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(stack.astype(jnp.float32), axis=0)


def ma_update_ref(stack: jnp.ndarray, mean: jnp.ndarray, alpha: float) -> jnp.ndarray:
    wi = stack.astype(jnp.float32)
    out = (1.0 - alpha) * wi + alpha * mean[None].astype(jnp.float32)
    return out.astype(stack.dtype)

"""Jitted MA sync entry points over flat replica space.

One launch per phase of the paper's background round: ``replica_mean_op`` at
sync-launch (the snapshot for decentralized algorithms IS the mean) and
``ma_sync_op`` at landing (elastic pull-back into the current buffer).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.ma_update.ma_update import (
    ma_update, ma_update_rows, replica_mean, replica_mean_rows)
from repro.kernels.ma_update.ref import (
    ma_update_ref, ma_update_rows_ref, replica_mean_ref,
    replica_mean_rows_ref)

BLOCK = 256


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "block"))
def replica_mean_op(stack: jnp.ndarray, *, use_pallas: bool = True,
                    interpret: Optional[bool] = None,
                    block: int = BLOCK) -> jnp.ndarray:
    """(R, n, 128) replica buffer -> (n, 128) fp32 replica mean."""
    if use_pallas:
        return replica_mean(stack, block=block, interpret=resolve_interpret(interpret))
    return replica_mean_ref(stack)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("alpha", "use_pallas", "interpret", "block"))
def ma_sync_op(stack: jnp.ndarray, mean: jnp.ndarray, alpha: float, *,
               use_pallas: bool = True, interpret: Optional[bool] = None,
               block: int = BLOCK) -> jnp.ndarray:
    """Pull every replica of a (R, n, 128) buffer toward ``mean``, one launch.
    ``stack`` is donated: the pull-back lands in place."""
    if use_pallas:
        return ma_update(stack, mean, alpha, block=block,
                         interpret=resolve_interpret(interpret))
    return ma_update_ref(stack, mean, alpha)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "block"))
def replica_mean_rows_op(stack: jnp.ndarray, rows: jnp.ndarray, *,
                         use_pallas: bool = True,
                         interpret: Optional[bool] = None,
                         block: int = BLOCK) -> jnp.ndarray:
    """Mean of only the LIVE rows of a (R, n, 128) buffer (elastic
    membership): dead slots cost zero HBM traffic and the mean divides by
    the live count. Retraces per distinct live count only."""
    if use_pallas:
        return replica_mean_rows(stack, rows, block=block,
                                 interpret=resolve_interpret(interpret))
    return replica_mean_rows_ref(stack, rows)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("alpha", "use_pallas", "interpret", "block"))
def ma_sync_rows_op(stack: jnp.ndarray, mean: jnp.ndarray, rows: jnp.ndarray,
                    alpha: float, *, use_pallas: bool = True,
                    interpret: Optional[bool] = None,
                    block: int = BLOCK) -> jnp.ndarray:
    """Pull only the LIVE rows of a (R, n, 128) buffer toward ``mean``.
    ``stack`` is donated: the landing is in place; dead rows stay
    bit-identical and are never streamed."""
    if use_pallas:
        return ma_update_rows(stack, mean, rows, alpha, block=block,
                              interpret=resolve_interpret(interpret))
    return ma_update_rows_ref(stack, mean, rows, alpha)

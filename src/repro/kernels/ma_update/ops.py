"""Jitted MA sync entry points over flat replica space.

One launch per phase of the paper's background round: ``replica_mean_op`` at
sync-launch (the snapshot for decentralized algorithms IS the mean) and
``ma_sync_op`` at landing (elastic pull-back into the current buffer).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.ma_update.ma_update import ma_update, replica_mean
from repro.kernels.ma_update.ref import ma_update_ref, replica_mean_ref

BLOCK = 256


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "block"))
def replica_mean_op(stack: jnp.ndarray, *, use_pallas: bool = True,
                    interpret: Optional[bool] = None,
                    block: int = BLOCK) -> jnp.ndarray:
    """(R, n, 128) replica buffer -> (n, 128) fp32 replica mean."""
    if use_pallas:
        return replica_mean(stack, block=block, interpret=resolve_interpret(interpret))
    return replica_mean_ref(stack)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("alpha", "use_pallas", "interpret", "block"))
def ma_sync_op(stack: jnp.ndarray, mean: jnp.ndarray, alpha: float, *,
               use_pallas: bool = True, interpret: Optional[bool] = None,
               block: int = BLOCK) -> jnp.ndarray:
    """Pull every replica of a (R, n, 128) buffer toward ``mean``, one launch.
    ``stack`` is donated: the pull-back lands in place."""
    if use_pallas:
        return ma_update(stack, mean, alpha, block=block,
                         interpret=resolve_interpret(interpret))
    return ma_update_ref(stack, mean, alpha)

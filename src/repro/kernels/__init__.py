# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Packages: easgd_update / ma_update / bmuf_update / gossip_update
# (the flat sync engine's fused per-algorithm launches, DESIGN.md 3),
# embedding_bag + sparse_adagrad (the sparse embedding substrate's
# fused lookup+pool forward and scatter-Adagrad backward, DESIGN.md 7),
# interaction, flash_attention. `backend.py` resolves interpret-vs-
# compiled once per process (compiled Pallas on TPU, interpreter
# elsewhere); wrappers take `interpret=None` to use it.

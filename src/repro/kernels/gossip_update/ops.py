"""Jitted gossip sync entry points over flat replica space.

``gossip_round_op`` is the HogwildSim landing: one launch covering every pair
that formed this round (retraces per distinct participant count — the shadow
schedule produces only a handful). ``gossip_pair_flat_op`` is the threaded
runner's shadow-thread primitive: one symmetric pair exchange per launch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.gossip_update.gossip_update import (
    gossip_pair_update, gossip_round_update)
from repro.kernels.gossip_update.ref import gossip_pair_ref, gossip_round_ref

BLOCK = 256


@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas", "interpret", "block"))
def gossip_pair_flat_op(w_a: jnp.ndarray, w_b: jnp.ndarray, alpha: float, *,
                        use_pallas: bool = True, interpret: Optional[bool] = None,
                        block: int = BLOCK) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One symmetric pair exchange on (n, 128) flat planes. NOT donated: the
    threaded runner's trainer threads may still be reading these planes."""
    if use_pallas:
        return gossip_pair_update(w_a, w_b, alpha, block=block,
                                  interpret=resolve_interpret(interpret))
    return gossip_pair_ref(w_a, w_b, alpha)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("alpha", "use_pallas", "interpret", "block"))
def gossip_round_op(stack: jnp.ndarray, snapshot: jnp.ndarray,
                    land: jnp.ndarray, self_pos: jnp.ndarray,
                    partner_pos: jnp.ndarray, alpha: float, *,
                    use_pallas: bool = True, interpret: Optional[bool] = None,
                    block: int = BLOCK) -> jnp.ndarray:
    """All pair landings of a round over a (R, n, 128) buffer, one launch.

    ``stack`` is donated — the kernel updates it in place; ``snapshot`` must
    be a separate buffer (the compact fired-rows gather), never the live
    stack. Non-participant rows are bit-identical on return.
    """
    if use_pallas:
        return gossip_round_update(stack, snapshot, land, self_pos,
                                   partner_pos, alpha, block=block,
                                   interpret=resolve_interpret(interpret))
    return gossip_round_ref(stack, snapshot, land, self_pos, partner_pos, alpha)

"""Pure-jnp oracles for the fused gossip kernels (== core.algorithms.Gossip
pytree math on flat replica buffers)."""
import jax.numpy as jnp


def gossip_pair_ref(w_a: jnp.ndarray, w_b: jnp.ndarray, alpha: float):
    a = w_a.astype(jnp.float32)
    b = w_b.astype(jnp.float32)
    mix = 0.5 * (a + b)
    new_a = (1.0 - alpha) * a + alpha * mix
    new_b = (1.0 - alpha) * b + alpha * mix
    return new_a.astype(w_a.dtype), new_b.astype(w_b.dtype)


def gossip_round_ref(stack: jnp.ndarray, snapshot: jnp.ndarray, land,
                     self_pos, partner_pos, alpha: float) -> jnp.ndarray:
    """Pair landings on a (R, n, 128) buffer. ``snapshot`` is the (F, n, 128)
    compact gather of the fired replicas; ``land``/``self_pos``/``partner_pos``
    are (P,) index vectors of static length (ids may be traced)."""
    land = jnp.asarray(land, jnp.int32)
    if land.shape[0] == 0:
        return stack
    self_pos = jnp.asarray(self_pos, jnp.int32)
    partner_pos = jnp.asarray(partner_pos, jnp.int32)
    mix = 0.5 * (snapshot[self_pos].astype(jnp.float32)
                 + snapshot[partner_pos].astype(jnp.float32))
    new_rows = ((1.0 - alpha) * stack[land].astype(jnp.float32)
                + alpha * mix).astype(stack.dtype)
    return stack.at[land].set(new_rows)

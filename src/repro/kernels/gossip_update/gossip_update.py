"""Pallas TPU kernels: fused ADPSGD-style gossip sync (pairwise averaging)
on flat replica space.

A gossip round pairs up the replicas whose shadow clocks fired (partial
participation is the algorithm's native mode — see core/algorithms.py::Gossip)
and elastically pulls each participant toward its pair's snapshot average:

    w_i <- (1-alpha) * w_i + alpha * 0.5 * (snap_i + snap_j)

Two kernels:

* ``gossip_round_update`` — a whole round in ONE launch. Participant rows and
  their snapshot positions arrive via scalar prefetch and drive the block
  index maps, so a replica that did not land a pair this round is never
  fetched and never written — zero HBM traffic for it, exactly like the
  un-fired replicas of the EASGD round kernel. The snapshot is passed twice
  with two index maps (own row, partner row), so the pair mix is computed
  in-VMEM without materializing a mixed plane in HBM.

* ``gossip_pair_update`` — one symmetric pair exchange over two (n, 128)
  planes (the ThreadedShadowRunner's shadow-thread primitive): both mixes
  stream through VMEM in a single pass.

Elastic membership (DESIGN.md §8): the participant-rows design IS the
active-mask mechanism — the host draws the rotating matching over
``membership.active_ids()`` only (core/algorithms
``_ring_partner_active_np``), so a dead slot's row never enters ``land`` or
the snapshot gather: zero HBM traffic. A slot that dies mid-flight is
filtered out of ``land`` at landing; its surviving partner still lands from
the snapshot mix gathered at launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flatspace import LANE


def _pair_kernel(a_ref, b_ref, out_a_ref, out_b_ref, *, alpha: float):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    mix = 0.5 * (a + b)
    out_a_ref[...] = ((1.0 - alpha) * a + alpha * mix).astype(out_a_ref.dtype)
    out_b_ref[...] = ((1.0 - alpha) * b + alpha * mix).astype(out_b_ref.dtype)


def gossip_pair_update(
    w_a: jnp.ndarray,
    w_b: jnp.ndarray,
    alpha: float,
    *,
    block: int = 256,
    lanes: int = LANE,
    interpret: bool = False,
):
    """Symmetric pair exchange on (n, 128) flat planes. Returns (new_a, new_b)."""
    n, l = w_a.shape
    assert l == lanes and n % block == 0, (w_a.shape, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_pair_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(w_a.shape, w_a.dtype),
            jax.ShapeDtypeStruct(w_b.shape, w_b.dtype),
        ),
        interpret=interpret,
    )(w_a, w_b)


def _round_kernel(land_ref, self_ref, partner_ref, stack_ref, snap_a_ref,
                  snap_b_ref, out_ref, *, alpha: float):
    del land_ref, self_ref, partner_ref  # consumed by the index maps
    wi = stack_ref[0].astype(jnp.float32)
    mix = 0.5 * (snap_a_ref[0].astype(jnp.float32)
                 + snap_b_ref[0].astype(jnp.float32))
    out_ref[0] = ((1.0 - alpha) * wi + alpha * mix).astype(out_ref.dtype)


def gossip_round_update(
    stack: jnp.ndarray,
    snapshot: jnp.ndarray,
    land: jnp.ndarray,
    self_pos: jnp.ndarray,
    partner_pos: jnp.ndarray,
    alpha: float,
    *,
    block: int = 256,
    interpret: bool = False,
):
    """A whole gossip round in one launch.

    stack: (R, n, 128) fp32 replica buffer; snapshot: (F, n, 128) launch-time
    copies of the FIRED replicas' rows (compact gather, id order);
    land: (P,) int32 replica ids that landed a pair this round (both members
    of every pair appear); self_pos / partner_pos: (P,) int32 positions of
    each participant's own / partner's row inside ``snapshot``.
    Returns new_stack; rows not in ``land`` are bit-identical.
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    P = land.shape[0]
    assert self_pos.shape == partner_pos.shape == (P,), (self_pos.shape, P)
    stack_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, k, land_ref, s_ref, p_ref: (land_ref[k], j, 0)
    )
    snap_a_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, k, land_ref, s_ref, p_ref: (s_ref[k], j, 0)
    )
    snap_b_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, k, land_ref, s_ref, p_ref: (p_ref[k], j, 0)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n // block, P),
        in_specs=[stack_spec, snap_a_spec, snap_b_spec],
        out_specs=[stack_spec],
    )
    return pl.pallas_call(
        functools.partial(_round_kernel, alpha=alpha),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(stack.shape, stack.dtype)],
        # operand order incl. scalar prefetch:
        # (land, self_pos, partner_pos, stack, snapshot, snapshot)
        input_output_aliases={3: 0},
        interpret=interpret,
    )(land, self_pos, partner_pos, stack, snapshot, snapshot)[0]

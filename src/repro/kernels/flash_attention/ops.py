"""Jitted wrapper: (B, S, H, d) GQA attention on top of the flash kernel.

Repeats KV heads for GQA, folds (B, H) into the kernel grid axis, pads S up to the
block size, and falls back to the oracle when use_pallas=False (the pure-JAX path
used by the dry-run, since Pallas-TPU can't lower on the CPU backend)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret", "block"))
def gqa_attention_op(
    q: jnp.ndarray,  # (B, S, H, d)
    k: jnp.ndarray,  # (B, S, Hkv, d)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    use_pallas: bool = True,
    interpret: bool | None = None,
    block: int = 128,
) -> jnp.ndarray:
    B, S, H, d = q.shape
    hkv = k.shape[2]
    n_rep = H // hkv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    if not use_pallas:
        out = attention_ref(qf, kf, vf, causal=causal)
    else:
        pad = (-S) % block
        if pad:
            qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
        out = flash_attention(
            qf, kf, vf, causal=causal, block_q=block, block_k=block, interpret=resolve_interpret(interpret)
        )[:, :S]
    return out.reshape(B, H, S, d).transpose(0, 2, 1, 3)

"""Pallas TPU kernel: causal flash attention (online softmax, VMEM-tiled).

The transformer substrate's compute hot-spot. Grid = (batch*heads, n_q_blocks,
n_kv_blocks); running (max, denom, acc) live in VMEM scratch that persists across
the kv axis (TPU grids execute sequentially, minor-most last). Causal blocks
strictly above the diagonal are skipped via ``pl.when`` — ~2x FLOP saving.
Block shapes default to (128, 128): MXU-aligned, and the working set
(q + k + v + acc tiles at head_dim 128) stays well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool, n_kv: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...], l_ref[...] = m_new, l_new

    if causal:
        # Skip blocks strictly above the diagonal.
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q, k, v: (BH, S, d) with S % block == 0. Returns (BH, S, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_q, n_kv = sq // block_q, sk // block_k
    scale = d ** -0.5
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, n_kv=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

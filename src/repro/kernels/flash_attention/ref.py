"""Pure-jnp oracle for the flash-attention kernel."""
import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True) -> jnp.ndarray:
    """q, k, v: (BH, S, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * d ** -0.5
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

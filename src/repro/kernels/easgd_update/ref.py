"""Pure-jnp oracle for the fused EASGD exchange (== core.sync.easgd_pair_update
on a flat array)."""
import jax.numpy as jnp


def easgd_update_ref(w_ps: jnp.ndarray, w_i: jnp.ndarray, alpha: float):
    ps = w_ps.astype(jnp.float32)
    wi = w_i.astype(jnp.float32)
    new_ps = (1 - alpha) * ps + alpha * wi
    new_wi = (1 - alpha) * wi + alpha * new_ps
    return new_ps.astype(w_ps.dtype), new_wi.astype(w_i.dtype)

"""Pure-jnp oracles for the fused EASGD kernels (== core.sync math on flat
planes)."""
import jax.numpy as jnp


def easgd_update_ref(w_ps: jnp.ndarray, w_i: jnp.ndarray, alpha: float):
    ps = w_ps.astype(jnp.float32)
    wi = w_i.astype(jnp.float32)
    new_ps = (1 - alpha) * ps + alpha * wi
    new_wi = (1 - alpha) * wi + alpha * new_ps
    return new_ps.astype(w_ps.dtype), new_wi.astype(w_i.dtype)


def easgd_round_ref(stack: jnp.ndarray, w_ps: jnp.ndarray,
                    snapshot: jnp.ndarray, fired, alpha: float):
    """Sequential masked round: stack (R, n, 128); snapshot (F, n, 128) holds
    the FIRED replicas' launch copies, positionally aligned with `fired` (a
    sequence of replica ids in exchange order, of static LENGTH — the ids
    themselves may be traced, so this oracle also works under jit)."""
    fired = jnp.asarray(fired, jnp.int32)
    ps = w_ps.astype(jnp.float32)
    if fired.shape[0] == 0:
        return stack, ps
    new_rows = []
    for k in range(fired.shape[0]):
        i = fired[k]
        ps = (1 - alpha) * ps + alpha * snapshot[k].astype(jnp.float32)
        new_rows.append(
            ((1 - alpha) * stack[i].astype(jnp.float32) + alpha * ps).astype(stack.dtype)
        )
    new_stack = stack.at[fired].set(jnp.stack(new_rows))
    return new_stack, ps

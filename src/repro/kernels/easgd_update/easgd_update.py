"""Pallas TPU kernels: fused ShadowSync-EASGD exchange (Algorithm 2).

Two kernels over flat replica space (core/flatspace.py):

* ``easgd_update`` — one PS<->replica pair exchange. Two dependent elementwise
  lerps streamed through VMEM in a single pass: 2 reads + 2 writes per element
  instead of 4 reads + 2 writes unfused.

* ``easgd_round_update`` — a whole masked sequential round in ONE launch.
  The replica index is a Pallas grid dimension; the *fired* replica ids
  arrive via scalar prefetch (PrefetchScalarGridSpec) and drive the stack
  block index maps, so an un-fired replica is never fetched and never
  written — zero HBM traffic for it. The PS plane is a revisited output
  block: it stays resident in VMEM while all fired replicas of a block
  stream past it (sequential Algorithm-2 semantics: replica i+1 sees the
  PS already moved by replica i), costing one HBM read + one write per
  block instead of one per replica. Stack and PS are aliased in/out, so
  un-fired rows keep their buffer contents and the launch updates in place.

Elastic membership (DESIGN.md §8): the fired-ids design IS the active-mask
mechanism — the host intersects fired ∩ membership.active (core/algorithms
``EASGD.launch_snapshot_flat`` / ``land_flat``), so a dead slot's id simply
never appears in ``fired``: zero HBM traffic, bit-identical rows, and the
(F, n, 128) snapshot carries its own row ids so a slot that dies while the
sync is in flight is dropped at landing without breaking positional
alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flatspace import LANE


def _pair_kernel(ps_ref, wi_ref, new_ps_ref, new_wi_ref, *, alpha: float):
    ps = ps_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    new_ps = (1.0 - alpha) * ps + alpha * wi
    new_wi = (1.0 - alpha) * wi + alpha * new_ps
    new_ps_ref[...] = new_ps.astype(new_ps_ref.dtype)
    new_wi_ref[...] = new_wi.astype(new_wi_ref.dtype)


def easgd_update(
    w_ps: jnp.ndarray,
    w_i: jnp.ndarray,
    alpha: float,
    *,
    block: int = 1024,
    lanes: int = LANE,
    interpret: bool = False,
):
    """w_ps, w_i: (n, 128) flat planes. Returns (new_ps, new_wi)."""
    n, l = w_ps.shape
    assert l == lanes and n % block == 0, (w_ps.shape, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_pair_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(w_ps.shape, w_ps.dtype),
            jax.ShapeDtypeStruct(w_i.shape, w_i.dtype),
        ),
        interpret=interpret,
    )(w_ps, w_i)


def _round_kernel(fired_ref, stack_ref, snap_ref, ps_ref,
                  out_stack_ref, out_ps_ref, *, alpha: float):
    i = pl.program_id(1)  # position in the fired-replica axis (fast axis)

    # First fired replica of this block: seed the resident PS accumulator.
    @pl.when(i == 0)
    def _():
        out_ps_ref[...] = ps_ref[...].astype(jnp.float32)

    ps = out_ps_ref[...]
    wi = stack_ref[0].astype(jnp.float32)
    snap = snap_ref[0].astype(jnp.float32)
    # PS moves toward the launch snapshot; the pull-back lands on the
    # current (still-moving) replica — paper §3.3.
    new_ps = (1.0 - alpha) * ps + alpha * snap
    new_wi = (1.0 - alpha) * wi + alpha * new_ps
    out_ps_ref[...] = new_ps
    out_stack_ref[0] = new_wi.astype(out_stack_ref.dtype)


def easgd_round_update(
    stack: jnp.ndarray,
    w_ps: jnp.ndarray,
    snapshot: jnp.ndarray,
    fired: jnp.ndarray,
    alpha: float,
    *,
    block: int = 256,
    interpret: bool = False,
):
    """Masked sequential EASGD round in one launch.

    stack: (R, n, 128) fp32 replica buffer; w_ps: (n, 128) fp32;
    fired: (F,) int32 replica ids whose shadow clock fired, in exchange order;
    snapshot: (F, n, 128) fp32 — launch-time copies of the FIRED replicas
    only, positionally aligned with ``fired`` (un-fired replicas are never
    consumed, so they are never snapshotted).
    Returns (new_stack, new_ps); rows not in ``fired`` are bit-identical.
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    F = fired.shape[0]
    assert snapshot.shape[0] == F, (snapshot.shape, F)
    stack_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, i, fired_ref: (fired_ref[i], j, 0)
    )
    snap_spec = pl.BlockSpec((1, block, LANE), lambda j, i, fired_ref: (i, j, 0))
    ps_spec = pl.BlockSpec((block, LANE), lambda j, i, fired_ref: (j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // block, F),
        in_specs=[stack_spec, snap_spec, ps_spec],
        out_specs=[stack_spec, ps_spec],
    )
    return pl.pallas_call(
        functools.partial(_round_kernel, alpha=alpha),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(stack.shape, stack.dtype),
            jax.ShapeDtypeStruct(w_ps.shape, jnp.float32),
        ],
        # operand order incl. scalar prefetch: (fired, stack, snap, ps)
        input_output_aliases={1: 0, 3: 1},
        interpret=interpret,
    )(fired, stack, snapshot, w_ps)

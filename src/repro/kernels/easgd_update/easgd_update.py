"""Pallas TPU kernel: fused ShadowSync-EASGD exchange.

Algorithm 2 is two dependent elementwise lerps over the full dense parameter
vector — pure memory-bandwidth work that the shadow thread runs continuously.
Unfused, XLA reads w_ps and w_i twice (once per lerp); this kernel streams both
through VMEM once and writes both results in a single pass: 2 reads + 2 writes
per element instead of 4 reads + 2 writes (1.5x less HBM traffic on the op the
background sync is made of).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ps_ref, wi_ref, new_ps_ref, new_wi_ref, *, alpha: float):
    ps = ps_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    new_ps = (1.0 - alpha) * ps + alpha * wi
    new_wi = (1.0 - alpha) * wi + alpha * new_ps
    new_ps_ref[...] = new_ps.astype(new_ps_ref.dtype)
    new_wi_ref[...] = new_wi.astype(new_wi_ref.dtype)


def easgd_update(
    w_ps: jnp.ndarray,
    w_i: jnp.ndarray,
    alpha: float,
    *,
    block: int = 1024,
    lanes: int = 128,
    interpret: bool = False,
):
    """w_ps, w_i: (n, 128)-reshaped flat params. Returns (new_ps, new_wi)."""
    n, l = w_ps.shape
    assert l == lanes and n % block == 0, (w_ps.shape, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(w_ps.shape, w_ps.dtype),
            jax.ShapeDtypeStruct(w_i.shape, w_i.dtype),
        ),
        interpret=interpret,
    )(w_ps, w_i)

"""Jitted wrapper: applies the fused EASGD kernel across a whole parameter pytree
by flattening + concatenating leaves into one (n, 128) stream (padding the tail),
so the shadow thread's exchange is a single kernel launch per sync."""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.easgd_update.easgd_update import easgd_update
from repro.kernels.easgd_update.ref import easgd_update_ref

LANE = 128
BLOCK = 1024


def _flatten(tree: Any) -> Tuple[jnp.ndarray, Any, list, int]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    total = flat.size
    padded = -(-total // (LANE * BLOCK)) * (LANE * BLOCK)
    flat = jnp.pad(flat, (0, padded - total)).reshape(-1, LANE)
    return flat, treedef, sizes, total


def _unflatten(flat: jnp.ndarray, treedef, sizes, total, like: Any) -> Any:
    vec = flat.reshape(-1)[:total]
    leaves, out, off = jax.tree_util.tree_leaves(like), [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(vec[off : off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas", "interpret"))
def easgd_pair_op(w_ps: Any, w_i: Any, alpha: float, *, use_pallas: bool = True,
                  interpret: bool = True) -> Tuple[Any, Any]:
    """Fused Algorithm-2 exchange over arbitrary pytrees."""
    ps_flat, treedef, sizes, total = _flatten(w_ps)
    wi_flat, _, _, _ = _flatten(w_i)
    if use_pallas:
        new_ps, new_wi = easgd_update(ps_flat, wi_flat, alpha, block=BLOCK, interpret=interpret)
    else:
        new_ps, new_wi = easgd_update_ref(ps_flat, wi_flat, alpha)
    return (
        _unflatten(new_ps, treedef, sizes, total, w_ps),
        _unflatten(new_wi, treedef, sizes, total, w_i),
    )

"""Jitted EASGD entry points over flat replica space.

``easgd_round_op`` / ``easgd_pair_flat_op`` are the runners' native path:
state already lives in a persistent FlatSpace buffer, so a sync is exactly
one kernel launch — no flatten, no concat, no padding at sync time.

``easgd_pair_op`` keeps the legacy arbitrary-pytree API (tests, ad-hoc use):
it packs through FlatSpace per call, which is the cost the flat engine
exists to avoid.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flatspace import FlatSpace
from repro.kernels.backend import resolve_interpret
from repro.kernels.easgd_update.easgd_update import easgd_round_update, easgd_update
from repro.kernels.easgd_update.ref import easgd_round_ref, easgd_update_ref

BLOCK = 256


@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas", "interpret", "block"))
def easgd_pair_flat_op(w_ps: jnp.ndarray, w_i: jnp.ndarray, alpha: float, *,
                       use_pallas: bool = True, interpret: Optional[bool] = None,
                       block: int = BLOCK) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One PS<->replica exchange on (n, 128) flat planes. NOT donated: the
    threaded runner's trainer threads may still be reading these planes."""
    if use_pallas:
        return easgd_update(w_ps, w_i, alpha, block=block,
                            interpret=resolve_interpret(interpret))
    return easgd_update_ref(w_ps, w_i, alpha)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=("alpha", "use_pallas", "interpret", "block"))
def easgd_round_op(stack: jnp.ndarray, w_ps: jnp.ndarray, snapshot: jnp.ndarray,
                   fired: jnp.ndarray, alpha: float, *, use_pallas: bool = True,
                   interpret: Optional[bool] = None,
                   block: int = BLOCK) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked sequential round over a (R, n, 128) replica buffer, one launch.

    ``fired``: (F,) int32 replica ids in exchange order; ``snapshot``:
    (F, n, 128) launch copies of exactly the fired replicas (positional).
    Retraces per distinct F (the shadow schedule produces only a handful of
    fired-set sizes). ``stack`` and ``w_ps`` are donated — the kernel updates
    them in place; ``snapshot`` must be a separate buffer, never the live
    stack.
    """
    if use_pallas:
        return easgd_round_update(stack, w_ps, snapshot, fired, alpha,
                                  block=block, interpret=resolve_interpret(interpret))
    return easgd_round_ref(stack, w_ps, snapshot, fired, alpha)


@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas", "interpret"))
def easgd_pair_op(w_ps: Any, w_i: Any, alpha: float, *, use_pallas: bool = True,
                  interpret: Optional[bool] = None) -> Tuple[Any, Any]:
    """Fused Algorithm-2 exchange over arbitrary pytrees (packs per call)."""
    space = FlatSpace.from_tree(w_ps, block=BLOCK)
    ps_flat = space.pack(w_ps)
    wi_flat = space.pack(w_i)
    if use_pallas:
        new_ps, new_wi = easgd_update(ps_flat, wi_flat, alpha, block=space.block,
                                      interpret=resolve_interpret(interpret))
    else:
        new_ps, new_wi = easgd_update_ref(ps_flat, wi_flat, alpha)
    return space.unpack(new_ps), space.unpack(new_wi)

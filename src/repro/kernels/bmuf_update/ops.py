"""Jitted BMUF sync entry point over flat replica space: one launch per
background landing (the launch-time replica mean comes from
``ma_update.replica_mean_op``)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.bmuf_update.bmuf_update import bmuf_update, bmuf_update_rows
from repro.kernels.bmuf_update.ref import bmuf_update_ref, bmuf_update_rows_ref

BLOCK = 256


@functools.partial(jax.jit, donate_argnums=(0, 2, 3), static_argnames=(
    "alpha", "eta", "block_momentum", "nesterov", "scale",
    "use_pallas", "interpret", "block"))
def bmuf_sync_op(stack: jnp.ndarray, mean: jnp.ndarray, w_global: jnp.ndarray,
                 velocity: jnp.ndarray, alpha: float, *, eta: float = 1.0,
                 block_momentum: float = 0.0, nesterov: bool = False,
                 scale: float = 1.0, use_pallas: bool = True,
                 interpret: Optional[bool] = None, block: int = BLOCK,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused Algorithm-4 landing. Returns (new_stack, new_w_global, new_velocity)."""
    if use_pallas:
        return bmuf_update(stack, mean, w_global, velocity, alpha, eta=eta,
                           block_momentum=block_momentum, nesterov=nesterov,
                           scale=scale, block=block,
                           interpret=resolve_interpret(interpret))
    return bmuf_update_ref(stack, mean, w_global, velocity, alpha, eta=eta,
                           block_momentum=block_momentum, nesterov=nesterov,
                           scale=scale)


@functools.partial(jax.jit, donate_argnums=(0, 2, 3), static_argnames=(
    "alpha", "eta", "block_momentum", "nesterov", "scale",
    "use_pallas", "interpret", "block"))
def bmuf_sync_rows_op(stack: jnp.ndarray, mean: jnp.ndarray,
                      w_global: jnp.ndarray, velocity: jnp.ndarray,
                      rows: jnp.ndarray, alpha: float, *, eta: float = 1.0,
                      block_momentum: float = 0.0, nesterov: bool = False,
                      scale: float = 1.0, use_pallas: bool = True,
                      interpret: Optional[bool] = None, block: int = BLOCK,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused Algorithm-4 landing restricted to the LIVE rows (elastic
    membership): dead slots move zero HBM bytes and stay bit-identical.
    Retraces per distinct live count only."""
    if use_pallas:
        return bmuf_update_rows(stack, mean, w_global, velocity, rows, alpha,
                                eta=eta, block_momentum=block_momentum,
                                nesterov=nesterov, scale=scale, block=block,
                                interpret=resolve_interpret(interpret))
    return bmuf_update_rows_ref(stack, mean, w_global, velocity, rows, alpha,
                                eta=eta, block_momentum=block_momentum,
                                nesterov=nesterov, scale=scale)

"""Pallas TPU kernel: fused BMUF sync (Algorithm 4) on flat replica space.

The pytree path chains mean -> descent -> block-momentum -> global step ->
(optional Nesterov look-ahead) -> broadcast -> lerp: every N-sized state op
is a separate HBM round trip and the stack is streamed three more times
(DESIGN.md §3.3).

Here the whole landing is ONE launch. The N-sized state math (velocity,
w_global, look-ahead) runs once per block on the first replica grid step and
the results stay VMEM-resident — revisited output blocks — while every
replica streams by exactly once for the elastic pull-back:

    read  R*N (stack) + 3N (mean, w_global, velocity)
    write R*N (stack) + 2N (w_global, velocity)

The replica mean itself is computed at sync-launch time by
``ma_update.replica_mean`` (it IS the decentralized launch snapshot).

Elastic membership (DESIGN.md §8): ``bmuf_update_rows`` lands only on the
LIVE replica rows — their ids arrive via scalar prefetch and drive the stack
index maps, so dead slots move zero HBM bytes and keep their buffer contents
bit-identical; the N-sized global step is membership-independent (w_global
and velocity have no replica axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flatspace import LANE


def _bmuf_kernel(stack_ref, mean_ref, wg_ref, vel_ref,
                 out_stack_ref, out_wg_ref, out_vel_ref, *,
                 alpha: float, eta: float, block_momentum: float,
                 nesterov: bool, scale: float):
    i = pl.program_id(1)

    # First replica of this block: run the N-sized global step once; the
    # results stay resident in the revisited out blocks for i > 0.
    @pl.when(i == 0)
    def _():
        desc = mean_ref[...] - wg_ref[...]
        vel = block_momentum * vel_ref[...] + eta * scale * desc
        out_vel_ref[...] = vel
        out_wg_ref[...] = wg_ref[...] + vel

    vel = out_vel_ref[...]
    wg = out_wg_ref[...]
    look = wg + block_momentum * vel if nesterov else wg
    wi = stack_ref[0].astype(jnp.float32)
    out_stack_ref[0] = ((1.0 - alpha) * wi + alpha * look).astype(out_stack_ref.dtype)


def bmuf_update(
    stack: jnp.ndarray,
    mean: jnp.ndarray,
    w_global: jnp.ndarray,
    velocity: jnp.ndarray,
    alpha: float,
    *,
    eta: float = 1.0,
    block_momentum: float = 0.0,
    nesterov: bool = False,
    scale: float = 1.0,
    block: int = 256,
    interpret: bool = False,
):
    """One-launch BMUF landing on flat replica space.

    stack: (R, n, 128); mean, w_global, velocity: (n, 128) fp32.
    Returns (new_stack, new_w_global, new_velocity).
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    stack_spec = pl.BlockSpec((1, block, LANE), lambda j, i: (i, j, 0))
    plane_spec = pl.BlockSpec((block, LANE), lambda j, i: (j, 0))
    return pl.pallas_call(
        functools.partial(
            _bmuf_kernel, alpha=alpha, eta=eta,
            block_momentum=block_momentum, nesterov=nesterov, scale=scale,
        ),
        grid=(n // block, R),
        in_specs=[stack_spec, plane_spec, plane_spec, plane_spec],
        out_specs=[stack_spec, plane_spec, plane_spec],
        out_shape=[
            jax.ShapeDtypeStruct(stack.shape, stack.dtype),
            jax.ShapeDtypeStruct(w_global.shape, jnp.float32),
            jax.ShapeDtypeStruct(velocity.shape, jnp.float32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(stack, mean, w_global, velocity)


def _bmuf_rows_kernel(rows_ref, stack_ref, mean_ref, wg_ref, vel_ref,
                      out_stack_ref, out_wg_ref, out_vel_ref, *,
                      alpha: float, eta: float, block_momentum: float,
                      nesterov: bool, scale: float):
    del rows_ref  # consumed by the index maps
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        desc = mean_ref[...] - wg_ref[...]
        vel = block_momentum * vel_ref[...] + eta * scale * desc
        out_vel_ref[...] = vel
        out_wg_ref[...] = wg_ref[...] + vel

    vel = out_vel_ref[...]
    wg = out_wg_ref[...]
    look = wg + block_momentum * vel if nesterov else wg
    wi = stack_ref[0].astype(jnp.float32)
    out_stack_ref[0] = ((1.0 - alpha) * wi + alpha * look).astype(out_stack_ref.dtype)


def bmuf_update_rows(
    stack: jnp.ndarray,
    mean: jnp.ndarray,
    w_global: jnp.ndarray,
    velocity: jnp.ndarray,
    rows: jnp.ndarray,
    alpha: float,
    *,
    eta: float = 1.0,
    block_momentum: float = 0.0,
    nesterov: bool = False,
    scale: float = 1.0,
    block: int = 256,
    interpret: bool = False,
):
    """One-launch BMUF landing restricted to the LIVE rows.

    stack: (R, n, 128); mean, w_global, velocity: (n, 128) fp32;
    rows: (A,) int32 active replica ids. Dead rows are never fetched or
    written (the in/out aliasing keeps them bit-identical).
    Returns (new_stack, new_w_global, new_velocity).
    """
    R, n, lanes = stack.shape
    assert lanes == LANE and n % block == 0, (stack.shape, block)
    A = rows.shape[0]
    assert A >= 1, "bmuf_update_rows needs at least one live row"
    stack_spec = pl.BlockSpec(
        (1, block, LANE), lambda j, i, rows_ref: (rows_ref[i], j, 0)
    )
    plane_spec = pl.BlockSpec((block, LANE), lambda j, i, rows_ref: (j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // block, A),
        in_specs=[stack_spec, plane_spec, plane_spec, plane_spec],
        out_specs=[stack_spec, plane_spec, plane_spec],
    )
    return pl.pallas_call(
        functools.partial(
            _bmuf_rows_kernel, alpha=alpha, eta=eta,
            block_momentum=block_momentum, nesterov=nesterov, scale=scale,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(stack.shape, stack.dtype),
            jax.ShapeDtypeStruct(w_global.shape, jnp.float32),
            jax.ShapeDtypeStruct(velocity.shape, jnp.float32),
        ],
        # operand order incl. scalar prefetch: (rows, stack, mean, wg, vel)
        input_output_aliases={1: 0},
        interpret=interpret,
    )(rows, stack, mean, w_global, velocity)

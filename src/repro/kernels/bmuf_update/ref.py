"""Pure-jnp oracle for the fused BMUF landing (== core.sync.bmuf_round given
a precomputed snapshot mean, on flat replica buffers)."""
import jax.numpy as jnp


def bmuf_update_ref(stack, mean, w_global, velocity, alpha, *,
                    eta=1.0, block_momentum=0.0, nesterov=False, scale=1.0):
    desc = mean.astype(jnp.float32) - w_global
    vel = block_momentum * velocity + eta * scale * desc
    wg = w_global + vel
    look = wg + block_momentum * vel if nesterov else wg
    wi = stack.astype(jnp.float32)
    new_stack = ((1.0 - alpha) * wi + alpha * look[None]).astype(stack.dtype)
    return new_stack, wg, vel


def bmuf_update_rows_ref(stack, mean, w_global, velocity, rows, alpha, *,
                         eta=1.0, block_momentum=0.0, nesterov=False,
                         scale=1.0):
    """Elastic-membership landing: the global step is unchanged, the elastic
    pull-back touches only the live ``rows``."""
    desc = mean.astype(jnp.float32) - w_global
    vel = block_momentum * velocity + eta * scale * desc
    wg = w_global + vel
    look = wg + block_momentum * vel if nesterov else wg
    sub = stack[rows].astype(jnp.float32)
    new = ((1.0 - alpha) * sub + alpha * look[None]).astype(stack.dtype)
    return stack.at[rows].set(new), wg, vel

"""ShadowSync synchronization algorithms (paper Algorithms 1-4), as pure pytree math.

Shadow and fixed-rate (FR) variants share these updates; what differs is *when* and
*from which snapshot* they are applied (see core/runners.py and core/spmd.py):

- Shadow: applied by a background shadow thread at its own cadence; the elastic
  pull-back interpolates the sync result into the *current* (still-moving) replica
  instead of overwriting it — the paper's key modification (§3.3).
- FR: applied in the foreground every k iterations, blocking the worker.

All functions are jit-friendly and operate on arbitrary pytrees. Replica stacks are
pytrees whose leaves carry a leading replica dimension R.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def lerp(a: Pytree, b: Pytree, alpha: float) -> Pytree:
    """(1-alpha) * a + alpha * b, elementwise over the pytree, in fp32."""
    return jax.tree.map(
        lambda x,
        y: ((1.0 - alpha) * x.astype(jnp.float32) + alpha * y.astype(jnp.float32)).astype(x.dtype),
        a,
        b,
    )


def replica_mean(stack: Pytree) -> Pytree:
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0), stack)


def _bc_mask(mask: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (R,) mask over a leaf with leading replica dim."""
    return mask.reshape((-1,) + (1,) * (x.ndim - 1))


def masked_replica_mean(stack: Pytree, active: jnp.ndarray) -> Pytree:
    """Mean over only the ACTIVE replicas — the elastic-membership
    denominator (dead slots contribute nothing, the mean divides by the
    live count). ``active``: (R,) bool."""
    cnt = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
    return jax.tree.map(
        lambda x: jnp.sum(jnp.where(_bc_mask(active, x), x.astype(jnp.float32), 0.0), axis=0) / cnt,
        stack,
    )


def tree_slice(stack: Pytree, i) -> Pytree:
    return jax.tree.map(lambda x: x[i], stack)


def tree_set(stack: Pytree, i, val: Pytree) -> Pytree:
    return jax.tree.map(lambda x, v: x.at[i].set(v.astype(x.dtype)), stack, val)


# ---------------------------------------------------------------------------
# EASGD (centralized; Algorithm 2)
# ---------------------------------------------------------------------------

def easgd_pair_update(w_ps: Pytree, w_i: Pytree, alpha: float) -> Tuple[Pytree, Pytree]:
    """One shadow-EASGD exchange between the sync-PS copy and replica i.

    Asymmetric elastic interpolation: the PS moves toward the (snapshot of the)
    replica, then the replica moves toward the *updated* PS. They are NOT equal
    afterwards — both sides keep trusting their own copy (paper §3.3)."""
    new_ps = lerp(w_ps, w_i, alpha)
    new_wi = lerp(w_i, new_ps, alpha)
    return new_ps, new_wi


def easgd_round(
    w_stack: Pytree,
    w_ps: Pytree,
    alpha: float,
    mask: Optional[jnp.ndarray] = None,
    snapshot: Optional[Pytree] = None,
) -> Tuple[Pytree, Pytree]:
    """Sequential EASGD over all replicas (shadow threads reach the PS one at a
    time). ``mask[i]`` selects which replicas' shadow clocks fired this round.
    ``snapshot`` (if given) is the replica stack at sync-launch time: the PS moves
    toward the snapshot while the pull-back lands on the current replica —
    training continued while the background exchange was in flight."""
    R = jax.tree.leaves(w_stack)[0].shape[0]
    mask = jnp.ones((R,), bool) if mask is None else mask
    snap = snapshot if snapshot is not None else w_stack

    def body(w_ps, args):
        w_i, w_i_snap, m = args
        new_ps = lerp(w_ps, w_i_snap, alpha)
        new_wi = lerp(w_i, new_ps, alpha)
        keep = lambda new, old: jnp.where(m, new, old)
        return (jax.tree.map(keep, new_ps, w_ps), jax.tree.map(keep, new_wi, w_i))

    w_ps, new_stack = jax.lax.scan(body, w_ps, (w_stack, snap, mask))
    return new_stack, w_ps


# ---------------------------------------------------------------------------
# Model Averaging (decentralized; Algorithm 3)
# ---------------------------------------------------------------------------

def ma_round(
    w_stack: Pytree,
    alpha: float,
    snapshot: Optional[Pytree] = None,
    active: Optional[jnp.ndarray] = None,
    land_active: Optional[jnp.ndarray] = None,
) -> Pytree:
    """AllReduce-average the replicas, then elastically pull each replica toward
    the average. ``snapshot`` (if given) is the replica stack at sync-launch time —
    the average is computed from it while the pull-back lands on the current stack,
    modeling training that continued during the background AllReduce.

    Elastic membership: ``active`` ((R,) bool) restricts the MEAN to the
    replicas live at launch (divide by the live count); ``land_active``
    restricts the pull-back to the replicas live at landing (defaults to
    ``active`` — dead slots are untouched either way)."""
    src = snapshot if snapshot is not None else w_stack
    if active is None:
        w_global = replica_mean(src)
    else:
        w_global = masked_replica_mean(src, active)
    bcast = jax.tree.map(
        lambda g, x: jnp.broadcast_to(g.astype(x.dtype), x.shape), w_global, w_stack
    )
    new = lerp(w_stack, bcast, alpha)
    if land_active is None:
        land_active = active
    if land_active is None:
        return new
    return jax.tree.map(lambda n, x: jnp.where(_bc_mask(land_active, x), n, x), new, w_stack)


# ---------------------------------------------------------------------------
# BMUF (decentralized; Algorithm 4)
# ---------------------------------------------------------------------------

@dataclass
class BMUFState:
    w_global: Pytree
    velocity: Pytree  # block momentum buffer

    @staticmethod
    def init(w0: Pytree) -> "BMUFState":
        return BMUFState(
            w_global=jax.tree.map(lambda x: x.astype(jnp.float32), w0),
            velocity=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), w0),
        )


jax.tree_util.register_dataclass(BMUFState, data_fields=["w_global", "velocity"], meta_fields=[])


def bmuf_round(
    w_stack: Pytree,
    state: BMUFState,
    alpha: float,
    *,
    eta: float = 1.0,
    block_momentum: float = 0.0,
    nesterov: bool = False,
    step_scale_n: bool = False,
    snapshot: Optional[Pytree] = None,
    active: Optional[jnp.ndarray] = None,
    land_active: Optional[jnp.ndarray] = None,
) -> Tuple[Pytree, BMUFState]:
    """Algorithm 4. AllReduce-average -> descent direction vs w_global -> (optional
    block-momentum / Nesterov) global step -> elastic pull-back into each replica.
    Elastic membership: ``active`` ((R,) bool) restricts the mean to the
    replicas live at launch (divide by the live count); ``land_active``
    restricts the pull-back to the replicas live at landing (defaults to
    ``active``); the global (w_global, velocity) step is
    membership-independent.

    ``step_scale_n=True`` reproduces the paper's line 9 literally
    (w_global += n * w_desc). With the elastic pull-back (alpha < 1) the replicas
    only partially adopt w_global, so the n-scaled step compounds and diverges at
    small sync gaps — we default to the classic BMUF block step (scale 1) and
    expose the paper's variant; see EXPERIMENTS.md §Paper-validation notes."""
    R = jax.tree.leaves(w_stack)[0].shape[0]
    src = snapshot if snapshot is not None else w_stack
    w_copy = (replica_mean(src) if active is None else masked_replica_mean(src, active))
    desc = jax.tree.map(lambda c, g: c - g, w_copy, state.w_global)
    scale = float(R) if step_scale_n else 1.0
    vel = jax.tree.map(lambda v, d: block_momentum * v + eta * scale * d, state.velocity, desc)
    w_global = jax.tree.map(lambda g, v: g + v, state.w_global, vel)
    if nesterov:
        look = jax.tree.map(lambda g, v: g + block_momentum * v, w_global, vel)
    else:
        look = w_global
    bcast = jax.tree.map(lambda g, x: jnp.broadcast_to(g.astype(x.dtype), x.shape), look, w_stack)
    new = lerp(w_stack, bcast, alpha)
    if land_active is None:
        land_active = active
    if land_active is not None:
        new = jax.tree.map(lambda n, x: jnp.where(_bc_mask(land_active, x), n, x), new, w_stack)
    return new, BMUFState(w_global=w_global, velocity=vel)


# ---------------------------------------------------------------------------
# Sync configuration (algorithms themselves live in core/algorithms.py —
# the pluggable registry every runner/substrate dispatches through)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SyncConfig:
    algo: str = "easgd"  # any name in core.algorithms.names()
    alpha: float = 0.5
    # shadow mode: sync fires per replica every `gap` iterations with staggered
    # offsets; FR mode: foreground, all replicas at t % gap == 0.
    mode: str = "shadow"  # shadow | fixed_rate
    gap: int = 5
    # iterations of training that elapse while a background sync is in flight;
    # the sync reads the snapshot taken at launch, lands `delay` iterations later.
    delay: int = 1
    eta: float = 1.0
    block_momentum: float = 0.0
    nesterov: bool = False
    # Sync substrate (DESIGN.md §3). "flat": replicas live in a persistent
    # (R, n_rows, 128) fp32 buffer (core/flatspace.py) and every sync is one
    # fused Pallas launch. "pytree": the pure jax.tree.map path above — kept
    # as the numerical oracle for the fused kernels.
    engine: str = "flat"  # flat | pytree

    def centralized(self) -> bool:
        from repro.core import algorithms  # deferred: algorithms imports us
        return algorithms.get(self.algo).centralized

    def validate(self) -> "SyncConfig":
        from repro.core import algorithms  # deferred: algorithms imports us
        if self.algo not in algorithms.names():
            raise ValueError(
                f"unknown sync algo: {self.algo!r}; " f"registered: {list(algorithms.names())}"
            )
        if self.engine not in ("flat", "pytree"):
            raise ValueError(f"unknown sync engine: {self.engine!r}")
        if self.mode not in ("shadow", "fixed_rate"):
            raise ValueError(f"unknown sync mode: {self.mode!r}")
        if self.gap < 1:
            raise ValueError(
                f"gap must be >= 1 (iterations between shadow-clock fires), " f"got {self.gap}"
            )
        if self.delay < 0:
            raise ValueError(
                f"delay must be >= 0 (in-flight iterations of a background "
                f"sync; 0 lands same-iteration), got {self.delay}")
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(
                f"alpha must be in [0, 1] (elastic interpolation weight), " f"got {self.alpha}"
            )
        return self

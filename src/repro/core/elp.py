"""EPS / ELP accounting (paper Definitions 1 and 2) + the Table 1 comparison."""
from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, Tuple


def elp(batch_size: int, n_hogwild: int, n_replicas: int) -> int:
    """Example Level Parallelism: examples processed concurrently at any instant.
    Two-level data parallelism: Hogwild within a trainer x replication across."""
    return batch_size * n_hogwild * n_replicas


@dataclass
class EPSMeter:
    """Examples Per Second over a true sliding window.

    ``add(n)`` records a bucket of ``n`` examples at the current clock time;
    ``eps`` is the rate over the trailing ``window_s`` seconds (buckets older
    than the window are evicted). Before a full window has elapsed the rate
    is over the time since construction, so early readings are not inflated.
    This matters for elasticity measurements: after a trainer crashes, the
    windowed rate converges to the SURVIVORS' pace instead of being diluted
    forever by the dead trainer's early contribution (a cumulative
    examples-since-construction rate — the previous implementation — never
    recovers). ``clock`` is injectable for deterministic tests AND for
    running the meter on a virtual clock: ``SlotEPS`` below feeds each
    per-trainer meter that trainer's accumulated BUSY time, so the reading
    is the trainer's intrinsic pace even while it blocks at a foreground
    sync barrier.

    Concurrency: one writer (``add``, which evicts) + any readers (``eps``
    never mutates — it snapshots the deque and filters, so a reader racing a
    writer cannot mis-evict a live bucket).
    """

    window_s: float = 5.0
    clock: Callable[[], float] = time.perf_counter
    _t0: float = field(init=False)
    _buckets: Deque[Tuple[float, int]] = field(init=False)

    def __post_init__(self) -> None:
        self._t0 = self.clock()
        self._buckets = deque()  # hogwild-race: ok — single writer, readers snapshot

    def _evict(self, now: float) -> None:
        # strictly-older-than-window: a bucket exactly at the cutoff is kept
        cutoff = now - self.window_s
        while self._buckets and self._buckets[0][0] < cutoff:
            self._buckets.popleft()

    def add(self, n: int) -> None:
        now = self.clock()
        self._buckets.append((now, n))
        self._evict(now)

    @property
    def eps(self) -> float:
        now = self.clock()
        span = min(now - self._t0, self.window_s)
        if span <= 0:
            return 0.0
        cutoff = now - self.window_s
        # list(deque) is atomic under the GIL; filtering instead of evicting
        # keeps this read-only (safe against a concurrent add)
        return sum(n for t, n in list(self._buckets) if t >= cutoff) / span


def median_eps(values: Iterable[float]) -> float:
    """Median of a (possibly empty) collection of rates; empty -> 0.0."""
    vals = list(values)
    return float(statistics.median(vals)) if vals else 0.0


class SlotEPS:
    """A bank of per-slot ``EPSMeter``s — the straggler controller's signal
    source (``core/scheduler.py`` reads ``eps_by_slot`` and takes its own
    ``median_eps`` over the slots it considers comparable).

    Each slot's meter runs on that slot's own virtual clock — ``tick(slot,
    busy_s)`` advances it by the seconds the trainer actually spent working
    (compute + any injected degradation), ``add(slot, n)`` then records the
    examples at that clock. Excluding time blocked at a foreground sync
    barrier is the point: under ``mode="fixed_rate"`` the barrier equalizes
    everyone's WALL-clock rate (the healthy trainers wait for the straggler),
    so a wall-time meter cannot tell who the straggler is. Busy-time can.

    Thread model: slot ``i`` is written only by trainer thread ``i``; the
    controller only reads (``eps`` is non-mutating), so no lock is needed.
    """

    def __init__(self, n_slots: int, window_s: float = 5.0):
        if n_slots < 1:
            raise ValueError(f"need n_slots >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.window_s = float(window_s)
        self._busy = [0.0] * self.n_slots  # hogwild-race: ok — slot-owned cells
        # hogwild-race: ok — slot-owned meters: only owner slot i mutates _meters[i]
        self._meters = [
            EPSMeter(window_s=window_s, clock=self._make_clock(i)) for i in range(self.n_slots)
        ]

    def _make_clock(self, slot: int) -> Callable[[], float]:
        return lambda: self._busy[slot]

    def tick(self, slot: int, busy_s: float) -> None:
        """Advance slot's virtual clock by ``busy_s`` seconds of real work."""
        self._busy[slot] += busy_s

    def add(self, slot: int, n: int) -> None:
        self._meters[slot].add(n)

    def busy(self, slot: int) -> float:
        return self._busy[slot]

    def eps(self, slot: int) -> float:
        return self._meters[slot].eps

    def eps_by_slot(self) -> Dict[int, float]:
        return {i: self._meters[i].eps for i in range(self.n_slots)}


# Paper Table 1 — ELP of prior art (batch, #hogwild, #replicas as reported).
PAPER_TABLE1 = {
    "ShadowSync": dict(batch=200, hogwild=24, replicas=20, elp=96000),
    "EASGD": dict(batch=128, hogwild=1, replicas=16, elp=2048),
    "DC-ASGD": dict(batch=128, hogwild=16, replicas=1, elp=2048),
    "BMUF": dict(batch=None, hogwild=1, replicas=64, elp=None),  # 64 x B
    "DownpourSGD": dict(batch=None, hogwild=1, replicas=200, elp=None),  # 200 x B
    "ADPSGD": dict(batch=128, hogwild=1, replicas=128, elp=16384),
    "LARS": dict(batch=32000, hogwild=1, replicas=1, elp=32000),
    "SGP": dict(batch=256, hogwild=1, replicas=256, elp=65536),
}

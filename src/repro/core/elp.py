"""EPS / ELP accounting (paper Definitions 1 and 2) + the Table 1 comparison."""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple


def elp(batch_size: int, n_hogwild: int, n_replicas: int) -> int:
    """Example Level Parallelism: examples processed concurrently at any instant.
    Two-level data parallelism: Hogwild within a trainer x replication across."""
    return batch_size * n_hogwild * n_replicas


@dataclass
class EPSMeter:
    """Examples Per Second over a true sliding window.

    ``add(n)`` records a bucket of ``n`` examples at the current clock time;
    ``eps`` is the rate over the trailing ``window_s`` seconds (buckets older
    than the window are evicted). Before a full window has elapsed the rate
    is over the time since construction, so early readings are not inflated.
    This matters for elasticity measurements: after a trainer crashes, the
    windowed rate converges to the SURVIVORS' pace instead of being diluted
    forever by the dead trainer's early contribution (a cumulative
    examples-since-construction rate — the previous implementation — never
    recovers). ``clock`` is injectable for deterministic tests.
    """

    window_s: float = 5.0
    clock: Callable[[], float] = time.perf_counter
    _t0: float = field(init=False)
    _buckets: Deque[Tuple[float, int]] = field(init=False)

    def __post_init__(self) -> None:
        self._t0 = self.clock()
        self._buckets = deque()

    def _evict(self, now: float) -> None:
        cutoff = now - self.window_s
        while self._buckets and self._buckets[0][0] < cutoff:
            self._buckets.popleft()

    def add(self, n: int) -> None:
        now = self.clock()
        self._buckets.append((now, n))
        self._evict(now)

    @property
    def eps(self) -> float:
        now = self.clock()
        self._evict(now)
        span = min(now - self._t0, self.window_s)
        if span <= 0:
            return 0.0
        return sum(n for _, n in self._buckets) / span


# Paper Table 1 — ELP of prior art (batch, #hogwild, #replicas as reported).
PAPER_TABLE1 = {
    "ShadowSync": dict(batch=200, hogwild=24, replicas=20, elp=96000),
    "EASGD": dict(batch=128, hogwild=1, replicas=16, elp=2048),
    "DC-ASGD": dict(batch=128, hogwild=16, replicas=1, elp=2048),
    "BMUF": dict(batch=None, hogwild=1, replicas=64, elp=None),  # 64 x B
    "DownpourSGD": dict(batch=None, hogwild=1, replicas=200, elp=None),  # 200 x B
    "ADPSGD": dict(batch=128, hogwild=1, replicas=128, elp=16384),
    "LARS": dict(batch=32000, hogwild=1, replicas=1, elp=32000),
    "SGP": dict(batch=256, hogwild=1, replicas=256, elp=65536),
}

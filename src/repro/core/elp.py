"""EPS / ELP accounting (paper Definitions 1 and 2) + the Table 1 comparison."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


def elp(batch_size: int, n_hogwild: int, n_replicas: int) -> int:
    """Example Level Parallelism: examples processed concurrently at any instant.
    Two-level data parallelism: Hogwild within a trainer x replication across."""
    return batch_size * n_hogwild * n_replicas


@dataclass
class EPSMeter:
    """Examples Per Second over a sliding window."""

    _t0: float = field(default_factory=time.perf_counter)
    _examples: int = 0

    def add(self, n: int) -> None:
        self._examples += n

    @property
    def eps(self) -> float:
        dt = time.perf_counter() - self._t0
        return self._examples / dt if dt > 0 else 0.0


# Paper Table 1 — ELP of prior art (batch, #hogwild, #replicas as reported).
PAPER_TABLE1 = {
    "ShadowSync": dict(batch=200, hogwild=24, replicas=20, elp=96000),
    "EASGD": dict(batch=128, hogwild=1, replicas=16, elp=2048),
    "DC-ASGD": dict(batch=128, hogwild=16, replicas=1, elp=2048),
    "BMUF": dict(batch=None, hogwild=1, replicas=64, elp=None),  # 64 x B
    "DownpourSGD": dict(batch=None, hogwild=1, replicas=200, elp=None),  # 200 x B
    "ADPSGD": dict(batch=128, hogwild=1, replicas=128, elp=16384),
    "LARS": dict(batch=32000, hogwild=1, replicas=1, elp=32000),
    "SGP": dict(batch=256, hogwild=1, replicas=256, elp=65536),
}

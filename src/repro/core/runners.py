"""Training runners realizing the paper's system on laptop-scale hardware.

Two runtimes:

* ``HogwildSim`` — deterministic, jitted simulation of n trainers x m Hogwild
  worker threads over the shared embedding tables + per-trainer dense replicas.
  Hogwild staleness semantics: all m thread-grads of an iteration are computed
  from the SAME replica snapshot, then applied sequentially through the optimizer
  (lock-free interleave, quantized at iteration granularity). Background sync is
  scheduled by shadow clocks with launch-snapshot/delayed-landing semantics.
  This runtime produces the paper-quality experiments (Tables 2-3, Figs 6-7).

* ``ThreadedShadowRunner`` — the faithful host-level realization: real Python
  threads (jitted compute releases the GIL), a genuinely racing shared embedding
  state, and a shadow thread that syncs continuously in the background at
  whatever cadence it achieves — the paper's Algorithm 1 verbatim.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sync as S
from repro.data import ctr
from repro.embeddings import table as emb
from repro.models import dlrm
from repro.optim import Optimizer

Pytree = Any


# ---------------------------------------------------------------------------
# Deterministic simulator
# ---------------------------------------------------------------------------

@dataclass
class SimState:
    w_stack: Pytree  # (R, ...) dense replicas
    opt_stack: Pytree
    emb_state: Pytree  # shared {"table", "acc"}
    w_ps: Optional[Pytree]  # EASGD central copy
    bmuf: Optional[S.BMUFState]
    step: int


class HogwildSim:
    def __init__(
        self,
        cfg,  # DLRMConfig
        sync_cfg: S.SyncConfig,
        *,
        n_trainers: int,
        n_threads: int,
        batch_size: int,
        optimizer: Optimizer,
        emb_lr: float = 0.05,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.sync_cfg = sync_cfg
        self.R, self.M, self.B = n_trainers, n_threads, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        self._build()

    # -- jitted pieces ------------------------------------------------------
    def _build(self):
        cfg, spec, opt, R, M = self.cfg, self.spec, self.opt, self.R, self.M

        def one_trainer(w, opt_state, dense, pooled, labels):
            # m thread-grads from the SAME snapshot, applied sequentially.
            loss, g_w, g_pooled = jax.vmap(
                dlrm.dense_loss_and_grads, in_axes=(None, 0, 0, 0)
            )(w, dense, pooled, labels)

            def apply_one(carry, g):
                w, st = carry
                w, st = opt.update(w, st, g)
                return (w, st), None

            (w, opt_state), _ = jax.lax.scan(apply_one, (w, opt_state), g_w)
            return w, opt_state, jnp.mean(loss), g_pooled

        def train_iter(state_w, state_opt, emb_state, batch):
            # batch leaves: (R, M, B, ...)
            idx = batch["sparse"]
            pooled = emb.lookup(
                emb_state, spec, idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            )
            pooled = pooled.reshape(self.R, self.M, self.B, cfg.n_sparse_features, -1)
            w2, opt2, loss, g_pooled = jax.vmap(one_trainer)(
                state_w, state_opt, batch["dense"], pooled, batch["labels"]
            )
            # Hogwild on the single embedding copy: every trainer/thread applies
            # immediately; one fused scatter implements the accumulate.
            flat_idx = idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            flat_g = g_pooled.reshape(-1, cfg.n_sparse_features, cfg.embedding_dim)
            emb2 = emb.sparse_adagrad_update(emb_state, spec, flat_idx, flat_g, self.emb_lr)
            return w2, opt2, emb2, jnp.mean(loss)

        self._train_iter = jax.jit(train_iter, donate_argnums=(0, 1, 2))
        self._easgd = jax.jit(
            lambda ws, ps, mask, snap: S.easgd_round(
                ws, ps, self.sync_cfg.alpha, mask=mask, snapshot=snap
            )
        )
        self._ma = jax.jit(
            lambda ws, snap: S.ma_round(ws, self.sync_cfg.alpha, snapshot=snap)
        )
        sc = self.sync_cfg
        self._bmuf = jax.jit(
            lambda ws, st, snap: S.bmuf_round(
                ws, st, sc.alpha, eta=sc.eta, block_momentum=sc.block_momentum,
                nesterov=sc.nesterov, snapshot=snap,
            )
        )

        def eval_batch(w, emb_state, batch):
            pooled = emb.lookup(emb_state, spec, batch["sparse"])
            logits = dlrm.forward(w, batch["dense"], pooled)
            return dlrm.bce_loss(logits, batch["labels"])

        self._eval = jax.jit(eval_batch)

    # -- state --------------------------------------------------------------
    def init_state(self) -> SimState:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        w_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), w0)
        opt0 = self.opt.init(w0)
        opt_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), opt0)
        emb_state = emb.init_tables(self.spec, ke)
        w_ps = jax.tree.map(lambda x: x.copy(), w0) if self.sync_cfg.centralized() else None
        bmuf = S.BMUFState.init(w0) if self.sync_cfg.algo == "bmuf" else None
        return SimState(w_stack, opt_stack, emb_state, w_ps, bmuf, 0)

    def make_batch(self, it: int) -> Dict[str, jnp.ndarray]:
        """One-pass stream: (R*M) distinct shards per iteration."""
        n = self.R * self.M
        b = ctr.gen_batch(self.cfg, self.teacher, self.seed, it, self.B * n)
        return jax.tree.map(
            lambda x: x.reshape(self.R, self.M, self.B, *x.shape[1:]), b
        )

    # -- sync scheduling ----------------------------------------------------
    def _shadow_schedule(self, t: int) -> np.ndarray:
        """mask[i]: replica i's shadow clock fires at iteration t (staggered)."""
        gap = self.sync_cfg.gap
        offs = (np.arange(self.R) * gap) // max(self.R, 1)
        return ((t + offs) % gap) == 0

    def run(self, n_iters: int, *, log_every: int = 0,
            on_iter: Optional[Callable[[int, float], None]] = None) -> Dict[str, Any]:
        st = self.init_state()
        sc = self.sync_cfg
        losses: List[float] = []
        sync_count = 0
        pending: Optional[Tuple[int, Pytree, np.ndarray]] = None  # (land_t, snapshot, mask)
        for t in range(n_iters):
            batch = self.make_batch(t)
            st.w_stack, st.opt_stack, st.emb_state, loss = self._train_iter(
                st.w_stack, st.opt_stack, st.emb_state, batch
            )
            losses.append(float(loss))
            if sc.mode == "fixed_rate":
                if (t + 1) % sc.gap == 0:
                    st = self._apply_sync(st, None, None)
                    sync_count += self.R  # every replica synced this round
            else:  # shadow
                if pending is not None and t + 1 >= pending[0]:
                    _, snap, mask = pending
                    st = self._apply_sync(st, snap, mask)
                    sync_count += int(mask.sum()) if mask is not None else self.R
                    pending = None
                if pending is None:
                    mask = self._shadow_schedule(t + 1)
                    if mask.any():
                        snap = jax.tree.map(jnp.copy, st.w_stack)  # launch snapshot (real copy: train donates buffers)
                        pending = (t + 1 + sc.delay, snap, mask)
            st.step = t + 1
            if on_iter:
                on_iter(t, losses[-1])
            if log_every and (t + 1) % log_every == 0:
                print(f"iter {t+1}: loss {np.mean(losses[-log_every:]):.5f}")
        return {
            "state": st,
            "train_loss": losses,
            "sync_count": sync_count,
            "avg_sync_gap": (n_iters * self.R / max(sync_count, 1)),
        }

    def _apply_sync(self, st: SimState, snap, mask) -> SimState:
        sc = self.sync_cfg
        mask_arr = jnp.asarray(mask) if mask is not None else jnp.ones((self.R,), bool)
        if sc.algo == "easgd":
            st.w_stack, st.w_ps = self._easgd(st.w_stack, st.w_ps, mask_arr, snap if snap is not None else st.w_stack)
        elif sc.algo == "ma":
            st.w_stack = self._ma(st.w_stack, snap)
        elif sc.algo == "bmuf":
            st.w_stack, st.bmuf = self._bmuf(st.w_stack, st.bmuf, snap)
        else:
            raise ValueError(sc.algo)
        return st

    def evaluate(self, st: SimState, n_batches: int = 20, batch_size: int = 4096,
                 replica: int = 0) -> float:
        """Paper protocol: evaluate the FIRST trainer's replica."""
        w = S.tree_slice(st.w_stack, replica)
        tot = 0.0
        for i in range(n_batches):
            b = ctr.gen_batch(self.cfg, self.teacher, self.seed + 10_000_000, i, batch_size)
            tot += float(self._eval(w, st.emb_state, b))
        return tot / n_batches


# ---------------------------------------------------------------------------
# Real-thread runner (faithful Algorithm 1)
# ---------------------------------------------------------------------------

class ThreadedShadowRunner:
    """Trainer threads + a background shadow thread over genuinely shared state.

    The embedding state is read-modify-written WITHOUT a lock (Hogwild: concurrent
    trainers can lose updates — that is the point). Dense replicas are owned by
    their trainer; the shadow thread interpolates them in the background."""

    def __init__(self, cfg, sync_cfg: S.SyncConfig, *, n_trainers: int,
                 batch_size: int, optimizer: Optimizer, emb_lr: float = 0.05,
                 seed: int = 0, sync_sleep_s: float = 0.0):
        self.cfg, self.sync_cfg = cfg, sync_cfg
        self.R, self.B = n_trainers, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.sync_sleep_s = sync_sleep_s
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        spec = self.spec

        def train_one(w, opt_state, emb_table, batch):
            pooled = emb.lookup({"table": emb_table}, spec, batch["sparse"])
            loss, g_w, g_pooled = dlrm.dense_loss_and_grads(
                w, batch["dense"], pooled, batch["labels"]
            )
            w, opt_state = optimizer.update(w, opt_state, g_w)
            return w, opt_state, loss, g_pooled

        self._train_one = jax.jit(train_one)
        self._emb_update = jax.jit(
            lambda st, idx, g: emb.sparse_adagrad_update(st, spec, idx, g, emb_lr)
        )
        self._easgd_pair = jax.jit(
            lambda ps, w: S.easgd_pair_update(ps, w, sync_cfg.alpha)
        )
        self._ma = jax.jit(lambda stack: S.ma_round(stack, sync_cfg.alpha))

    def run(self, iters_per_trainer: int) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        self.w: List[Pytree] = [jax.tree.map(lambda x: x.copy(), w0) for _ in range(self.R)]
        self.opt_states = [self.opt.init(w0) for _ in range(self.R)]
        self.emb_state = emb.init_tables(self.spec, ke)
        self.w_ps = jax.tree.map(lambda x: x.copy(), w0)
        self.done = False
        self.examples = 0
        self.sync_count = 0
        self.iter_count = [0] * self.R
        losses: List[List[float]] = [[] for _ in range(self.R)]
        ex_lock = threading.Lock()

        def trainer(i: int):
            for it in range(iters_per_trainer):
                batch = ctr.gen_batch(
                    self.cfg, self.teacher, self.seed + i, it, self.B
                )
                # Lock-free read of the shared embedding table (Hogwild).
                w, opt_state, loss, g_pooled = self._train_one(
                    self.w[i], self.opt_states[i], self.emb_state["table"], batch
                )
                self.w[i], self.opt_states[i] = w, opt_state
                # Lock-free read-modify-write: concurrent writers can interleave.
                self.emb_state = self._emb_update(self.emb_state, batch["sparse"], g_pooled)
                losses[i].append(float(loss))
                self.iter_count[i] = it + 1
                with ex_lock:
                    self.examples += self.B

        def shadow():
            algo = self.sync_cfg.algo
            while not self.done:
                if algo == "easgd":
                    for i in range(self.R):
                        ps, wi = self._easgd_pair(self.w_ps, self.w[i])
                        self.w_ps, self.w[i] = ps, wi
                        self.sync_count += 1
                else:  # decentralized: ma (bmuf analogous, ma used here)
                    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *self.w)
                    new = self._ma(stack)
                    for i in range(self.R):
                        self.w[i] = S.tree_slice(new, i)
                    self.sync_count += 1
                if self.sync_sleep_s:
                    time.sleep(self.sync_sleep_s)

        threads = [threading.Thread(target=trainer, args=(i,)) for i in range(self.R)]
        shadow_t = threading.Thread(target=shadow, daemon=True)
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        shadow_t.start()
        for t in threads:
            t.join()
        self.done = True
        shadow_t.join(timeout=5.0)
        wall = time.perf_counter() - t0
        total_iters = sum(self.iter_count)
        return {
            "eps": self.examples / wall,
            "wall_s": wall,
            "train_loss": [float(np.mean(l[-50:])) for l in losses],
            "sync_count": self.sync_count,
            "avg_sync_gap": total_iters / max(self.sync_count, 1),
            "w": self.w,
            "emb_state": self.emb_state,
        }

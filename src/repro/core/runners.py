"""Training runners realizing the paper's system on laptop-scale hardware.

Two runtimes:

* ``HogwildSim`` — deterministic, jitted simulation of n trainers x m Hogwild
  worker threads over the shared embedding tables + per-trainer dense replicas.
  Hogwild staleness semantics: all m thread-grads of an iteration are computed
  from the SAME replica snapshot, then applied sequentially through the optimizer
  (lock-free interleave, quantized at iteration granularity). Background sync is
  scheduled by shadow clocks with launch-snapshot/delayed-landing semantics.
  This runtime produces the paper-quality experiments (Tables 2-3, Figs 6-7).

* ``ThreadedShadowRunner`` — the faithful host-level realization: real Python
  threads (jitted compute releases the GIL), a genuinely racing shared embedding
  state, and a shadow thread that syncs continuously in the background at
  whatever cadence it achieves — the paper's Algorithm 1 verbatim.

Both runners default to the FLAT sync engine (DESIGN.md §3): dense replicas
live in a persistent ``(R, n_rows, 128)`` fp32 buffer (core/flatspace.py) and
every background sync is one fused Pallas launch. ``SyncConfig(engine=
"pytree")`` selects the pure jax.tree.map oracle path.

Both runners also default to the fused SPARSE substrate (DESIGN.md §7):
embedding forward is the fused lookup+pool kernel and the backward is the
fused scatter-Adagrad kernel (``kernels/embedding_bag`` /
``kernels/sparse_adagrad``; compiled on TPU, interpreter elsewhere).
``HogwildSim`` keeps one packed table (the deterministic-sim semantics);
``ThreadedShadowRunner`` realizes the paper's embedding PSs: the LPT
bin-pack plan (``embeddings/shards.py``) splits the collection into
``n_emb_shards`` independent per-PS Hogwild states, lookups route by the
plan, and trainer writes to different PSs no longer serialize through one
jitted scatter.

Neither runner knows any algorithm by name: the whole sync lifecycle —
state init, launch snapshot, landing, the threaded shadow round — is owned
by the ``SyncAlgorithm`` fetched from ``core.algorithms`` (DESIGN.md §6),
so a newly registered algorithm runs here without touching this file.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms
from repro.core import sync as S
from repro.core.flatspace import FlatSpace
from repro.data import ctr
from repro.embeddings import shards as emb_shards
from repro.embeddings import table as emb
from repro.models import dlrm
from repro.optim import Optimizer

Pytree = Any


def _dense_flatspace(cfg) -> FlatSpace:
    """Layout of the DLRM dense replica space, from shapes only (no init)."""
    shapes = jax.eval_shape(
        lambda: dlrm.init_dense(cfg, jax.random.PRNGKey(0))
    )
    return FlatSpace.from_tree(shapes)


# ---------------------------------------------------------------------------
# Deterministic simulator
# ---------------------------------------------------------------------------

@dataclass
class SimState:
    # Dense replicas: pytree stack with leading R (engine="pytree") or a
    # persistent (R, n_rows, 128) fp32 flat buffer (engine="flat").
    w_stack: Pytree
    opt_stack: Pytree
    emb_state: Pytree  # shared {"table", "acc"}
    # Opaque, owned by the SyncAlgorithm (EASGD: the sync-PS copy; BMUF:
    # global model + block momentum; gossip: round counter; MA: None).
    algo_state: Any
    step: int


class HogwildSim:
    def __init__(
        self,
        cfg,  # DLRMConfig
        sync_cfg: S.SyncConfig,
        *,
        n_trainers: int,
        n_threads: int,
        batch_size: int,
        optimizer: Optimizer,
        emb_lr: float = 0.05,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.sync_cfg = sync_cfg.validate()
        self.engine = sync_cfg.engine
        self.algo = algorithms.get(sync_cfg.algo)
        self.R, self.M, self.B = n_trainers, n_threads, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        self.flat = _dense_flatspace(cfg) if self.engine == "flat" else None
        self._build()

    # -- jitted pieces ------------------------------------------------------
    def _build(self):
        cfg, spec, opt, R, M = self.cfg, self.spec, self.opt, self.R, self.M

        def one_trainer(w, opt_state, dense, pooled, labels):
            # m thread-grads from the SAME snapshot, applied sequentially.
            loss, g_w, g_pooled = jax.vmap(
                dlrm.dense_loss_and_grads, in_axes=(None, 0, 0, 0)
            )(w, dense, pooled, labels)

            def apply_one(carry, g):
                w, st = carry
                w, st = opt.update(w, st, g)
                return (w, st), None

            (w, opt_state), _ = jax.lax.scan(apply_one, (w, opt_state), g_w)
            return w, opt_state, jnp.mean(loss), g_pooled

        def train_core(state_w, state_opt, emb_state, batch):
            # batch leaves: (R, M, B, ...)
            idx = batch["sparse"]
            pooled = emb.lookup(
                emb_state, spec, idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            )
            pooled = pooled.reshape(self.R, self.M, self.B, cfg.n_sparse_features, -1)
            w2, opt2, loss, g_pooled = jax.vmap(one_trainer)(
                state_w, state_opt, batch["dense"], pooled, batch["labels"]
            )
            # Hogwild on the single embedding copy: every trainer/thread applies
            # immediately; one fused scatter-Adagrad kernel launch implements
            # the duplicate-row accumulate.
            flat_idx = idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            flat_g = g_pooled.reshape(-1, cfg.n_sparse_features, cfg.embedding_dim)
            emb2 = emb.sparse_adagrad_update_fused(
                emb_state, spec, flat_idx, flat_g, self.emb_lr)
            return w2, opt2, emb2, jnp.mean(loss)

        sc = self.sync_cfg
        if self.engine == "flat":
            fs = self.flat

            def train_iter(w_buf, state_opt, emb_state, batch):
                # unpack -> train -> repack stays inside one jit: XLA fuses the
                # layout moves with the optimizer update, and the donated flat
                # buffer is re-emitted contiguously.
                w2, opt2, emb2, loss = train_core(
                    fs.unpack_stack(w_buf), state_opt, emb_state, batch
                )
                return fs.pack_stack(w2), opt2, emb2, loss

            # Sync launches/landings are owned by the algorithm (host hooks
            # dispatching fused Pallas kernels) — nothing to build here.
        else:
            train_iter = train_core
            # pytree landing: one jit over the algorithm's oracle (retraces
            # only per snap/mask None-ness — a handful of structures).
            self._land_py = jax.jit(
                lambda ws, st, snap, mask: self.algo.land(ws, st, snap, mask, sc)
            )

        self._train_iter = jax.jit(train_iter, donate_argnums=(0, 1, 2))

        def eval_batch(w, emb_state, batch):
            pooled = emb.lookup(emb_state, spec, batch["sparse"])
            logits = dlrm.forward(w, batch["dense"], pooled)
            return dlrm.bce_loss(logits, batch["labels"])

        self._eval = jax.jit(eval_batch)

    # -- state --------------------------------------------------------------
    def init_state(self) -> SimState:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        emb_state = emb.init_tables(self.spec, ke)
        opt0 = self.opt.init(w0)
        opt_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), opt0)
        if self.engine == "flat":
            fs = self.flat
            w_stack = fs.broadcast(w0, self.R)  # packed ONCE here
            algo_state = self.algo.init_state_flat(fs.pack(w0), self.sync_cfg, fs)
        else:
            w_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), w0)
            algo_state = self.algo.init_state(w0, self.sync_cfg)
        return SimState(w_stack, opt_stack, emb_state, algo_state, 0)

    def make_batch(self, it: int) -> Dict[str, jnp.ndarray]:
        """One-pass stream: (R*M) distinct shards per iteration."""
        n = self.R * self.M
        b = ctr.gen_batch(self.cfg, self.teacher, self.seed, it, self.B * n)
        return jax.tree.map(
            lambda x: x.reshape(self.R, self.M, self.B, *x.shape[1:]), b
        )

    # -- sync scheduling ----------------------------------------------------
    def _shadow_schedule(self, t: int) -> np.ndarray:
        """mask[i]: replica i's shadow clock fires at iteration t (staggered)."""
        gap = self.sync_cfg.gap
        offs = (np.arange(self.R) * gap) // max(self.R, 1)
        return ((t + offs) % gap) == 0

    def _launch_snapshot(self, st: SimState, mask: np.ndarray) -> Pytree:
        """State captured when a background sync launches (lands `delay` later).

        Flat engine: the algorithm picks its own compact form — a fired-rows
        gather (EASGD/gossip), a replica-mean plane (MA/BMUF), or a full
        buffer copy (the generic fallback).
        """
        if self.engine == "flat":
            return self.algo.launch_snapshot_flat(
                st.w_stack, mask, self.sync_cfg, self.flat, st.algo_state)
        # pytree: real deep copy (train_iter donates its buffers)
        return jax.tree.map(jnp.copy, st.w_stack)

    def run(self, n_iters: int, *, log_every: int = 0,
            on_iter: Optional[Callable[[int, float], None]] = None) -> Dict[str, Any]:
        st = self.init_state()
        sc = self.sync_cfg
        losses: List[float] = []
        sync_count = 0
        pending: Optional[Tuple[int, Pytree, np.ndarray]] = None  # (land_t, snapshot, mask)
        for t in range(n_iters):
            batch = self.make_batch(t)
            st.w_stack, st.opt_stack, st.emb_state, loss = self._train_iter(
                st.w_stack, st.opt_stack, st.emb_state, batch
            )
            losses.append(float(loss))
            if sc.mode == "fixed_rate":
                if (t + 1) % sc.gap == 0:
                    st = self._apply_sync(st, None, None)
                    sync_count += self.R  # every replica synced this round
            else:  # shadow
                if pending is not None and t + 1 >= pending[0]:
                    _, snap, mask = pending
                    st = self._apply_sync(st, snap, mask)
                    sync_count += int(mask.sum()) if mask is not None else self.R
                    pending = None
                if pending is None:
                    mask = self._shadow_schedule(t + 1)
                    if mask.any():
                        if sc.delay == 0:
                            # Zero in-flight iterations: the sync launched at
                            # iteration t lands at iteration t, not t+1 (the
                            # landing check above has already run this round).
                            # No training step intervenes and the pytree
                            # landing doesn't donate, so skip the defensive
                            # deep copy; the flat engine still builds its
                            # compact launch form (the fused landing consumes
                            # exactly that shape).
                            snap = (self._launch_snapshot(st, mask)
                                    if self.engine == "flat" else st.w_stack)
                            st = self._apply_sync(st, snap, mask)
                            sync_count += int(mask.sum())
                        else:
                            pending = (t + 1 + sc.delay,
                                       self._launch_snapshot(st, mask), mask)
            st.step = t + 1
            if on_iter:
                on_iter(t, losses[-1])
            if log_every and (t + 1) % log_every == 0:
                print(f"iter {t+1}: loss {np.mean(losses[-log_every:]):.5f}")
        return {
            "state": st,
            "train_loss": losses,
            "sync_count": sync_count,
            "avg_sync_gap": (n_iters * self.R / max(sync_count, 1)),
        }

    def _apply_sync(self, st: SimState, snap, mask) -> SimState:
        """Land one background sync: the algorithm owns the semantics (one
        fused kernel launch on the flat engine; the jitted pytree oracle
        otherwise). ``snap=None`` means fixed-rate — sync against the current
        state; ``mask=None`` means every replica fired."""
        if self.engine == "flat":
            st.w_stack, st.algo_state = self.algo.land_flat(
                st.w_stack, st.algo_state, snap, mask, self.sync_cfg, self.flat)
        else:
            mask_arr = None if mask is None else jnp.asarray(mask)
            st.w_stack, st.algo_state = self._land_py(
                st.w_stack, st.algo_state, snap, mask_arr)
        return st

    def replica_params(self, st: SimState, i: int) -> Pytree:
        """Replica i's dense weights as a pytree, whatever the engine."""
        if self.engine == "flat":
            return self.flat.unpack_replica(st.w_stack, i)
        return S.tree_slice(st.w_stack, i)

    def dense_stack(self, st: SimState) -> Pytree:
        """The dense replica stack as an engine-independent pytree (leading R)
        — the stable on-disk / external representation."""
        if self.engine == "flat":
            return self.flat.unpack_stack(st.w_stack)
        return st.w_stack

    def evaluate(self, st: SimState, n_batches: int = 20, batch_size: int = 4096,
                 replica: int = 0) -> float:
        """Paper protocol: evaluate the FIRST trainer's replica."""
        w = self.replica_params(st, replica)
        tot = 0.0
        for i in range(n_batches):
            b = ctr.gen_batch(self.cfg, self.teacher, self.seed + 10_000_000, i, batch_size)
            tot += float(self._eval(w, st.emb_state, b))
        return tot / n_batches


# ---------------------------------------------------------------------------
# Real-thread runner (faithful Algorithm 1)
# ---------------------------------------------------------------------------

class ThreadedShadowRunner:
    """Trainer threads + a background shadow thread over genuinely shared state.

    The embedding state is read-modify-written WITHOUT a lock (Hogwild: concurrent
    trainers can lose updates — that is the point). Dense replicas are owned by
    their trainer; the shadow thread interpolates them in the background.

    The embedding collection is plan-sharded (``embeddings/shards.py``): the
    LPT bin-pack plan splits the packed tables into ``n_emb_shards``
    independent per-PS Hogwild states. Lookups route by the plan (one fused
    lookup+pool kernel launch per shard) and each trainer's backward is one
    fused scatter-Adagrad launch per shard — writes to different PSs are
    independent jitted calls on independent arrays, so they no longer
    serialize through a single scatter over one packed table.

    Flat engine: each replica is one contiguous (n_rows, 128) fp32 plane and
    the shadow thread's exchange is a handful of fused kernel launches per
    round. The round itself is built by the SyncAlgorithm
    (``make_shadow_round``), so this runner hosts any registered algorithm:
    EASGD pairs against the PS plane, slice-free decentralized mean +
    pull-backs (MA), the full block-momentum global step (BMUF), or rotating
    pairwise exchanges (gossip)."""

    def __init__(self, cfg, sync_cfg: S.SyncConfig, *, n_trainers: int,
                 batch_size: int, optimizer: Optimizer, emb_lr: float = 0.05,
                 seed: int = 0, sync_sleep_s: float = 0.0,
                 n_emb_shards: Optional[int] = None):
        self.cfg, self.sync_cfg = cfg, sync_cfg.validate()
        self.engine = sync_cfg.engine
        self.algo = algorithms.get(sync_cfg.algo)
        self.R, self.B = n_trainers, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.sync_sleep_s = sync_sleep_s
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        self.flat = _dense_flatspace(cfg) if self.engine == "flat" else None
        if n_emb_shards is None:
            n_emb_shards = min(4, cfg.n_sparse_features)
        # The LPT bin_pack plan assigns tables to embedding PSs (paper §3.1);
        # lookups and sparse updates route by it below.
        self.plan = emb_shards.plan_shards(self.spec, n_emb_shards, batch_size)
        self.n_emb_shards = self.plan.n_shards
        plan = self.plan

        def train_one(w, opt_state, shard_tables, batch):
            pooled = emb_shards.shard_lookup(plan, shard_tables, batch["sparse"])
            loss, g_w, g_pooled = dlrm.dense_loss_and_grads(
                w, batch["dense"], pooled, batch["labels"]
            )
            w, opt_state = optimizer.update(w, opt_state, g_w)
            return w, opt_state, loss, g_pooled

        def _make_shard_update(s: int):
            return jax.jit(lambda st, idx, g: emb_shards.shard_update(
                plan, s, st, idx, g, emb_lr))

        self._emb_updates = [_make_shard_update(s)
                             for s in range(self.n_emb_shards)]

        if self.engine == "flat":
            fs = self.flat

            def train_one_flat(w_plane, opt_state, emb_table, batch):
                w, opt_state, loss, g_pooled = train_one(
                    fs.unpack(w_plane), opt_state, emb_table, batch
                )
                return fs.pack(w), opt_state, loss, g_pooled

            self._train_one = jax.jit(train_one_flat)
        else:
            self._train_one = jax.jit(train_one)
        # The background round: a host callable from the algorithm that
        # mutates the per-trainer planes/pytrees in place (Algorithm 1).
        self._shadow_round = self.algo.make_shadow_round(self.sync_cfg, self.flat)

    def run(self, iters_per_trainer: int) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        if self.engine == "flat":
            plane0 = self.flat.pack(w0)
            self.w: List[Pytree] = [plane0.copy() for _ in range(self.R)]
            self.algo_state = self.algo.init_state_flat(
                plane0, self.sync_cfg, self.flat)
        else:
            self.w = [jax.tree.map(lambda x: x.copy(), w0) for _ in range(self.R)]
            self.algo_state = self.algo.init_state(w0, self.sync_cfg)
        self.opt_states = [self.opt.init(w0) for _ in range(self.R)]
        # Per-PS Hogwild states, seed-identical to the packed single table.
        self.emb = emb_shards.EmbeddingShards.init(self.plan, ke)
        self.done = False
        self.examples = 0
        self.sync_count = 0
        self.iter_count = [0] * self.R
        losses: List[List[float]] = [[] for _ in range(self.R)]
        ex_lock = threading.Lock()

        def trainer(i: int):
            for it in range(iters_per_trainer):
                batch = ctr.gen_batch(
                    self.cfg, self.teacher, self.seed + i, it, self.B
                )
                # Lock-free read of the shared per-PS tables (Hogwild).
                w, opt_state, loss, g_pooled = self._train_one(
                    self.w[i], self.opt_states[i], self.emb.tables(), batch
                )
                self.w[i], self.opt_states[i] = w, opt_state
                # Lock-free read-modify-write PER SHARD: concurrent writers to
                # different PSs proceed independently; writers to the same PS
                # can interleave and lose updates (the Hogwild property).
                for s in range(self.n_emb_shards):
                    self.emb.states[s] = self._emb_updates[s](
                        self.emb.states[s], batch["sparse"], g_pooled)
                losses[i].append(float(loss))
                self.iter_count[i] = it + 1
                with ex_lock:
                    self.examples += self.B

        def shadow():
            while not self.done:
                # One algorithm-owned background round over the live replica
                # planes — landings interpolate into the CURRENT state while
                # trainers keep moving (paper §3.3).
                self.algo_state, n = self._shadow_round(self.w, self.algo_state)
                self.sync_count += n
                if self.sync_sleep_s:
                    time.sleep(self.sync_sleep_s)

        threads = [threading.Thread(target=trainer, args=(i,)) for i in range(self.R)]
        shadow_t = threading.Thread(target=shadow, daemon=True)
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        shadow_t.start()
        for t in threads:
            t.join()
        self.done = True
        shadow_t.join(timeout=5.0)
        wall = time.perf_counter() - t0
        total_iters = sum(self.iter_count)
        if self.engine == "flat":
            w_out = [self.flat.unpack(p) for p in self.w]
        else:
            w_out = self.w
        return {
            "eps": self.examples / wall,
            "wall_s": wall,
            "train_loss": [float(np.mean(l[-50:])) for l in losses],
            "sync_count": self.sync_count,
            "avg_sync_gap": total_iters / max(self.sync_count, 1),
            "w": w_out,
            # Engine-independent packed view of the per-PS states.
            "emb_state": self.emb.to_packed(),
        }

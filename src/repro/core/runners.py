"""Training runners realizing the paper's system on laptop-scale hardware.

Two runtimes:

* ``HogwildSim`` — deterministic, jitted simulation of n trainers x m Hogwild
  worker threads over the shared embedding tables + per-trainer dense replicas.
  Hogwild staleness semantics: all m thread-grads of an iteration are computed
  from the SAME replica snapshot, then applied sequentially through the optimizer
  (lock-free interleave, quantized at iteration granularity). Background sync is
  scheduled by shadow clocks with launch-snapshot/delayed-landing semantics.
  This runtime produces the paper-quality experiments (Tables 2-3, Figs 6-7).

* ``ThreadedShadowRunner`` — the faithful host-level realization: real Python
  threads (jitted compute releases the GIL), a genuinely racing shared embedding
  state, and a shadow thread that syncs continuously in the background at
  whatever cadence it achieves — the paper's Algorithm 1 verbatim.

Both runners default to the FLAT sync engine (DESIGN.md §3): dense replicas
live in a persistent ``(R, n_rows, 128)`` fp32 buffer (core/flatspace.py) and
every background sync is one fused Pallas launch. ``SyncConfig(engine=
"pytree")`` selects the pure jax.tree.map oracle path.

Both runners also default to the fused SPARSE substrate (DESIGN.md §7):
embedding forward is the fused lookup+pool kernel and the backward is the
fused scatter-Adagrad kernel (``kernels/embedding_bag`` /
``kernels/sparse_adagrad``; compiled on TPU, interpreter elsewhere).
``HogwildSim`` keeps one packed table (the deterministic-sim semantics);
``ThreadedShadowRunner`` realizes the paper's embedding PSs: the LPT
bin-pack plan (``embeddings/shards.py``) splits the collection into
``n_emb_shards`` independent per-PS Hogwild states, lookups route by the
plan, and trainer writes to different PSs no longer serialize through one
jitted scatter.

Neither runner knows any algorithm by name: the whole sync lifecycle —
state init, launch snapshot, landing, the threaded shadow round — is owned
by the ``SyncAlgorithm`` fetched from ``core.algorithms`` (DESIGN.md §6),
so a newly registered algorithm runs here without touching this file.

Elastic membership (DESIGN.md §8): both runners consume a mutable
``core.membership.Membership`` instead of a frozen ``R``. Buffers are
capacity-padded at ``R_max`` so join/leave/fail never reallocate or retrace;
``HogwildSim`` takes a deterministic ``MembershipSchedule`` for reproducible
elasticity experiments, ``ThreadedShadowRunner`` a ``FaultSpec`` harness
(straggler slowdown, crash-at-iteration, join-at-iteration) where the shadow
thread reads membership each round and simply skips dead slots — training
never blocks on a fault. ``mode="fixed_rate"`` in the threaded runner is the
foreground contrast: every trainer blocks at the sync point, so one
straggler drags the whole cohort to its pace.

Closed-loop straggler scheduling (DESIGN.md §9): pass a
``core.scheduler.StragglerPolicy`` and the threaded runner evaluates it
every background round over per-slot busy-clock EPS meters — a slot whose
pace falls below the policy floor is demoted to ``leave`` (with provenance
in the membership event log) and re-admitted through the ordinary join
bootstrap once its probation passes. ``HogwildSim`` consumes the same
policy deterministically via ``core.scheduler.StragglerSchedule``.

Failure-domain supervision (DESIGN.md §10): the threaded runner's long-lived
threads — shadow, monitor, trainers — register heartbeats with a
``core.supervision.Supervisor``. A dead or stalled shadow thread is
restarted against the LIVE membership state (isolation makes this safe:
training never blocked on it); when the restart budget is exhausted the run
degrades gracefully — training continues locally, a ``degraded`` event with
provenance lands in the membership log, and one final foreground sync at
shutdown still converges the replicas. The embedding PSs are their own
failure domain (``embeddings/shards.py``): the shadow thread takes O(1)
background snapshots, ``FaultSpec.ps_fail_at`` kills a shard, lookups fall
back to the snapshot (bounded staleness, never a blocked trainer), updates
retry-then-drop, and the supervisor rehydrates the shard after the
provisioning delay. Trainer exceptions are captured per-thread and re-raised
with slot provenance after ``join()`` — a failed run no longer returns
partial results as if it succeeded.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import algorithms
from repro.core import sync as S
from repro.core.elp import EPSMeter, SlotEPS, median_eps
from repro.core.flatspace import FlatSpace
from repro.core.membership import FaultSpec, Membership, MembershipSchedule
from repro.core.modeswitch import ModeController, ModeDecision, ModeSchedule
from repro.core.pipeline import PipelineConfig, PipelineStats, StepPipeline
from repro.core.scheduler import StragglerPolicy
from repro.core.supervision import Supervisor, SupervisorConfig
from repro.data import ctr
from repro.embeddings import shards as emb_shards
from repro.embeddings import table as emb
from repro.embeddings.cache import CacheConfig, CachedStore
from repro.models import dlrm
from repro.optim import Optimizer

Pytree = Any


def _dense_flatspace(cfg) -> FlatSpace:
    """Layout of the DLRM dense replica space, from shapes only (no init)."""
    shapes = jax.eval_shape(lambda: dlrm.init_dense(cfg, jax.random.PRNGKey(0)))
    return FlatSpace.from_tree(shapes)


# ---------------------------------------------------------------------------
# Deterministic simulator
# ---------------------------------------------------------------------------

@dataclass
class SimState:
    # Dense replicas: pytree stack with leading R (engine="pytree") or a
    # persistent (R, n_rows, 128) fp32 flat buffer (engine="flat").
    w_stack: Pytree
    opt_stack: Pytree
    emb_state: Pytree  # shared {"table", "acc"}
    # Opaque, owned by the SyncAlgorithm (EASGD: the sync-PS copy; BMUF:
    # global model + block momentum; gossip: round counter; MA: None).
    algo_state: Any
    step: int


class HogwildSim:
    def __init__(
        self,
        cfg,  # DLRMConfig
        sync_cfg: S.SyncConfig,
        *,
        n_trainers: int,
        n_threads: int,
        batch_size: int,
        optimizer: Optimizer,
        emb_lr: float = 0.05,
        seed: int = 0,
        membership: Optional[Membership] = None,
        schedule: Optional[Union[MembershipSchedule, Sequence[Tuple[int, str, int]]]] = None,
        cache: Optional[CacheConfig] = None,
        pipeline: Optional[PipelineConfig] = None,
        mode_schedule: Optional[Union[ModeSchedule, Sequence[Tuple[int, str]]]] = None,
    ):
        self.cfg = cfg
        self.sync_cfg = sync_cfg.validate()
        # Runtime mode switching (DESIGN.md §14): a deterministic per-
        # iteration mode trace — scripted [(iteration, mode)] switch points
        # or a closed-loop ControllerModeSchedule — moves the whole cohort
        # between shadow and fixed_rate at iteration boundaries, with the
        # staleness-compensated handoff applied in run(). Without one, the
        # sim runs the exact legacy single-mode path (bit-identical).
        if mode_schedule is not None and not isinstance(mode_schedule, ModeSchedule):
            mode_schedule = ModeSchedule(mode_schedule, start_mode=sync_cfg.mode)
        if mode_schedule is not None and mode_schedule.start_mode != sync_cfg.mode:
            raise ValueError(
                f"mode_schedule starts in {mode_schedule.start_mode!r} but "
                f"sync_cfg.mode is {sync_cfg.mode!r}; construct them to agree")
        self.mode_schedule = mode_schedule
        # Tiered embedding cache (DESIGN.md §11): the packed table moves
        # behind a CachedStore and training runs lookup -> dense jit ->
        # fused update with only the hot tier device-resident. Deterministic:
        # the batch stream is a pure function of the iteration counter, so
        # the prefetch horizon is peeked, not raced.
        self.cache = cache.validate() if cache is not None else None
        # Step pipelining (DESIGN.md §13): a StepPipeline stages batch k+1's
        # lookup while batch k's dense jit runs, hazard-checked over the
        # peeked index stream so the trajectory stays bitwise-serial.
        self.pipeline = pipeline.validate() if pipeline is not None else None
        self.engine = sync_cfg.engine
        self.algo = algorithms.get(sync_cfg.algo)
        # Elastic membership: buffers are CAPACITY-padded at R_max; join/
        # leave/fail only flip the active mask — no reallocation, no retrace.
        # Without an explicit membership/schedule the sim runs the exact
        # legacy fixed-R path (bit-identical trajectories).
        self._elastic = membership is not None or schedule is not None
        if schedule is not None and not isinstance(schedule, MembershipSchedule):
            schedule = MembershipSchedule(schedule)
        self.schedule = schedule
        if membership is None:
            cap = n_trainers
            if schedule is not None:
                cap = max(cap, schedule.max_slot() + 1)
            membership = Membership(n_trainers, R_max=cap)
        if membership.R_max < n_trainers:
            raise ValueError(
                f"membership capacity {membership.R_max} < " f"n_trainers {n_trainers}"
            )
        self.membership = membership
        self.R, self.M, self.B = membership.R_max, n_threads, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        self.flat = _dense_flatspace(cfg) if self.engine == "flat" else None
        self._build()

    # -- jitted pieces ------------------------------------------------------
    def _build(self):
        cfg, spec, opt, R, M = self.cfg, self.spec, self.opt, self.R, self.M

        def one_trainer(w, opt_state, dense, pooled, labels):
            # m thread-grads from the SAME snapshot, applied sequentially.
            loss, g_w, g_pooled = jax.vmap(
                dlrm.dense_loss_and_grads, in_axes=(None, 0, 0, 0)
            )(w, dense, pooled, labels)

            def apply_one(carry, g):
                w, st = carry
                w, st = opt.update(w, st, g)
                return (w, st), None

            (w, opt_state), _ = jax.lax.scan(apply_one, (w, opt_state), g_w)
            return w, opt_state, jnp.mean(loss), g_pooled

        def dense_core(state_w, state_opt, pooled, batch, active=None):
            # Everything downstream of the embedding lookup. Factored out of
            # train_core so the cached path can run it as its own jit with
            # ``pooled`` as an INPUT (lookup and sparse update run standalone
            # against the hot tier) — bitwise-identical to the fused program
            # (tests/test_cache.py pins this).
            w2, opt2, loss, g_pooled = jax.vmap(one_trainer)(
                state_w, state_opt, batch["dense"], pooled, batch["labels"]
            )
            if active is not None:
                # Elastic membership: dead slots are computed (shape-stable —
                # no retrace on join/leave) but contribute NOTHING: their
                # dense/optimizer updates are discarded and their embedding
                # gradients zeroed (a zero-gradient Adagrad row update is an
                # exact no-op: acc += 0, row += 0).
                def keep(new, old):
                    k = active.reshape((R,) + (1,) * (old.ndim - 1))
                    return jnp.where(k, new, old)

                w2 = jax.tree.map(keep, w2, state_w)
                opt2 = jax.tree.map(keep, opt2, state_opt)
                g_pooled = jnp.where(active.reshape((R, 1, 1, 1, 1)), g_pooled, 0.0)
            # elastic callers get the per-replica loss vector (the host masks
            # dead slots out of the reported mean and the join tests read it)
            return w2, opt2, (loss if active is not None else jnp.mean(loss)), g_pooled

        def train_core(state_w, state_opt, emb_state, batch, active=None):
            # batch leaves: (R, M, B, ...)
            idx = batch["sparse"]
            pooled = emb.lookup(
                emb_state, spec, idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            )
            pooled = pooled.reshape(self.R, self.M, self.B, cfg.n_sparse_features, -1)
            w2, opt2, loss, g_pooled = dense_core(state_w, state_opt, pooled, batch, active=active)
            # Hogwild on the single embedding copy: every trainer/thread applies
            # immediately; one fused scatter-Adagrad kernel launch implements
            # the duplicate-row accumulate.
            flat_idx = idx.reshape(-1, cfg.n_sparse_features, cfg.multi_hot)
            flat_g = g_pooled.reshape(-1, cfg.n_sparse_features, cfg.embedding_dim)
            emb2 = emb.sparse_adagrad_update_fused(emb_state, spec, flat_idx, flat_g, self.emb_lr)
            return w2, opt2, emb2, loss

        sc = self.sync_cfg
        if self.engine == "flat":
            fs = self.flat

            def train_iter(w_buf, state_opt, emb_state, batch):
                # unpack -> train -> repack stays inside one jit: XLA fuses the
                # layout moves with the optimizer update, and the donated flat
                # buffer is re-emitted contiguously.
                w2, opt2, emb2, loss = train_core(
                    fs.unpack_stack(w_buf), state_opt, emb_state, batch
                )
                return fs.pack_stack(w2), opt2, emb2, loss

            def train_iter_elastic(w_buf, state_opt, emb_state, active, batch):
                w2, opt2, emb2, loss = train_core(
                    fs.unpack_stack(w_buf), state_opt, emb_state, batch, active=active
                )
                return fs.pack_stack(w2), opt2, emb2, loss

            # Sync launches/landings are owned by the algorithm (host hooks
            # dispatching fused Pallas kernels) — nothing to build here.
        else:
            train_iter = train_core

            def train_iter_elastic(state_w, state_opt, emb_state, active, batch):
                return train_core(state_w, state_opt, emb_state, batch, active=active)

            # pytree landing: one jit over the algorithm's oracle (retraces
            # only per snap/mask None-ness — a handful of structures). The
            # elastic path dispatches the algorithm's membership-aware
            # ``land_elastic`` host hook instead.
            self._land_py = jax.jit(
                lambda ws, st, snap, mask: self.algo.land(ws, st, snap, mask, sc)
            )

        self._train_iter = jax.jit(train_iter, donate_argnums=(0, 1, 2))
        self._train_iter_elastic = jax.jit(train_iter_elastic, donate_argnums=(0, 1, 2))

        # Cached-mode dense programs: pooled arrives as an input (the hot-
        # tier lookup ran standalone) and the sparse update runs standalone
        # after; the embedding state never enters this jit.
        if self.engine == "flat":
            fs = self.flat

            def dense_iter(w_buf, state_opt, pooled, batch):
                w2, opt2, loss, g = dense_core(fs.unpack_stack(w_buf), state_opt, pooled, batch)
                return fs.pack_stack(w2), opt2, loss, g

            def dense_iter_elastic(w_buf, state_opt, active, pooled, batch):
                w2, opt2, loss, g = dense_core(
                    fs.unpack_stack(w_buf), state_opt, pooled, batch, active=active
                )
                return fs.pack_stack(w2), opt2, loss, g
        else:
            def dense_iter(state_w, state_opt, pooled, batch):
                return dense_core(state_w, state_opt, pooled, batch)

            def dense_iter_elastic(state_w, state_opt, active, pooled, batch):
                return dense_core(state_w, state_opt, pooled, batch, active=active)

        self._dense_iter = jax.jit(dense_iter, donate_argnums=(0, 1))
        self._dense_iter_elastic = jax.jit(dense_iter_elastic, donate_argnums=(0, 1))

        # Pipelined-uncached programs (DESIGN.md §13): the split path's
        # standalone lookup/update, deliberately NON-donating — a staged
        # lookup holds a ref to the pre-update emb state while the update
        # for the current step produces the next one, so neither buffer may
        # be invalidated under the staging worker. Same module-jitted
        # kernels as train_core, so split == fused bitwise (the §11 cache
        # already pins the identical decomposition).
        self._lookup_iter = jax.jit(lambda emb_state, idx: emb.lookup(emb_state, spec, idx))
        self._update_iter = jax.jit(
            lambda emb_state, idx, g: emb.sparse_adagrad_update_fused(
                emb_state, spec, idx, g, self.emb_lr
            )
        )

        def eval_batch(w, emb_state, batch):
            pooled = emb.lookup(emb_state, spec, batch["sparse"])
            logits = dlrm.forward(w, batch["dense"], pooled)
            return dlrm.bce_loss(logits, batch["labels"])

        self._eval = jax.jit(eval_batch)

    # -- state --------------------------------------------------------------
    def init_state(self) -> SimState:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        emb_state = emb.init_tables(self.spec, ke)
        opt0 = self.opt.init(w0)
        self._opt0 = opt0  # fresh-slot template for join bootstraps
        opt_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), opt0)
        if self.engine == "flat":
            fs = self.flat
            w_stack = fs.broadcast(w0, self.R)  # packed ONCE, at capacity R_max
            algo_state = self.algo.init_state_flat(fs.pack(w0), self.sync_cfg, fs)
        else:
            w_stack = jax.tree.map(lambda x: jnp.broadcast_to(x, (self.R,) + x.shape).copy(), w0)
            algo_state = self.algo.init_state(w0, self.sync_cfg)
        return SimState(w_stack, opt_stack, emb_state, algo_state, 0)

    def make_batch(self, it: int) -> Dict[str, jnp.ndarray]:
        """One-pass stream: (R*M) distinct shards per iteration."""
        n = self.R * self.M
        b = ctr.gen_batch(self.cfg, self.teacher, self.seed, it, self.B * n)
        return jax.tree.map(lambda x: x.reshape(self.R, self.M, self.B, *x.shape[1:]), b)

    # -- sync scheduling ----------------------------------------------------
    def _shadow_schedule(self, t: int) -> np.ndarray:
        """mask[i]: replica i's shadow clock fires at iteration t (staggered)."""
        gap = self.sync_cfg.gap
        offs = (np.arange(self.R) * gap) // max(self.R, 1)
        return ((t + offs) % gap) == 0

    def _launch_snapshot(
        self, st: SimState, mask: np.ndarray, active: Optional[np.ndarray] = None
    ) -> Pytree:
        """State captured when a background sync launches (lands `delay` later).

        Flat engine: the algorithm picks its own compact form — a fired-rows
        gather (EASGD/gossip), a replica-mean plane (MA/BMUF), or a full
        buffer copy (the generic fallback). ``active`` is the membership mask
        at launch: a dead slot is never snapshotted and the decentralized
        mean divides by the live count.
        """
        if self.engine == "flat":
            return self.algo.launch_snapshot_flat(
                st.w_stack, mask, self.sync_cfg, self.flat, st.algo_state, active=active
            )
        # pytree: real deep copy (train_iter donates its buffers)
        return jax.tree.map(jnp.copy, st.w_stack)

    def _apply_membership_event(
        self, st: SimState, kind: str, slot: int, reason: str = ""
    ) -> SimState:
        """One schedule transition, at an iteration boundary. Joins bootstrap
        through the algorithm's ``on_join`` hook (live mean / PS copy) with a
        fresh optimizer slot; leaves/fails dispatch ``on_leave``. Nothing
        reallocates — the capacity-padded buffers just flip a mask bit.
        ``reason`` is provenance for the event log (e.g. a straggler-policy
        demotion — core/scheduler.py)."""
        sc, fs = self.sync_cfg, self.flat
        if kind in ("fail", "leave"):
            getattr(self.membership, kind)(slot, reason=reason)
            if self.engine == "flat":
                st.algo_state = self.algo.on_leave_flat(st.algo_state, slot, sc, fs)
            else:
                st.algo_state = self.algo.on_leave(st.algo_state, slot, sc)
            return st
        if kind != "join":
            raise ValueError(f"unknown membership event kind {kind!r}")
        donors = self.membership.active_mask()  # before the join
        self.membership.join(slot, reason=reason)
        if donors.any():  # no live donors -> keep the slot's current weights
            if self.engine == "flat":
                st.w_stack, st.algo_state = self.algo.on_join_flat(
                    st.w_stack, slot, st.algo_state, donors, sc, fs
                )
            else:
                st.w_stack, st.algo_state = self.algo.on_join(
                    st.w_stack, slot, st.algo_state, jnp.asarray(donors), sc
                )
        st.opt_stack = S.tree_set(st.opt_stack, slot, self._opt0)
        self.membership.activate(slot)
        return st

    def run(
        self,
        n_iters: int,
        *,
        log_every: int = 0,
        on_iter: Optional[Callable[[int, float], None]] = None,
        state: Optional[SimState] = None,
    ) -> Dict[str, Any]:
        """Train ``n_iters`` iterations. ``state`` resumes a prior run (e.g.
        an elastic ``load_state``): iteration numbering — and therefore the
        one-pass batch stream, the shadow-clock offsets, and the membership
        schedule — continues from ``state.step`` instead of replaying from
        zero."""
        st = self.init_state() if state is None else state
        sc = self.sync_cfg
        elastic = self._elastic
        cached = self.cache is not None
        pipelined = self.pipeline is not None
        store: Optional[CachedStore] = None
        batch_memo: Dict[int, Any] = {}
        gid_memo: Dict[int, np.ndarray] = {}
        offs = np.asarray(self.spec.offsets)
        F, m, d = (self.cfg.n_sparse_features, self.cfg.multi_hot, self.cfg.embedding_dim)
        if cached:
            # the packed table moves behind the two-tier store for the run;
            # merged() restores the canonical emb_state at the end, so
            # resume/save/eval see exactly the uncached representation
            store = CachedStore(st.emb_state, self.cache)
            st.emb_state = None

        def _get_batch(it: int):
            if not cached:
                return self.make_batch(it)
            if it not in batch_memo:
                batch_memo[it] = self.make_batch(it)
            return batch_memo[it]

        def _gids(it: int) -> np.ndarray:
            # packed GLOBAL row ids of iteration ``it``'s batch — the peek:
            # the one-pass stream is a pure function of the iteration
            # counter, so "the next K queued batches" are regenerated, not
            # raced (memoized across the prefetch horizon)
            if it not in gid_memo:
                idx = np.asarray(_get_batch(it)["sparse"]).reshape(
                    -1, self.cfg.n_sparse_features, self.cfg.multi_hot
                )
                gid_memo[it] = idx + offs[None, :, None]
            return gid_memo[it]

        losses: List[float] = []
        replica_losses: List[np.ndarray] = []
        sync_count = 0
        examples = 0
        start = int(st.step)
        # Step pipelining (DESIGN.md §13): the staging worker peeks future
        # batches (pure in the iteration counter — regenerated, not shared
        # with this thread's memos) and dispatches their lookups while this
        # thread is blocked in the dense jit; the hazard check keeps the
        # trajectory bitwise-serial. The sim has ONE lookup unit (the packed
        # table), so n_shards=1.
        pipe: Optional[StepPipeline] = None
        if pipelined:

            def _prep_step(it: int) -> Dict[str, Any]:
                b = self.make_batch(it)
                idx = np.asarray(b["sparse"]).reshape(-1, F, m)
                gids = idx + offs[None, :, None]
                return {"rows": [np.unique(gids)], "batch": b, "idx": idx, "gids": gids}

            if cached:

                def _stage_lookup(s, it, prep, ctx):
                    # races only placement (promotions); values are
                    # placement-invariant and the hazard check guarantees
                    # no window update touches these rows
                    return store.lookup(prep["gids"], staged=True)

                _make_ctx = None
            else:

                def _stage_lookup(s, it, prep, ctx):
                    # ctx = the pre-update emb state captured at stage()
                    # time (immutable arrays; _update_iter does not donate)
                    return self._lookup_iter(ctx, prep["idx"])

                def _make_ctx():
                    return st.emb_state

            pipe = StepPipeline(
                self.pipeline, 1, prepare=_prep_step, stage_fn=_stage_lookup,
                make_ctx=_make_ctx, end=start + n_iters, name="sim-pipe",
            )
        # prefetch horizon composed with the pipeline depth: the prefetcher
        # must peek at least as far as lookups are staged (DESIGN.md §13)
        la = (
            self.cache.effective_lookahead(self.pipeline.depth if pipelined else 1)
            if cached
            else 0
        )
        # (land_t, snapshot, fired_mask, launch_active)
        pending: Optional[Tuple[int, Pytree, np.ndarray, Optional[np.ndarray]]] = None
        # Runtime mode switching (DESIGN.md §14). ``cur_mode`` tracks the
        # cohort's mode; the anchors realize the staleness-compensated
        # handoff: ``fr_anchor`` aligns the barrier cadence to the catch-up
        # sync that opened the fixed_rate phase, ``shadow_base`` seeds the
        # staggered shadow clocks from the last GLOBAL sync, and
        # ``last_global_sync`` remembers where that was. All stay 0 when no
        # switch ever fires, so a schedule-free run is bit-identical legacy.
        msched = self.mode_schedule
        cur_mode = sc.mode
        if msched is not None and start > 0:
            cur_mode = msched.mode_at(start - 1)  # mode already in effect
        mode_events: List[Tuple[int, str, str]] = []
        last_global_sync = 0
        fr_anchor = 0
        shadow_base = 0
        for t in range(start, start + n_iters):
            if elastic and self.schedule is not None:
                # plain schedules yield (kind, slot); a closed-loop
                # StragglerSchedule yields (kind, slot, reason) — provenance
                # rides into the membership event log
                evs = list(self.schedule.events_at(t))
                if evs and pipe is not None:
                    # in-flight stages predate the event: drain BEFORE the
                    # membership epoch advances (DESIGN.md §13)
                    pipe.drain()
                for ev in evs:
                    kind, slot = ev[0], ev[1]
                    reason = ev[2] if len(ev) > 2 else ""
                    st = self._apply_membership_event(st, kind, slot, reason)
            active = self.membership.active_mask() if elastic else None
            if msched is not None:
                mode = msched.mode_at(t)
                if mode != cur_mode:
                    # Mode handoff at the iteration boundary (DESIGN.md §14).
                    # In-flight pipeline stages predate the switch: drain on
                    # this (owning) thread before anything else moves.
                    if pipe is not None:
                        pipe.drain()
                    if mode == "fixed_rate":
                        # shadow -> fixed_rate: drop the in-flight launch
                        # (its snapshot is stale against the barrier about
                        # to arm) and run one foreground catch-up sync —
                        # GBA-style compensation, so stale replica deltas
                        # are merged before the first synchronous step.
                        pending = None
                        if active is None or active.any():
                            st = self._apply_sync(st, None, None, active=active)
                            sync_count += self.R if active is None else int(active.sum())
                        last_global_sync = t
                        fr_anchor = t
                    else:
                        # fixed_rate -> shadow: nothing in flight to drain
                        # (the sim's barrier is implicit); seed every
                        # replica's shadow clock from the LAST GLOBAL sync,
                        # so the staggered offsets resume as if the cohort
                        # had been on shadow clocks since that sync.
                        shadow_base = last_global_sync
                    mode_events.append((t, cur_mode, mode))
                    cur_mode = mode
            staged = prep = None
            if pipe is not None:
                staged, prep = pipe.consume(t)
            batch = prep["batch"] if prep is not None else _get_batch(t)
            if cached:
                if prep is not None:
                    # the worker already generated this step's batch/gids:
                    # seed the memos so the prefetch peek below reuses them
                    batch_memo.setdefault(t, batch)
                    gid_memo.setdefault(t, prep["gids"])
                # deterministic lookahead: one prefetch round covering the
                # horizon [t, t+K) at the iteration boundary — exactly what
                # the threaded shadow thread does between syncs, quantized
                if la:
                    store.prefetch([_gids(t + j) for j in range(la)])
                gids = prep["gids"] if prep is not None else _gids(t)
                if staged is not None and staged[0] is not None:
                    pooled = staged[0]  # batch t's lookup overlapped batch
                    # t-1's dense pass (bitwise: the hazard check held)
                else:
                    pooled = store.lookup(gids)
                pooled = pooled.reshape(self.R, self.M, self.B, F, -1)
                if elastic:
                    st.w_stack, st.opt_stack, loss_out, g_pooled = self._dense_iter_elastic(
                        st.w_stack, st.opt_stack, jnp.asarray(active), pooled, batch
                    )
                else:
                    st.w_stack, st.opt_stack, loss_out, g_pooled = (
                        self._dense_iter(st.w_stack, st.opt_stack, pooled, batch)
                    )
                if pipe is not None:
                    # stage AFTER the dense dispatch (the worker overlaps
                    # it) and BEFORE this step's sparse update lands
                    pipe.stage(t)
                # standalone fused scatter-Adagrad on the hot tier, same
                # (B*F, m)/(B*F, d) flattening as sparse_adagrad_update_fused
                store.update(
                    gids.reshape(-1, self.cfg.multi_hot),
                    g_pooled.reshape(-1, self.cfg.embedding_dim),
                    self.emb_lr,
                )
                for k in [k for k in gid_memo if k <= t]:
                    del gid_memo[k]
                    batch_memo.pop(k, None)
            elif pipelined:
                # uncached split path (standalone lookup -> dense jit ->
                # standalone update): bitwise-identical to the fused
                # program — same module-jitted kernels, same order (the
                # §11 cache pins the identical decomposition)
                idx = (
                    prep["idx"]
                    if prep is not None
                    else np.asarray(batch["sparse"]).reshape(-1, F, m)
                )
                if staged[0] is not None:
                    pooled = staged[0]
                else:
                    pooled = self._lookup_iter(st.emb_state, idx)
                pooled = pooled.reshape(self.R, self.M, self.B, F, -1)
                if elastic:
                    st.w_stack, st.opt_stack, loss_out, g_pooled = self._dense_iter_elastic(
                        st.w_stack, st.opt_stack, jnp.asarray(active), pooled, batch
                    )
                else:
                    st.w_stack, st.opt_stack, loss_out, g_pooled = (
                        self._dense_iter(st.w_stack, st.opt_stack, pooled, batch)
                    )
                pipe.stage(t)  # _make_ctx captures the PRE-update emb state
                st.emb_state = self._update_iter(st.emb_state, idx, g_pooled.reshape(-1, F, d))
            elif elastic:
                st.w_stack, st.opt_stack, st.emb_state, loss_out = self._train_iter_elastic(
                    st.w_stack, st.opt_stack, st.emb_state, jnp.asarray(active), batch
                )
            else:
                st.w_stack, st.opt_stack, st.emb_state, loss_out = (
                    self._train_iter(st.w_stack, st.opt_stack, st.emb_state, batch)
                )
            if elastic:
                lv = np.asarray(loss_out)
                replica_losses.append(lv)
                # an all-dead cohort trains nothing: nan, not a mean of []
                losses.append(float(lv[active].mean()) if active.any() else float("nan"))
                examples += int(active.sum()) * self.M * self.B
            else:
                losses.append(float(loss_out))
                examples += self.R * self.M * self.B
            if cur_mode == "fixed_rate":
                if (t + 1 - fr_anchor) % sc.gap == 0 and (active is None or active.any()):
                    st = self._apply_sync(st, None, None, active=active)
                    sync_count += self.R if active is None else int(active.sum())
                    last_global_sync = t + 1
            else:  # shadow
                if pending is not None and t + 1 >= pending[0]:
                    _, snap, mask, launch_active = pending
                    # landing reads the CURRENT membership — a slot that died
                    # while the sync was in flight is simply skipped (an
                    # all-dead cohort drops the landing entirely)
                    if active is None or active.any():
                        st = self._apply_sync(
                            st, snap, mask, active=active, launch_active=launch_active
                        )
                        sync_count += (int(mask.sum()) if mask is not None else self.R)
                    pending = None
                if pending is None:
                    mask = self._shadow_schedule(t + 1 - shadow_base)
                    if elastic:
                        mask = mask & active  # a dead slot's clock never fires
                    if mask.any():
                        if sc.delay == 0:
                            # Zero in-flight iterations: the sync launched at
                            # iteration t lands at iteration t, not t+1 (the
                            # landing check above has already run this round).
                            # No training step intervenes and the pytree
                            # landing doesn't donate, so skip the defensive
                            # deep copy; the flat engine still builds its
                            # compact launch form (the fused landing consumes
                            # exactly that shape).
                            snap = (self._launch_snapshot(st, mask, active)
                                    if self.engine == "flat" else st.w_stack)
                            st = self._apply_sync(
                                st, snap, mask, active=active, launch_active=active
                            )
                            sync_count += int(mask.sum())
                        else:
                            pending = (
                                t + 1 + sc.delay,
                                self._launch_snapshot(st, mask, active),
                                mask,
                                active,
                            )
            st.step = t + 1
            if on_iter:
                on_iter(t, losses[-1])
            if log_every and (t + 1) % log_every == 0:
                print(f"iter {t+1}: loss {np.mean(losses[-log_every:]):.5f}")
        if pipe is not None:
            # quiesce the staging worker before the canonical merge below
            # (a still-running staged lookup would race the hot-tier drain)
            pipe.close()
        if cached:
            # fold the hot tier back into the canonical packed state: the
            # cache is invisible to save/eval/resume (and to the caller)
            st.emb_state = store.merged()
        # replica-iterations actually trained (dead slots don't count):
        # identical to n_iters * R when membership never changes
        replica_iters = examples // (self.M * self.B)
        out = {
            "state": st,
            "train_loss": losses,
            "sync_count": sync_count,
            "avg_sync_gap": (replica_iters / max(sync_count, 1)),
            "examples": examples,
        }
        if cached:
            out["cache_stats"] = store.stats.as_dict()
        if pipe is not None:
            out["pipeline_stats"] = pipe.stats.as_dict()
        if elastic:
            out["replica_losses"] = np.stack(replica_losses)
            out["membership_events"] = list(self.membership.events)
        if msched is not None:
            # (iteration, from_mode, to_mode) handoffs this run applied —
            # the reproducibility contract: two runs of the same schedule
            # produce identical mode_events AND identical trajectories
            out["mode_events"] = mode_events
            out["mode"] = cur_mode
        return out

    def _apply_sync(self, st: SimState, snap, mask, active=None, launch_active=None) -> SimState:
        """Land one background sync: the algorithm owns the semantics (one
        fused kernel launch on the flat engine; the jitted pytree oracle
        otherwise). ``snap=None`` means fixed-rate — sync against the current
        state; ``mask=None`` means every replica fired; ``active`` /
        ``launch_active`` are the membership masks at landing / launch time
        (None == not elastic)."""
        if self.engine == "flat":
            st.w_stack, st.algo_state = self.algo.land_flat(
                st.w_stack, st.algo_state, snap, mask, self.sync_cfg, self.flat, active=active
            )
        elif active is None:
            mask_arr = None if mask is None else jnp.asarray(mask)
            st.w_stack, st.algo_state = self._land_py(st.w_stack, st.algo_state, snap, mask_arr)
        else:
            st.w_stack, st.algo_state = self.algo.land_elastic(
                st.w_stack,
                st.algo_state,
                snap,
                mask,
                active,
                self.sync_cfg,
                launch_active=launch_active,
            )
        return st

    def replica_params(self, st: SimState, i: int) -> Pytree:
        """Replica i's dense weights as a pytree, whatever the engine."""
        if self.engine == "flat":
            return self.flat.unpack_replica(st.w_stack, i)
        return S.tree_slice(st.w_stack, i)

    def dense_stack(self, st: SimState) -> Pytree:
        """The dense replica stack as an engine-independent pytree (leading R)
        — the stable on-disk / external representation."""
        if self.engine == "flat":
            return self.flat.unpack_stack(st.w_stack)
        return st.w_stack

    # -- elastic checkpointing (DESIGN.md §8.5) ------------------------------
    def _state_tree(self, st: SimState) -> Dict[str, Any]:
        """Engine-independent on-disk form: dense replicas as the named
        pytree stack, embedding + optimizer + opaque algorithm state."""
        return {
            "w": self.dense_stack(st),
            "opt": st.opt_stack,
            "emb": st.emb_state,
            "algo": st.algo_state,
        }

    def save_state(
        self, path: str, st: SimState, metadata: Optional[Dict[str, Any]] = None
    ) -> None:
        meta = {
            "step": st.step,
            "algo": self.sync_cfg.algo,
            "engine": self.engine,
            "R": self.R,
            "active_mask": [bool(b) for b in self.membership.active_mask()],
        }
        meta.update(metadata or {})
        ckpt.save(path, self._state_tree(st), metadata=meta)

    def load_state(self, path: str) -> SimState:
        """Elastic restore: the checkpoint's replica count may differ from
        this sim's capacity. Shrink truncates the replica axis; every slot
        that is active NOW but was not live at save time — grown slots AND
        slots that were dead when saved (their rows are stale) — is
        bootstrapped through the algorithm's ``on_join`` hook (live mean /
        PS copy) with a fresh optimizer state, so resuming a run saved at
        R=4 with R=6 just works and a dead-at-save slot is never silently
        resurrected from stale weights."""
        meta0 = ckpt.read_metadata(path)
        for field in ("engine", "algo"):
            want = getattr(self, field) if field == "engine" else self.sync_cfg.algo
            if field in meta0 and meta0[field] != want:
                raise ValueError(
                    f"checkpoint at {path!r} was saved with {field}="
                    f"{meta0[field]!r} but this sim runs {field}={want!r}; "
                    f"construct the sim to match (the algo_state layout is "
                    f"{field}-specific)")
        template = self.init_state()
        like = self._state_tree(template)
        # only the replica-stacked trees may resize; a mismatch anywhere
        # else (e.g. embedding rows from a different config) must raise
        replica_stacked = lambda k: k == "w" or k.startswith("w/") \
            or k == "opt" or k.startswith("opt/")
        tree, meta, resized = ckpt.restore_elastic(path, like, may_resize=replica_stacked)
        w_stack = (self.flat.pack_stack(tree["w"]) if self.engine == "flat" else tree["w"])
        st = SimState(w_stack, tree["opt"], tree["emb"], tree["algo"], int(meta.get("step", 0)))
        saved_R = int(meta.get("R", self.R))
        # donors = the restored cohort: rows live at SAVE time (and present
        # after any truncation)
        donors = np.zeros((self.R,), bool)
        k = min(saved_R, self.R)
        saved_active = meta.get("active_mask")
        if saved_active is None:
            donors[:k] = True
        else:
            donors[:k] = np.asarray(saved_active, bool)[:k]
        need = self.membership.active_mask() & ~donors
        sc, fs = self.sync_cfg, self.flat
        for slot in np.flatnonzero(need):
            slot = int(slot)
            if donors.any():
                if self.engine == "flat":
                    st.w_stack, st.algo_state = self.algo.on_join_flat(
                        st.w_stack, slot, st.algo_state, donors, sc, fs
                    )
                else:
                    st.w_stack, st.algo_state = self.algo.on_join(
                        st.w_stack, slot, st.algo_state, jnp.asarray(donors), sc
                    )
            st.opt_stack = S.tree_set(st.opt_stack, slot, self._opt0)
        return st

    def evaluate(
        self, st: SimState, n_batches: int = 20, batch_size: int = 4096, replica: int = 0
    ) -> float:
        """Paper protocol: evaluate the FIRST trainer's replica."""
        w = self.replica_params(st, replica)
        tot = 0.0
        for i in range(n_batches):
            b = ctr.gen_batch(self.cfg, self.teacher, self.seed + 10_000_000, i, batch_size)
            tot += float(self._eval(w, st.emb_state, b))
        return tot / n_batches


# ---------------------------------------------------------------------------
# Real-thread runner (faithful Algorithm 1)
# ---------------------------------------------------------------------------

class ThreadedShadowRunner:
    """Trainer threads + a background shadow thread over genuinely shared state.

    The embedding state is read-modify-written WITHOUT a lock (Hogwild: concurrent
    trainers can lose updates — that is the point). Dense replicas are owned by
    their trainer; the shadow thread interpolates them in the background.

    The embedding collection is plan-sharded (``embeddings/shards.py``): the
    LPT bin-pack plan splits the packed tables into ``n_emb_shards``
    independent per-PS Hogwild states. Lookups route by the plan (one fused
    lookup+pool kernel launch per shard) and each trainer's backward is one
    fused scatter-Adagrad launch per shard — writes to different PSs are
    independent jitted calls on independent arrays, so they no longer
    serialize through a single scatter over one packed table.

    Flat engine: each replica is one contiguous (n_rows, 128) fp32 plane and
    the shadow thread's exchange is a handful of fused kernel launches per
    round. The round itself is built by the SyncAlgorithm
    (``make_shadow_round``), so this runner hosts any registered algorithm:
    EASGD pairs against the PS plane, slice-free decentralized mean +
    pull-backs (MA), the full block-momentum global step (BMUF), or rotating
    pairwise exchanges (gossip)."""

    def __init__(
        self,
        cfg,
        sync_cfg: S.SyncConfig,
        *,
        n_trainers: int,
        batch_size: int,
        optimizer: Optimizer,
        emb_lr: float = 0.05,
        seed: int = 0,
        sync_sleep_s: float = 0.0,
        n_emb_shards: Optional[int] = None,
        fault_spec: Optional[FaultSpec] = None,
        membership: Optional[Membership] = None,
        eps_window_s: float = 2.0,
        straggler_policy: Optional[StragglerPolicy] = None,
        supervise: bool = True,
        supervisor_config: Optional[SupervisorConfig] = None,
        ps_snapshot_every: int = 2,
        shard_retry: Optional[emb_shards.ShardRetryPolicy] = None,
        cache: Optional[CacheConfig] = None,
        pipeline: Optional[PipelineConfig] = None,
        mode_controller: Optional[ModeController] = None,
    ):
        self.cfg, self.sync_cfg = cfg, sync_cfg.validate()
        # Tuning-free mode switching (DESIGN.md §14): when a ModeController
        # is supplied, the run starts in sync_cfg.mode but the controller —
        # evaluated every shadow round over live busy-EPS dispersion (plus
        # the loss-divergence quality skew) — may move the WHOLE cohort
        # between shadow and fixed_rate mid-run, with the staleness-
        # compensated handoff applied in run().
        self.mode_ctl = mode_controller
        if mode_controller is not None and mode_controller.mode != sync_cfg.mode:
            raise ValueError(
                f"mode_controller starts in {mode_controller.mode!r} but "
                f"sync_cfg.mode is {sync_cfg.mode!r}; construct them to agree")
        # Tiered embedding cache (DESIGN.md §11): each PS fronts its table
        # with a two-tier store; the shadow thread (already the background
        # worker) runs the lookahead prefetcher between syncs.
        self.cache = cache.validate() if cache is not None else None
        # Step pipelining (DESIGN.md §13): each trainer owns a StepPipeline
        # that stages hazard-free per-shard lookups one-plus steps ahead.
        self.pipeline = pipeline.validate() if pipeline is not None else None
        self.engine = sync_cfg.engine
        self.algo = algorithms.get(sync_cfg.algo)
        self.R, self.B = n_trainers, batch_size
        self.opt = optimizer
        self.emb_lr = emb_lr
        self.seed = seed
        self.sync_sleep_s = sync_sleep_s
        # Fault-injection harness + elastic membership (DESIGN.md §8.4):
        # slots with a join_at schedule start dead and bootstrap mid-run.
        self.fault = (fault_spec or FaultSpec()).validate(n_trainers)
        # Closed-loop straggler controller (DESIGN.md §9): evaluated in the
        # shadow thread each round (mode="shadow") or by a lightweight
        # monitor thread (mode="fixed_rate", which has no shadow thread).
        if straggler_policy is not None and straggler_policy.n_slots != n_trainers:
            raise ValueError(
                f"straggler_policy watches "
                f"{straggler_policy.n_slots} slots, runner has "
                f"{n_trainers} trainers"
            )
        self.policy = straggler_policy
        if membership is None:
            membership = Membership.from_mask(
                [i not in self.fault.join_at for i in range(n_trainers)]
            )
        if membership.R_max != n_trainers:
            raise ValueError(
                f"membership capacity {membership.R_max} != " f"n_trainers {n_trainers}"
            )
        self.membership = membership
        self.eps_window_s = eps_window_s
        self.spec = emb.spec_from_config(cfg)
        self.teacher = ctr.make_teacher(cfg, seed=seed + 777)
        self.flat = _dense_flatspace(cfg) if self.engine == "flat" else None
        if n_emb_shards is None:
            n_emb_shards = min(4, cfg.n_sparse_features)
        # The LPT bin_pack plan assigns tables to embedding PSs (paper §3.1);
        # lookups and sparse updates route by it below.
        self.plan = emb_shards.plan_shards(self.spec, n_emb_shards, batch_size)
        self.n_emb_shards = self.plan.n_shards
        # Failure-domain supervision (DESIGN.md §10): heartbeats over every
        # long-lived thread, bounded shadow restarts, PS fail/recover
        # orchestration. Chaos injection (sync_crash_at / sync_stall_at /
        # ps_fail_at) rides the supervisor's watch loop, so a FaultSpec that
        # kills the sync thread or a PS requires supervise=True.
        self.supervise = bool(supervise)
        self.supervisor_config = (supervisor_config or SupervisorConfig()).validate()
        if ps_snapshot_every < 1:
            raise ValueError(f"ps_snapshot_every must be >= 1, got " f"{ps_snapshot_every}")
        self.ps_snapshot_every = int(ps_snapshot_every)
        self.shard_retry = shard_retry
        for s in self.fault.ps_fail_at:
            if not 0 <= s < self.n_emb_shards:
                raise ValueError(
                    f"ps_fail_at names shard {s}, but the plan "
                    f"has {self.n_emb_shards} embedding shards"
                )
        sync_chaos = (self.fault.sync_crash_at is not None or self.fault.sync_stall_at is not None)
        if sync_chaos and self.sync_cfg.mode == "fixed_rate" and self.mode_ctl is None:
            raise ValueError(
                "sync_crash_at / sync_stall_at target the shadow thread; "
                "static mode='fixed_rate' has none (auto-mode runs — with a "
                "mode_controller — always keep one)"
            )
        if (sync_chaos or self.fault.ps_fail_at) and not self.supervise:
            raise ValueError(
                "FaultSpec injects sync/PS chaos, but "
                "supervise=False — the supervisor is both the "
                "injection clock and the recovery path"
            )
        self.supervisor: Optional[Supervisor] = None
        plan = self.plan

        def dense_one(w, opt_state, pooled, batch):
            # downstream of the lookup — the cached path's jit (pooled came
            # off the hot tiers via cached_lookup)
            loss, g_w, g_pooled = dlrm.dense_loss_and_grads(
                w, batch["dense"], pooled, batch["labels"]
            )
            w, opt_state = optimizer.update(w, opt_state, g_w)
            return w, opt_state, loss, g_pooled

        def train_one(w, opt_state, shard_tables, batch):
            pooled = emb_shards.shard_lookup(plan, shard_tables, batch["sparse"])
            return dense_one(w, opt_state, pooled, batch)

        def _make_shard_update(s: int):
            return jax.jit(lambda st, idx, g: emb_shards.shard_update(plan, s, st, idx, g, emb_lr))

        self._emb_updates = [_make_shard_update(s) for s in range(self.n_emb_shards)]

        if self.engine == "flat":
            fs = self.flat

            def train_one_flat(w_plane, opt_state, emb_table, batch):
                w, opt_state, loss, g_pooled = train_one(
                    fs.unpack(w_plane), opt_state, emb_table, batch
                )
                return fs.pack(w), opt_state, loss, g_pooled

            def dense_one_flat(w_plane, opt_state, pooled, batch):
                w, opt_state, loss, g_pooled = dense_one(
                    fs.unpack(w_plane), opt_state, pooled, batch
                )
                return fs.pack(w), opt_state, loss, g_pooled

            self._train_one = jax.jit(train_one_flat)
            self._train_dense = jax.jit(dense_one_flat)
        else:
            self._train_one = jax.jit(train_one)
            self._train_dense = jax.jit(dense_one)
        # The background round: a host callable from the algorithm that
        # mutates the per-trainer planes/pytrees in place (Algorithm 1).
        self._shadow_round = self.algo.make_shadow_round(self.sync_cfg, self.flat)

    def warmup(self, iters: int = 1) -> None:
        """Trace/compile this runner's jitted programs on throwaway state.

        Each runner instance owns fresh ``jax.jit`` wrappers, so its first
        training iteration pays tracing (~0.5-2 s on a loaded box) — enough
        to dominate a short benchmark run and to blind the straggler
        controller's meters during exactly the window it should be
        detecting in. Warming up touches no membership, meters, or
        measured state."""
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        plane = self.flat.pack(w0) if self.engine == "flat" else w0
        opt0 = self.opt.init(w0)
        embs = emb_shards.EmbeddingShards.init(self.plan, ke, cache=self.cache)
        for it in range(iters):
            batch = ctr.gen_batch(self.cfg, self.teacher, self.seed, it, self.B)
            if self.cache is not None:
                sparse_np = np.asarray(batch["sparse"])
                pooled = embs.cached_lookup(sparse_np)
                plane, opt0, _, g_pooled = self._train_dense(plane, opt0, pooled, batch)
                for s in range(self.n_emb_shards):
                    embs.cached_update(s, sparse_np, g_pooled, self.emb_lr)
            else:
                plane, opt0, _, g_pooled = self._train_one(plane, opt0, embs.tables(), batch)
                for s in range(self.n_emb_shards):
                    embs.states[s] = self._emb_updates[s](embs.states[s], batch["sparse"], g_pooled)
        # the background/foreground sync round is its own jitted program
        # (retraced per live count): warm it at the initial cohort size on
        # throwaway state, or the FIRST measured round pays the trace —
        # inside the controller's detection window
        n_live = max(int(self.membership.active_ids().size), 1)
        if self.engine == "flat":
            algo_state = self.algo.init_state_flat(plane, self.sync_cfg, self.flat)
        else:
            algo_state = self.algo.init_state(w0, self.sync_cfg)
        # Also warm every cohort size the FaultSpec/policy can retrace to
        # mid-run: each crash/raise (and a straggler demotion) shrinks the
        # cohort by one, each scheduled join grows it by one. Without this
        # the first round AFTER an elastic event pays the trace — exactly
        # when the membership epoch just advanced and the controller is
        # re-baselining (the PR 5 fix warmed only the initial size).
        shrinks = (
            len(self.fault.crash_at)
            + len(self.fault.raise_at)
            + (1 if self.policy is not None else 0)
        )
        grows = len(self.fault.join_at)
        sizes = {n_live}
        sizes.update(max(n_live - k, 1) for k in range(1, shrinks + 1))
        sizes.update(min(n_live + k, self.R) for k in range(1, grows + 1))
        for n in sorted(sizes):
            self._shadow_round([plane] * n, algo_state)

    # holds-lock: _state_lock
    def _dispatch_on_leave(self, slot: int) -> None:
        """Engine-dispatched algorithm hook for a departing slot. Caller
        holds ``_state_lock``."""
        if self.engine == "flat":
            self.algo_state = self.algo.on_leave_flat(
                self.algo_state, slot, self.sync_cfg, self.flat
            )
        else:
            self.algo_state = self.algo.on_leave(self.algo_state, slot, self.sync_cfg)

    # holds-lock: _state_lock
    def _admit_slot(self, slot: int, reason: str = "") -> None:
        """join -> bootstrap -> activate, the one admission sequence (used
        by the join_at fault path and policy re-admission). Caller holds
        ``_state_lock``."""
        self.membership.join(slot, reason=reason)
        self._bootstrap_join(slot)
        self.membership.activate(slot)

    # holds-lock: _state_lock; lock-blocking: ok — admission must be atomic
    # with the membership transition; joins are rare and bounded (one stack
    # + on_join hook over the live cohort)
    def _bootstrap_join(self, i: int) -> None:
        """Bootstrap a joining slot through the algorithm's ``on_join`` hook
        (live mean / PS copy) with a fresh optimizer state. Called between
        ``membership.join`` and ``membership.activate`` — the joiner is not
        yet in the active mask, so the donors are exactly the live cohort.
        The hook sees a COMPACT stack of [donor planes..., joiner plane]
        (joiner last) rather than a copy of the whole replica space — this
        runs under ``_state_lock``, so the copy is kept to the data a donor
        mean actually needs."""
        donor_ids = [int(j) for j in self.membership.active_ids()]
        if not donor_ids:  # no live donors: keep the slot's current weights
            self.opt_states[i] = self.opt.init(self._w0)
            return
        slot = len(donor_ids)  # joiner's position in the compact stack
        active = np.asarray([True] * slot + [False])
        if self.engine == "flat":
            buf = jnp.stack([self.w[j] for j in donor_ids] + [self.w[i]])
            buf, self.algo_state = self.algo.on_join_flat(
                buf, slot, self.algo_state, active, self.sync_cfg, self.flat
            )
            self.w[i] = buf[slot]
        else:
            trees = [self.w[j] for j in donor_ids] + [self.w[i]]
            stack = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
            stack, self.algo_state = self.algo.on_join(
                stack, slot, self.algo_state, jnp.asarray(active), self.sync_cfg
            )
            self.w[i] = S.tree_slice(stack, slot)
        self.opt_states[i] = self.opt.init(self._w0)

    def _merged_pipe_stats(self) -> Dict[str, Any]:
        """Sum the per-trainer pipeline counters (harvested in each
        trainer's finally, read here post-join)."""
        total = PipelineStats()
        for st in self._pipe_stats:
            if st is not None:
                total.add(st)
        return total.as_dict()

    def run(self, iters_per_trainer: int) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.seed)
        kw, ke = jax.random.split(key)
        w0 = dlrm.init_dense(self.cfg, kw)
        self._w0 = w0  # hogwild-race: ok — written once pre-spawn, read-only after
        if self.engine == "flat":
            plane0 = self.flat.pack(w0)
            # swap-published: elements — slot planes rebound wholesale
            # (trainer i publishes w[i]; the sync round republishes the cohort)
            self.w: List[Pytree] = [plane0.copy() for _ in range(self.R)]
            # guarded-by: _state_lock
            self.algo_state = self.algo.init_state_flat(plane0, self.sync_cfg, self.flat)
        else:
            # swap-published: elements
            self.w = [jax.tree.map(lambda x: x.copy(), w0) for _ in range(self.R)]
            self.algo_state = self.algo.init_state(w0, self.sync_cfg)  # guarded-by: _state_lock
        # swap-published: elements — fresh optimizer state per publish
        self.opt_states = [self.opt.init(w0) for _ in range(self.R)]
        # Per-PS Hogwild states, seed-identical to the packed single table.
        # hogwild-race: ok — bound once pre-spawn; rebinding after spawn is a bug
        self.emb = emb_shards.EmbeddingShards.init(
            self.plan, ke, retry=self.shard_retry, cache=self.cache
        )
        self.done = threading.Event()
        self.examples = 0  # guarded-by-writes: ex_lock — post-join reads are lock-free
        self.sync_count = 0  # guarded-by-writes: _sync_lock — post-join reads are lock-free
        # Failure-domain bookkeeping (DESIGN.md §10): captured trainer
        # exceptions (re-raised with slot provenance after join), dead sync-
        # thread incarnations, restart/degradation state, PS chaos tracking.
        self._trainer_excs: List[Tuple[int, BaseException]] = []  # guarded-by-writes: _state_lock
        # hogwild-race: ok — append-only post-mortem log, atomic under the GIL
        self._sync_excs: List[BaseException] = []
        # hogwild-race: ok — single logical writer (generation-fenced shadow)
        self._shadow_rounds = 0
        self._sync_degraded = False  # hogwild-race: ok — single store, read post-join
        self._sync_stalled = False  # hogwild-race: ok — same contract
        self._sync_crash_t: Optional[float] = None  # hogwild-race: ok — same contract
        # hogwild-race: ok — restart hook appends; read post-join
        self._sync_count_at_restart: List[int] = []
        self._ps_injected: set = set()  # hogwild-race: ok — supervision tick owns it
        self._tick_count = 0  # hogwild-race: ok — supervision tick owns it
        self._sync_lock = threading.Lock()  # shadow/trainer threads both add
        # serializes algo_state transitions: the shadow round vs the rare
        # crash/join handlers (an unguarded read-modify-write could revert a
        # round's PS/consensus update with a stale copy)
        self._state_lock = threading.Lock()
        # guarded-by-writes: ex_lock — adds share examples' lock; eps reads are lock-free
        self.eps_meter = EPSMeter(window_s=self.eps_window_s)
        # Per-slot meters on each trainer's BUSY clock (compute + injected
        # degradation, excluding barrier waits): under fixed_rate the barrier
        # equalizes everyone's wall-clock rate, so busy-time is the only
        # signal that identifies the straggler (core/scheduler.py).
        self.slot_eps = SlotEPS(self.R, window_s=self.eps_window_s)  # hogwild-race: ok — slot-owned
        # thread-alive flags: the controller must not judge a trainer that
        # merely FINISHED (its rate decays to zero) nor re-admit a ghost
        # guarded-by-writes: _state_lock — cleared on trainer exit under the
        # lock so _readmit's alive check is race-free; reads are advisory
        self._alive = [True] * self.R
        self.iter_count = [0] * self.R  # hogwild-race: ok — slot-owned counters
        trainer_wall = [0.0] * self.R  # hogwild-race: ok — slot-owned cells, read post-join
        # Per-trainer step pipelines (DESIGN.md §13): each slot owns one
        # StepPipeline staging its own hazard-free per-shard lookups.
        # hogwild-race: ok — slot-owned cells
        self._pipes: List[Optional[StepPipeline]] = [None] * self.R
        # hogwild-race: ok — slot-owned cells, merged post-join
        self._pipe_stats: List[Optional[PipelineStats]] = [None] * self.R
        # hogwild-race: ok — slot-owned lists, merged post-join
        losses: List[List[float]] = [[] for _ in range(self.R)]
        # Quality signals (DESIGN.md §14): per-slot loss EMA feeds the
        # policy's loss-divergence demotion and the controller's quality
        # skew.
        # hogwild-race: ok — slot-owned cells (each trainer writes only its
        # own; reader threads see a coherent latest float)
        self._loss_ema = [float("nan")] * self.R
        # perf_counter stamp of each slot's last LANDED sync — the gradient
        # staleness age the policy judges. Written in the round's publish
        # step.
        # guarded-by-writes: _state_lock — lock-free reads see a coherent
        # latest stamp
        self._last_sync_t = [time.perf_counter()] * self.R
        ex_lock = threading.Lock()
        auto = self.mode_ctl is not None
        # static fixed_rate: no shadow thread exists, the monitor thread
        # carries the policy. Auto-mode runs ALWAYS keep the shadow thread
        # (it is the mode/policy evaluator even while the barrier owns the
        # rounds), so fr_static gates the no-shadow-thread paths.
        fr_static = (not auto) and self.sync_cfg.mode == "fixed_rate"
        has_fr = fr_static or auto
        # The cohort's CURRENT mode. Static runs pin it forever; auto runs
        # move it in _apply_mode_switch.
        # guarded-by-writes: _fr_cond — trainers read it lock-free each
        # iteration; a stale read is bounded-safe (an unregistered waiter
        # returns immediately from the sync point, and a trainer that
        # misses one barrier boundary re-arrives at its next gap — the
        # barrier waits, never deadlocks)
        self._mode = self.sync_cfg.mode
        # bumped on every handoff: trainers drain their own (owner-
        # confined) step pipelines when they observe it moved
        self._mode_gen = 0  # guarded-by-writes: _fr_cond
        if has_fr:
            # Foreground sync point: a Condition-based barrier whose party
            # count tracks membership, so a crash shrinks it instead of
            # deadlocking — but a straggler still drags EVERYONE (the paper's
            # fixed-rate failure mode, restated as fault tolerance) until the
            # straggler policy (if any) demotes it out of the barrier.
            self._fr_cond = threading.Condition()
            # guarded-by: _fr_cond
            self._fr_registered = [bool(b) for b in self.membership.active_mask()]
            # per-slot arrival flags, not a counter: the barrier fires only
            # when every REGISTERED slot has arrived, so demoting a slot
            # that is already waiting cannot leave a stale arrival that
            # releases the round before the rest of the cohort shows up
            self._fr_arrived = [False] * self.R  # guarded-by: _fr_cond
            self._fr_gen = 0  # guarded-by: _fr_cond
            # slot id of the thread elected to run the current round, while
            # it runs OUTSIDE the condition; None when no round is in flight
            self._fr_leader: Optional[int] = None  # guarded-by: _fr_cond
        initial_active = set(int(j) for j in self.membership.active_ids())
        # guarded-by-writes: ex_lock — late joiners poll it lock-free
        self._initial_running = len(initial_active)

        def _progress() -> int:
            return max((self.iter_count[j] for j in initial_active), default=iters_per_trainer)

        def _add_syncs(n: int) -> None:
            with self._sync_lock:
                self.sync_count += n

        def _beat(name: str) -> None:
            # liveness heartbeat; `sup` is bound later in run() — closures
            # resolve it at call time, after the threads have started
            if sup is not None:
                sup.beat(name)

        # Lookahead prefetch (DESIGN.md §11): each trainer's stream is a pure
        # function of (seed + slot, iteration), so the next K queued batches
        # per live trainer are PEEKED — regenerated on the host, memoized
        # across rounds — and their per-shard miss sets staged cold->hot by
        # the background worker between syncs. A trainer that outruns the
        # horizon pays a counted synchronous promotion, never a stall of
        # anyone else.
        _peek_memo: Dict[Tuple[int, int], np.ndarray] = {}  # guarded-by: _prefetch_gate
        _prefetch_gate = threading.Lock()

        def _prefetch_step() -> None:
            if self.cache is None or self.cache.lookahead == 0:
                return
            # the pipeline stages lookups up to depth-1 steps ahead of the
            # trainer's clock; the prefetch horizon must cover at least that
            # far or staged lookups systematically miss (DESIGN.md §13)
            la = self.cache.effective_lookahead(
                self.pipeline.depth if self.pipeline is not None else 1
            )
            if not _prefetch_gate.acquire(blocking=False):
                return  # another incarnation (restart race) is mid-round
            try:
                horizons: List[List[np.ndarray]] = [[] for _ in range(self.n_emb_shards)]
                for i in range(self.R):
                    if not self._alive[i]:
                        continue
                    base = self.iter_count[i]
                    for j in range(la):
                        it = base + j
                        if it >= iters_per_trainer:
                            break
                        idx = _peek_memo.get((i, it))
                        if idx is None:
                            idx = np.asarray(ctr.gen_batch(
                                self.cfg, self.teacher, self.seed + i, it, self.B
                            )["sparse"])
                            _peek_memo[(i, it)] = idx
                        for s in range(self.n_emb_shards):
                            horizons[s].append(emb_shards._route_np(self.plan, s, idx))
                for k in [k for k in _peek_memo if k[1] < self.iter_count[k[0]]]:
                    del _peek_memo[k]  # trained past it: peek no longer queued
                for s in range(self.n_emb_shards):
                    store = self.emb.stores[s]
                    if store is not None and self.emb.health[s]:
                        # lock-blocking: ok — the non-blocking gate IS the
                        # round's mutual exclusion; no thread ever waits on it
                        store.prefetch(horizons[s])
            finally:
                _prefetch_gate.release()

        def _round_over_active() -> int:
            # The round runs over the LIVE planes only: the matching/mean/PS
            # exchange is drawn over membership.active_ids() — dead slots are
            # simply skipped, training never blocks on them.
            #
            # The round itself is kernel dispatch wholesale, so it must not
            # run under _state_lock (no-blocking-under-lock, DESIGN.md §12):
            # capture the cohort + algorithm state under the lock, run the
            # round outside it, then publish only if neither moved in the
            # meantime. A discarded round is harmless — by the isolation
            # property the next round simply syncs strictly fresher planes.
            with self._state_lock:
                epoch = self.membership.epoch
                ids = self.membership.active_ids()
                if ids.size == 0:
                    return 0
                state_in = self.algo_state
                sub = [self.w[j] for j in ids]
            new_state, n = self._shadow_round(sub, state_in)
            with self._state_lock:
                if (self.membership.epoch != epoch or self.algo_state is not state_in):
                    return 0  # membership/algo state moved under the round
                self.algo_state = new_state
                now_sync = time.perf_counter()
                for k, j in enumerate(ids):
                    self.w[j] = sub[k]
                    # the slot's deltas just landed: its staleness age resets
                    self._last_sync_t[j] = now_sync
                return n

        def _fr_ready_locked() -> bool:  # holds-lock: _fr_cond
            regs = [j for j in range(self.R) if self._fr_registered[j]]
            return bool(regs) and all(self._fr_arrived[j] for j in regs)

        def _fr_deregister(i: int) -> None:
            # idempotent; waiters re-evaluate readiness over the slots that
            # remain registered (a stale arrival flag of a deregistered
            # slot is simply ignored)
            with self._fr_cond:
                self._fr_registered[i] = False
                self._fr_cond.notify_all()

        def _fr_register(i: int) -> None:
            # re-admission: only a live thread may rejoin the barrier — a
            # party that never arrives would deadlock the whole cohort
            # (atomic with the trainer's exit path, which deregisters under
            # this same condition)
            with self._fr_cond:
                if self._alive[i] and not self._fr_registered[i]:
                    self._fr_registered[i] = True
                    self._fr_arrived[i] = False
                self._fr_cond.notify_all()

        def _fr_sync_point(i: int) -> None:
            run_round = False
            with self._fr_cond:
                if not self._fr_registered[i]:
                    return  # demoted: train on, but never block the cohort
                gen = self._fr_gen
                self._fr_arrived[i] = True
                # wait until every REGISTERED slot arrived (a crash or
                # demotion clears a registration and notifies, so the
                # barrier re-evaluates over the remaining cohort) AND no
                # elected leader is still mid-round for this generation
                while (
                    self._fr_gen == gen
                    and self._fr_registered[i]
                    and not (_fr_ready_locked() and self._fr_leader is None)
                ):
                    self._fr_cond.wait(timeout=0.05)
                    # parked at the barrier is intentional waiting, not a
                    # stall — keep the heartbeat fresh
                    _beat(f"trainer-{i}")
                    if self._fr_gen == gen and self._fr_registered[i]:
                        # a demote -> readmit cycle while we were parked
                        # cleared our arrival flag; we ARE at the sync
                        # point, so re-assert it or the barrier starves
                        self._fr_arrived[i] = True
                if self._fr_gen == gen and not self._fr_registered[i]:
                    # demoted while waiting: clear the (now ignored) arrival
                    # and leave the barrier to the remaining cohort
                    self._fr_arrived[i] = False
                    self._fr_cond.notify_all()
                    return
                if self._fr_gen == gen:
                    # every registered slot is here: this thread is elected
                    # leader and runs the round for the whole cohort. The
                    # election happens under the condition (single leader),
                    # the round does NOT (no-blocking-under-lock) — the
                    # leader flag keeps the cohort parked meanwhile.
                    self._fr_leader = i
                    run_round = True
            if not run_round:
                return
            n = 0
            try:
                n = _round_over_active()
            finally:
                # the generation MUST advance even if the round raised,
                # or the parked cohort would wait on a dead leader forever
                with self._fr_cond:
                    for j in range(self.R):
                        self._fr_arrived[j] = False
                    self._fr_leader = None
                    self._fr_gen += 1
                    self._fr_cond.notify_all()
            if n:
                _add_syncs(n)

        def _demote(slot: int, reason: str) -> None:
            """Policy demotion: active -> dead ("leave", with provenance).
            The trainer thread keeps running — its continued local iterations
            ARE the probe stream the policy watches for re-admission — but
            its replica leaves the sync set, its shared-embedding writes are
            suppressed (the trainer checks membership per iteration), and
            (fixed_rate) it leaves the barrier."""
            with self._state_lock:
                if not self.membership.active_mask()[slot]:
                    return  # crashed/left between observation and action
                self.membership.leave(slot, reason=reason)
                self._dispatch_on_leave(slot)
            if has_fr:
                _fr_deregister(slot)

        def _readmit(slot: int, reason: str) -> None:
            """Policy re-admission after probation: dead -> joining ->
            active, bootstrapped from the live cohort exactly like a fresh
            join. The trainer may finish an in-flight iteration concurrently
            and overwrite the bootstrap with its own plane — the same
            landing-into-moving-state race every shadow round tolerates by
            design; the next sync pulls it to consensus either way."""
            with self._state_lock:
                # alive is cleared under this lock on trainer exit, so a
                # finished trainer can no longer be resurrected here
                if not self._alive[slot]:
                    return
                if self.membership.status(slot) != "dead":
                    return
                self._admit_slot(slot, reason=reason)
            if has_fr:
                _fr_register(slot)

        def _policy_step() -> None:
            policy = self.policy
            if policy is None:
                return
            now = time.perf_counter()
            pcfg = policy.config
            # quality observations (DESIGN.md §14) only when the matching
            # knob is armed — the default policy stays pace-only
            loss_by = (
                {i: self._loss_ema[i] for i in range(self.R)}
                if pcfg.loss_div_frac is not None else None)
            stale_by = (
                {i: now - self._last_sync_t[i] for i in range(self.R)}
                if pcfg.staleness_max is not None else None)
            actions = policy.observe(
                now,
                self.slot_eps.eps_by_slot(),
                self.membership.active_mask(),
                list(self._alive),
                loss_by_slot=loss_by,
                staleness_by_slot=stale_by,
            )
            for a in actions:
                if a.kind == "demote":
                    _demote(a.slot, a.reason)
                else:
                    _readmit(a.slot, a.reason)

        def _quality_skew() -> float:
            # loss-EMA divergence over the live cohort: max slot EMA over
            # the cohort median — a replica whose TRAJECTORY diverges
            # pushes the controller toward shadow even at healthy pace
            active = self.membership.active_mask()
            vals = [
                self._loss_ema[i]
                for i in range(self.R)
                if active[i] and self._alive[i]
            ]
            vals = [v for v in vals if v == v and v > 0.0]
            if len(vals) < 2:
                return 0.0
            med = median_eps(vals)
            return max(vals) / med if med > 0.0 else 0.0

        def _apply_mode_switch(dec: ModeDecision, gen: Optional[int]) -> None:
            # One whole-cohort mode handoff (DESIGN.md §14), fenced by the
            # supervisor's generation token: a stalled shadow incarnation
            # that was already replaced must not run a handoff concurrently
            # with its replacement's (the supervisor's own backup tick
            # passes gen=None — it is always current).
            if (gen is not None and sup is not None
                    and sup.generation("shadow") != gen):
                return
            if dec.target == "fixed_rate":
                # shadow -> fixed_rate: one foreground catch-up sync —
                # GBA-style compensation — BEFORE arming the barrier, so
                # stale replica deltas are merged and the first synchronous
                # step starts from consensus, not from whatever the last
                # background landing happened to leave behind
                n = _round_over_active()
                if n:
                    _add_syncs(n)
            with self._fr_cond:
                if self._mode == dec.target:
                    return  # raced another switcher: handoff already done
                active = self.membership.active_mask()
                arm = dec.target == "fixed_rate"
                for j in range(self.R):
                    self._fr_arrived[j] = False
                    self._fr_registered[j] = bool(arm and self._alive[j] and active[j])
                # fixed_rate -> shadow: bumping the generation DRAINS the
                # barrier — every parked waiter re-checks, sees its
                # generation gone, and trains on without a round; the next
                # background round then syncs from the last barrier state
                # (the shadow cadence re-seeds itself from the live planes)
                self._fr_leader = None
                self._fr_gen += 1
                self._mode = dec.target
                # trainers drain their own (owner-confined) pipelines when
                # they observe the bump: staged lookups predate the handoff
                self._mode_gen += 1
                self._fr_cond.notify_all()
            self.membership.note("mode_switch", -1, f"-> {dec.target}: {dec.reason}")

        def _mode_step(gen: Optional[int]) -> None:
            ctl = self.mode_ctl
            if ctl is None:
                return
            disp = ModeController.dispersion(
                self.slot_eps.eps_by_slot(),
                self.membership.active_mask(),
                list(self._alive),
            )
            dec = ctl.observe(time.perf_counter(), disp, quality_skew=_quality_skew())
            if dec is not None:
                _apply_mode_switch(dec, gen)

        def trainer(i: int):
            try:
                _trainer_body(i)
            except BaseException as e:
                # A dying trainer thread must not die SILENTLY (the old
                # behavior: join() succeeds, partial results look complete).
                # Capture with slot provenance — run() re-raises the first
                # after join — and record the failure in the membership log
                # so the cohort (and the sync set) sees the slot leave.
                with self._state_lock:
                    self._trainer_excs.append((i, e))
                    if self.membership.status(i) != "dead":
                        self.membership.fail(i, reason=f"exception: {type(e).__name__}: {e}")
                        self._dispatch_on_leave(i)
            finally:
                # stop the slot's stager thread (idempotent) and harvest its
                # stats before the thread object dies — crash/raise exits
                # included, or the stager would outlive its trainer
                pipe = self._pipes[i]
                if pipe is not None:
                    pipe.close()
                    self._pipe_stats[i] = pipe.stats
                # under _state_lock so _readmit's alive check is race-free
                # (a finished trainer must never be resurrected into the
                # sync set); then drop out of the barrier
                with self._state_lock:
                    self._alive[i] = False
                if has_fr:
                    _fr_deregister(i)
                if sup is not None:
                    # clean exit (or captured failure): stop watching before
                    # the thread object dies, or the supervisor would read
                    # the natural end of the run as a death
                    sup.deregister(f"trainer-{i}")
                if i in initial_active:
                    with ex_lock:
                        self._initial_running -= 1

        def _trainer_body(i: int):
            n_iters = iters_per_trainer
            if i in self.fault.join_at:
                target = self.fault.join_at[i]
                while _progress() < target:
                    if (_progress() >= iters_per_trainer or self._initial_running == 0):
                        return  # cohort finished (or all crashed) before the
                        # join point — never block run() on an unreachable join
                    _beat(f"trainer-{i}")  # waiting to join is not a stall
                    time.sleep(0.001)
                with self._state_lock:
                    self._admit_slot(i)
                if has_fr:
                    _fr_register(i)
                n_iters = max(iters_per_trainer - target, 1)
            pipe: Optional[StepPipeline] = None
            if self.pipeline is not None:
                # Per-trainer step pipeline (DESIGN.md §13): the slot's own
                # batch stream is pure in (seed + slot, iteration), so the
                # stager peeks it deterministically. The hazard check is
                # SELF-read-after-write only — interleaving with the other
                # trainers' updates is the permitted Hogwild race, exactly
                # as in the serial path.
                def _prep(it2: int) -> Dict[str, Any]:
                    b = ctr.gen_batch(self.cfg, self.teacher, self.seed + i, it2, self.B)
                    sp = np.asarray(b["sparse"])
                    rows = [
                        np.unique(emb_shards._route_np(self.plan, s, sp))
                        for s in range(self.n_emb_shards)
                    ]
                    return {"rows": rows, "batch": b, "sparse": sp}

                def _stage(s: int, it2: int, prep: Dict[str, Any], ctx: Any) -> Any:
                    return self.emb.lookup_shard(s, prep["sparse"], staged=True)

                pipe = StepPipeline(
                    self.pipeline,
                    self.n_emb_shards,
                    prepare=_prep,
                    stage_fn=_stage,
                    # any membership transition (join/crash/demote) or PS
                    # fail/recover between staging and consumption drains
                    # the staged value — the lookup reruns serially
                    epoch=lambda: self.membership.epoch,
                    shard_token=self.emb.incarnation,
                    end=n_iters,
                    name=f"pipe-{i}",
                )
                self._pipes[i] = pipe
            t_start = time.perf_counter()
            sleep_s = self.fault.straggler_sleep_s.get(i, 0.0)
            sleep_until = self.fault.straggler_until.get(i)
            crash = self.fault.crash_at.get(i)
            boom = self.fault.raise_at.get(i)
            seen_mode_gen = self._mode_gen
            for it in range(n_iters):
                _beat(f"trainer-{i}")
                if pipe is not None and self._mode_gen != seen_mode_gen:
                    # a mode handoff happened since the last check: staged
                    # lookups predate it — drain on THIS thread (stage/
                    # consume/drain are owner-confined, core/pipeline.py §13)
                    seen_mode_gen = self._mode_gen
                    pipe.drain()
                if boom is not None and it >= boom:
                    # injected software fault: an actual raise, exercising the
                    # capture -> membership.fail -> re-raise-after-join path
                    raise RuntimeError(f"injected trainer fault at iteration {it}")
                if crash is not None and it >= crash:
                    with self._state_lock:
                        # a slot the policy already demoted is dead in the
                        # membership table — its host dying is a no-op there
                        if self.membership.status(i) != "dead":
                            self.membership.fail(i)
                            self._dispatch_on_leave(i)
                    if has_fr:
                        _fr_deregister(i)
                    break
                t_busy = time.perf_counter()
                if sleep_s and (sleep_until is None or it < sleep_until):
                    time.sleep(sleep_s)  # injected degradation
                staged = prep = None
                if pipe is not None:
                    staged, prep = pipe.consume(it)
                batch = (
                    prep["batch"]
                    if prep is not None
                    else ctr.gen_batch(self.cfg, self.teacher, self.seed + i, it, self.B)
                )
                if pipe is not None:
                    # pipelined: per-shard planes staged ahead where the
                    # hazard check allowed it; hazarded/drained shards rerun
                    # serially right here — bitwise the same either way
                    sparse_np = (
                        prep["sparse"] if prep is not None else np.asarray(batch["sparse"])
                    )
                    outs = [
                        staged[s]
                        if staged is not None and staged[s] is not None
                        else self.emb.lookup_shard(s, sparse_np)
                        for s in range(self.n_emb_shards)
                    ]
                    pooled = self.emb.assemble(outs)
                    w, opt_state, loss, g_pooled = self._train_dense(
                        self.w[i], self.opt_states[i], pooled, batch
                    )
                    # stage batch it+1.. while THIS step's dense compute and
                    # sparse updates land (the overlap window)
                    pipe.stage(it)
                elif self.cache is not None:
                    # hot-tier lookup through the per-PS caches (a miss that
                    # beat the prefetch horizon promotes synchronously —
                    # counted, never a stall of another trainer)
                    sparse_np = np.asarray(batch["sparse"])
                    pooled = self.emb.cached_lookup(sparse_np)
                    w, opt_state, loss, g_pooled = self._train_dense(
                        self.w[i], self.opt_states[i], pooled, batch
                    )
                else:
                    # Lock-free read of the shared per-PS tables (Hogwild).
                    w, opt_state, loss, g_pooled = self._train_one(
                        self.w[i], self.opt_states[i], self.emb.tables(), batch
                    )
                self.w[i], self.opt_states[i] = w, opt_state
                # Lock-free read-modify-write PER SHARD: concurrent writers to
                # different PSs proceed independently; writers to the same PS
                # can interleave and lose updates (the Hogwild property).
                # A slot membership holds dead — policy-demoted — keeps
                # training PRIVATE state (its iterations are the probe
                # stream re-admission watches) but must not land its
                # degraded gradients in the SHARED embedding state: same
                # dead-slot no-op invariant as HogwildSim (DESIGN.md §8.2).
                is_member = self.membership.status(i) == "active"
                if is_member:
                    for s in range(self.n_emb_shards):
                        # routed through the PS failure domain: a healthy
                        # shard takes the plain lock-free swap; a failed one
                        # retries with backoff then DROPS the update (counted)
                        # — training never blocks on a dead PS
                        if self.cache is not None:
                            self.emb.cached_update(s, sparse_np, g_pooled, self.emb_lr)
                        else:
                            self.emb.try_update(s, self._emb_updates[s], batch["sparse"], g_pooled)
                lv = float(loss)
                losses[i].append(lv)
                # slot-owned loss EMA (quality signal, DESIGN.md §14)
                prev = self._loss_ema[i]
                self._loss_ema[i] = lv if prev != prev else 0.9 * prev + 0.1 * lv
                self.iter_count[i] = it + 1
                # busy time stops HERE, before any barrier wait: the per-slot
                # meter reads the trainer's intrinsic pace in both modes
                # (probe iterations of a demoted slot included — that is the
                # signal re-admission watches)
                self.slot_eps.tick(i, time.perf_counter() - t_busy)
                self.slot_eps.add(i, self.B)
                if is_member:
                    # headline eps/eps_window count COHORT work only: a
                    # demoted slot's probe iterations are discarded work
                    with ex_lock:
                        self.examples += self.B
                        self.eps_meter.add(self.B)
                if (has_fr and (it + 1) % self.sync_cfg.gap == 0
                        and self._mode == "fixed_rate"):
                    _fr_sync_point(i)
            trainer_wall[i] = time.perf_counter() - t_start

        def _shadow_body(gen: int):
            # One incarnation of the shadow loop. A restarted incarnation
            # resumes rounds against the LIVE membership state — safe because
            # training never blocked on the sync engine (the isolation
            # property, paper §3.3). ``gen`` is the supervisor's generation
            # token at spawn: a stalled-but-alive zombie whose replacement is
            # already running sees itself superseded and stands down.
            while not self.done.is_set():
                if sup is not None and sup.generation("shadow") != gen:
                    return  # fenced out: a replacement owns the rounds now
                r = self._shadow_rounds
                if (self.fault.sync_crash_at is not None
                        and r >= self.fault.sync_crash_at
                        and self._sync_crash_t is None):
                    self._sync_crash_t = time.perf_counter()
                    raise RuntimeError(f"injected sync-thread crash at round {r}")
                if (self.fault.sync_stall_at is not None
                        and r >= self.fault.sync_stall_at
                        and not self._sync_stalled):
                    # wedge WITHOUT beating: the supervisor must detect the
                    # stale heartbeat, fence this incarnation, and restart
                    self._sync_stalled = True
                    t_end = time.perf_counter() + self.fault.sync_stall_s
                    while (time.perf_counter() < t_end and not self.done.is_set()):
                        time.sleep(0.01)
                    continue  # generation check above retires the zombie
                _beat("shadow")
                if auto and self._mode == "fixed_rate":
                    # the barrier's elected leaders own the rounds in
                    # fixed_rate; this thread idles as the mode/policy
                    # evaluator (and keeps the prefetch + snapshot cadence
                    # below alive) until the controller switches back
                    time.sleep(0.001)
                else:
                    # One algorithm-owned background round over the live
                    # replica planes — landings interpolate into the CURRENT
                    # state while trainers keep moving (paper §3.3).
                    n = _round_over_active()
                    if n:
                        _add_syncs(n)
                    else:
                        time.sleep(0.001)
                self._shadow_rounds = r + 1
                # the shadow thread is already the background worker: the
                # cache's lookahead prefetch rides BETWEEN the sync rounds
                # (stage promotions/evictions while trainers compute), and
                # PS snapshots ride its cadence (O(1) reference grabs;
                # O(hot_rows) merged() drains in cached mode)
                _prefetch_step()
                if self._shadow_rounds % self.ps_snapshot_every == 0:
                    self.emb.snapshot_all()
                # the controllers ride the shadow cadence: membership AND
                # the cohort mode are re-evaluated every background round,
                # training never blocks on either
                _policy_step()
                _mode_step(gen)
                if self.sync_sleep_s:
                    time.sleep(self.sync_sleep_s)

        def shadow(gen: int = 0):
            try:
                _shadow_body(gen)
            except BaseException as e:
                # die quietly: the supervisor's death detection (and the
                # restart it triggers) IS the recovery path; the exception is
                # kept for the output record
                self._sync_excs.append(e)

        def _restart_shadow() -> threading.Thread:
            # Called by the supervisor (outside its lock) after backoff. The
            # generation token was already bumped, fencing any stalled
            # zombie; record where sync_count stood so the bench can assert
            # post-restart progress.
            with self._sync_lock:
                self._sync_count_at_restart.append(self.sync_count)
            gen = sup.generation("shadow")
            self.membership.note(
                "sync_restart", -1,
                f"shadow thread restarted (attempt "
                f"{len(self._sync_count_at_restart)}, generation {gen})")
            t = threading.Thread(target=shadow, args=(gen,), daemon=True)
            t.start()
            return t

        def _sync_give_up(name: str) -> None:
            # Degradation ladder, last rung (DESIGN.md §10.2): training keeps
            # running locally (isolation means nothing breaks), the event log
            # records the degradation with provenance, and run() forces one
            # final FOREGROUND sync at shutdown so the run still converges.
            self._sync_degraded = True
            self.membership.note(
                "degraded", -1,
                "sync engine degraded: restart budget exhausted; training "
                "continues locally, final foreground sync at shutdown")

        def _supervision_tick() -> None:
            # PS chaos injection + timed recovery ride the supervisor's
            # watch loop (its clock domain is the policy's: perf_counter).
            if fr_static:
                # no shadow thread to ride: the lookahead prefetch and the
                # background PS snapshots take the watch-loop cadence instead
                self._tick_count += 1
                _prefetch_step()
                if self._tick_count % 10 == 0:
                    self.emb.snapshot_all()
            for s, at in self.fault.ps_fail_at.items():
                if s not in self._ps_injected and _progress() >= at:
                    self._ps_injected.add(s)
                    self.emb.fail_shard(s, reason=f"injected PS failure at iteration {at}")
                    self.membership.note(
                        "ps_fail", -1,
                        f"embedding shard {s} down: live state lost, serving "
                        f"snapshot reads, dropping writes after retry")
            now = time.perf_counter()
            for s in list(self.emb.failed_at):
                t_fail = self.emb.failed_at.get(s)
                if (t_fail is not None and now - t_fail >= self.fault.ps_recover_after_s):
                    self.emb.recover_shard(
                        s, reason=f"rehydrated from snapshot after " f"{now - t_fail:.2f}s down"
                    )
                    self.membership.note(
                        "ps_recover", -1, f"embedding shard {s} rejoined the routing plan"
                    )
            # backup policy/mode clock: membership AND mode decisions keep
            # flowing even while the thread that normally evaluates them
            # (the shadow thread) is the thing being restarted (gen=None:
            # the supervisor's own tick is always the current incarnation)
            _policy_step()
            _mode_step(None)

        def monitor():
            # fixed_rate has no shadow thread, so the controller gets its own
            # (otherwise a demotion decision could only happen at a barrier —
            # exactly the place the straggler is blocking everyone)
            while not self.done.is_set():
                _beat("monitor")
                _policy_step()
                time.sleep(0.02)

        sup = (
            Supervisor(self.supervisor_config, tick=_supervision_tick) if self.supervise else None
        )
        self.supervisor = sup
        threads = [threading.Thread(target=trainer, args=(i,)) for i in range(self.R)]
        shadow_t = None if fr_static else threading.Thread(target=shadow, args=(0,), daemon=True)
        monitor_t = (
            threading.Thread(target=monitor, daemon=True)
            if fr_static and self.policy is not None
            else None
        )
        # register BEFORE starting anything: a fast-finishing thread must
        # never race its own registration (it deregisters itself on exit)
        if sup is not None:
            for i, t in enumerate(threads):
                sup.register(f"trainer-{i}", t)  # watch-only
            if shadow_t is not None:
                sup.register("shadow", shadow_t, restart=_restart_shadow, on_give_up=_sync_give_up)
            if monitor_t is not None:
                sup.register("monitor", monitor_t)
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        if shadow_t is not None:
            shadow_t.start()
        if monitor_t is not None:
            monitor_t.start()
        if sup is not None:
            sup.start()
        for t in threads:
            t.join()
        self.done.set()
        sync_restarts = 0
        if sup is not None:
            sync_restarts = sup.restarts("shadow")
            cur = sup.thread("shadow")
            if cur is not None:
                shadow_t = cur  # join the CURRENT incarnation, not gen 0
            # clean shutdown: done is set and the loops exit on their own —
            # stop watching first, or the supervisor would read those clean
            # exits as deaths and spin up doomed replacements
            sup.deregister("shadow")
            sup.deregister("monitor")
            sup.stop()
        if shadow_t is not None:
            shadow_t.join(timeout=5.0)
            if shadow_t.is_alive():
                warnings.warn(
                    "shadow thread failed to exit within 5s at shutdown "
                    "(sync engine wedged?); proceeding — the returned state "
                    "may race one final background round", RuntimeWarning)
        if monitor_t is not None:
            monitor_t.join(timeout=5.0)
            if monitor_t.is_alive():
                warnings.warn(
                    "monitor thread failed to exit within 5s at " "shutdown", RuntimeWarning
                )
        # rehydrate any still-down PS so the returned packed state is the
        # best surviving copy and a subsequent run starts healthy
        for s in self.emb.down_shards():
            self.emb.recover_shard(s, reason="shutdown rehydrate")
            self.membership.note("ps_recover", -1, f"embedding shard {s} rehydrated at shutdown")
        final_fg_sync = False
        if self._sync_degraded and self.membership.active_ids().size > 0:
            # degradation ladder's last rung: one FOREGROUND sync so the run
            # still converges to a synchronized model
            n = _round_over_active()
            if n:
                _add_syncs(n)
                final_fg_sync = True
        wall = time.perf_counter() - t0
        if self._trainer_excs:
            i, e = self._trainer_excs[0]
            others = len(self._trainer_excs) - 1
            raise RuntimeError(
                f"trainer thread (slot {i}) died with "
                f"{type(e).__name__}: {e}"
                + (f"; {others} more trainer exception(s) captured" if others else "")
            ) from e
        total_iters = sum(self.iter_count)
        if self.engine == "flat":
            w_out = [self.flat.unpack(p) for p in self.w]
        else:
            w_out = self.w
        return {
            "eps": self.examples / wall,
            # rate over the trailing window — after a crash this is the
            # SURVIVORS' pace, not an average diluted by the dead trainer
            "eps_window": self.eps_meter.eps,
            "wall_s": wall,
            "train_loss": [float(np.mean(l[-50:])) if l else float("nan") for l in losses],
            "sync_count": self.sync_count,
            "avg_sync_gap": total_iters / max(self.sync_count, 1),
            "per_trainer_eps": [
                self.B * self.iter_count[i] / trainer_wall[i]
                if trainer_wall[i] > 0 and self.iter_count[i] > 0 else 0.0
                for i in range(self.R)],
            # intrinsic (busy-clock) pace per slot: what the straggler
            # controller saw; barrier waits excluded
            "per_trainer_eps_busy": [
                self.B * self.iter_count[i] / self.slot_eps.busy(i)
                if self.slot_eps.busy(i) > 0 else 0.0
                for i in range(self.R)],
            "iter_count": list(self.iter_count),
            "membership_events": list(self.membership.events),
            "policy_transitions": (
                list(self.policy.transitions) if self.policy is not None else []
            ),
            # failure-domain telemetry (DESIGN.md §10)
            "supervision_events": (list(sup.events) if sup is not None else []),
            "shard_events": list(self.emb.events),
            "dropped_updates": list(self.emb.dropped_updates),
            "stale_lookups": list(self.emb.stale_lookups),
            # tiered-cache telemetry (DESIGN.md §11; {} when cache is off)
            "cache_stats": (self.emb.cache_stats() if self.cache is not None else {}),
            # step-pipeline telemetry (DESIGN.md §13; {} when pipelining is
            # off): per-trainer stats merged post-join
            "pipeline_stats": (self._merged_pipe_stats() if self.pipeline is not None else {}),
            # mode-switching telemetry (DESIGN.md §14): the final mode and
            # the controller's decision log (empty when auto-mode is off)
            "mode": self._mode,
            "mode_transitions": (
                list(self.mode_ctl.transitions) if self.mode_ctl is not None else []
            ),
            "sync_rounds": self._shadow_rounds,
            "sync_restarts": sync_restarts,
            "sync_count_at_restart": list(self._sync_count_at_restart),
            "sync_degraded": self._sync_degraded,
            "final_foreground_sync": final_fg_sync,
            "t_start": t0,
            "w": w_out,
            # Engine-independent packed view of the per-PS states.
            "emb_state": self.emb.to_packed(),
        }

"""NestPipe-style step pipelining with a hazard-checked double buffer.

Every training step used to serialize embedding lookup -> dense
forward/backward -> per-shard sparse-Adagrad update, even though the batch
stream is a pure function of ``(seed, iteration)`` and the §11 prefetcher
already peeks it. NestPipe (PAPERS.md) scales recommendation training by
nesting pipelines so the PS lookup for batch k+1 overlaps the dense pass of
batch k; BagPipe shows the same deterministic lookahead admits *exact*,
semantics-preserving overlap. ``StepPipeline`` is that move for both
runners (DESIGN.md §13):

* a background **staging worker** peeks future batches (``prepare`` — pure
  in the iteration counter) and dispatches their per-shard fused lookups
  (``stage_fn``) up to ``depth - 1`` steps ahead, while the training thread
  is blocked inside the current step's dense jit;
* the training thread calls ``consume(t)`` at the top of step ``t`` (a
  staged pooled plane, or None -> run the lookup serially), ``stage(t)``
  once the dense pass is dispatched but BEFORE step ``t``'s sparse update
  lands (so a captured ``make_ctx`` context predates the update), and
  ``drain()`` before any membership epoch advances.

**Hazard rule (read-after-write, deterministic).** A lookup staged for
batch ``j`` from the context of batch ``base`` races the sparse updates of
batches ``[base, j)``, which have not landed when it dispatches. Per shard,
the rows batch ``k`` updates are exactly the rows it reads, so the staged
lookup is bitwise-identical to the serial one iff batch ``j``'s row set is
disjoint from every window batch's row set on that shard. The worker checks
that disjointness over the peeked index stream; a colliding shard is NOT
staged — its lookup runs serially at consume time, after the updates landed
(counted in ``hazard_serialized``). Both paths are exact, so the pipelined
trajectory is bitwise-identical to the serial one (tests/test_pipeline.py
pins this across engines and cache modes).

**Drain semantics.** Elastic events must not consume stale stages: the
owner calls ``drain()`` before a membership epoch advances (the sim), and
``consume`` re-validates the ``epoch`` and per-shard ``shard_token``
captured at staging time (the threaded runner: membership epoch + PS store
incarnation) — any mismatch discards the staged value (counted in
``drains``) and the lookup reruns serially against the post-event state.

The worker catches every exception (a staging failure degrades the run to
serial, it never kills it — ``worker_errors``), and all jax dispatch runs
outside the pipeline lock (no-blocking-under-lock, DESIGN.md §12).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

Prep = Dict[str, Any]


@dataclass(frozen=True)
class PipelineConfig:
    """``depth`` is the number of in-flight steps including the one being
    consumed: depth 1 is the serial loop (nothing staged, no worker thread),
    depth 2 double-buffers (batch k+1's lookup dispatches while batch k's
    dense jit runs), depth d keeps d-1 lookups staged ahead."""

    depth: int = 2

    def validate(self) -> "PipelineConfig":
        if self.depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {self.depth}")
        return self


@dataclass
class PipelineStats:
    steps: int = 0  # consume() calls (pipelined training steps)
    shard_steps: int = 0  # steps x shards: the overlap-rate denominator
    overlapped: int = 0  # shard-steps served from a staged lookup
    hazard_serialized: int = 0  # shard-steps the RAW hazard forced serial
    drains: int = 0  # staged work discarded (drain()/epoch/incarnation)
    worker_errors: int = 0  # staging exceptions (the run degrades to serial)

    @property
    def overlap_rate(self) -> float:
        return self.overlapped / max(self.shard_steps, 1)

    def add(self, other: "PipelineStats") -> "PipelineStats":
        for k, v in other.__dict__.items():
            setattr(self, k, getattr(self, k) + v)
        return self

    def as_dict(self) -> Dict[str, float]:
        d = dict(self.__dict__)
        d["overlap_rate"] = self.overlap_rate
        return d


class _Staged:
    """One in-flight pipeline entry. The worker writes ``vals``/``tokens``/
    ``prep`` then sets ``done`` — the Event publish is the happens-before
    edge the consuming thread reads through."""

    __slots__ = ("it", "base", "gen", "epoch0", "ctx", "done", "vals", "tokens", "prep")

    def __init__(self, it: int, base: int, gen: int, epoch0: Any, ctx: Any):
        self.it = it  # iteration this entry stages
        self.base = base  # consuming step when it was staged (window start)
        self.gen = gen  # drain generation at staging time
        self.epoch0 = epoch0  # membership epoch at staging time
        self.ctx = ctx  # owner-thread context (e.g. pre-update emb state)
        self.done = threading.Event()
        self.vals: Optional[List[Any]] = None  # per-shard staged lookups
        self.tokens: Optional[List[Any]] = None  # per-shard tokens at dispatch
        self.prep: Optional[Prep] = None  # the worker's peeked batch/rows


class StepPipeline:
    """Double-buffered step pipeline over ``n_shards`` independent lookup
    units (the per-PS shards of the threaded runner; one unit for the sim's
    packed table).

    Callbacks (all provided by the owning runner):

    * ``prepare(it) -> {"rows": [per-shard unique row ids], ...}`` — peek
      iteration ``it``'s batch. Pure in ``it`` (the deterministic stream),
      called on the worker thread; whatever else it returns (the generated
      batch, routed indices) rides back through ``consume`` so the owner
      never regenerates a peeked batch.
    * ``stage_fn(s, it, prep, ctx)`` — dispatch shard ``s``'s fused lookup
      for iteration ``it``. Called on the worker, never under a lock.
    * ``make_ctx()`` — optional owner-thread capture at ``stage()`` time
      (the sim's pre-update embedding state ref; immutable jnp arrays make
      the captured view torn-write-free).
    * ``epoch()`` / ``shard_token(s)`` — optional validity tokens captured
      at staging and re-checked at consumption; any change discards the
      staged value (a counted drain, never a wrong read).

    Thread model: ``stage``/``consume``/``drain``/``close`` run on the
    OWNING training thread only; the single staging worker communicates via
    the job queue and per-entry Events; shared counters sit under ``_lock``.
    """

    def __init__(
        self,
        cfg: PipelineConfig,
        n_shards: int,
        *,
        prepare: Callable[[int], Prep],
        stage_fn: Callable[[int, int, Prep, Any], Any],
        make_ctx: Optional[Callable[[], Any]] = None,
        epoch: Optional[Callable[[], Any]] = None,
        shard_token: Optional[Callable[[int], Any]] = None,
        end: Optional[int] = None,
        name: str = "pipeline",
    ):
        self.cfg = cfg.validate()
        self.n_shards = int(n_shards)
        self._prepare = prepare
        self._stage_fn = stage_fn
        self._make_ctx = make_ctx
        self._epoch = epoch
        self._shard_token = shard_token
        self._end = end  # first iteration past the stream (never staged)
        self._lock = threading.Lock()
        self._stats = PipelineStats()  # guarded-by: _lock
        self._gen = 0  # guarded-by: _lock — drain generation fence
        self._error: Optional[BaseException] = None  # guarded-by: _lock
        self._disabled = False  # guarded-by: _lock — set on worker error
        # hogwild-race: ok — owner-thread-confined (stage/consume/drain all
        # run on the one training thread that owns this pipeline)
        self._buf: Dict[int, _Staged] = {}
        # hogwild-race: ok — worker-thread-confined peek memo
        self._prep_memo: Dict[int, Prep] = {}
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._worker: Optional[threading.Thread] = None
        if self.cfg.depth > 1:
            self._worker = threading.Thread(
                target=self._worker_loop, name=f"{name}-stager", daemon=True
            )
            self._worker.start()

    # -- owner-thread API ----------------------------------------------------
    def stage(self, t: int) -> None:
        """Queue the lookups of iterations ``(t, t + depth)`` that are not
        already in flight. Call AFTER step ``t``'s dense dispatch (the
        worker then overlaps its staging with the dense execution) and
        BEFORE step ``t``'s sparse update, so ``make_ctx`` captures the
        pre-update state the hazard rule reasons about."""
        if self._worker is None:
            return
        with self._lock:
            if self._disabled:
                return
            gen = self._gen
        epoch0 = self._epoch() if self._epoch is not None else None
        for j in range(t + 1, t + self.cfg.depth):
            if self._end is not None and j >= self._end:
                break
            if j in self._buf:
                continue
            ctx = self._make_ctx() if self._make_ctx is not None else None
            entry = _Staged(j, t, gen, epoch0, ctx)
            self._buf[j] = entry
            self._q.put(entry)

    def consume(self, t: int) -> tuple:
        """-> ``(vals, prep)``: per-shard staged lookups (``None`` entries
        run serially — never staged, hazard-serialized, or drained) plus the
        worker's peeked prep for ``t`` (``None`` -> regenerate)."""
        with self._lock:
            self._stats.steps += 1
            self._stats.shard_steps += self.n_shards
        entry = self._buf.pop(t, None)
        if entry is None:
            return [None] * self.n_shards, None
        # The worker always publishes (its error path publishes Nones); an
        # unpublished entry with a dead worker means the job was never
        # dequeued — fall back to serial rather than wait forever.
        while not entry.done.wait(timeout=1.0):
            if self._worker is None or not self._worker.is_alive():
                return [None] * self.n_shards, None
        vals, tokens, prep = entry.vals, entry.tokens, entry.prep
        with self._lock:
            stale = entry.gen != self._gen
        if stale or (self._epoch is not None and self._epoch() != entry.epoch0):
            # an elastic event advanced under this entry: discard the staged
            # lookups (prep is iteration-pure, so it stays reusable)
            with self._lock:
                self._stats.drains += 1
            return [None] * self.n_shards, prep
        out: List[Any] = []
        overlapped = drained = 0
        for s in range(self.n_shards):
            v = vals[s] if vals is not None else None
            if (
                v is not None
                and self._shard_token is not None
                and self._shard_token(s) != tokens[s]
            ):
                drained += 1  # e.g. the PS failed/recovered mid-stage
                v = None
            if v is not None:
                overlapped += 1
            out.append(v)
        with self._lock:
            self._stats.overlapped += overlapped
            self._stats.drains += drained
        return out, prep

    def drain(self) -> None:
        """Discard every in-flight stage. The owner calls this BEFORE a
        membership epoch advances (demote/crash/join, PS fail): staged
        lookups captured pre-event must not serve post-event steps."""
        if not self._buf:
            return
        with self._lock:
            self._gen += 1  # queued-but-unstarted jobs are fenced out
            self._stats.drains += len(self._buf)
        self._buf.clear()

    def close(self) -> None:
        """Stop the staging worker (sentinel + join). Idempotent."""
        worker, self._worker = self._worker, None
        if worker is None:
            return
        self._q.put(None)
        worker.join(timeout=5.0)
        self._buf.clear()

    @property
    def stats(self) -> PipelineStats:
        with self._lock:
            return PipelineStats(**self._stats.__dict__)

    @property
    def error(self) -> Optional[BaseException]:
        with self._lock:
            return self._error

    # -- staging worker ------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            entry = self._q.get()
            if entry is None:
                return
            with self._lock:
                stale = entry.gen != self._gen
                disabled = self._disabled
            if stale:
                continue  # drained while queued; never consumed
            if disabled:
                self._publish_empty(entry)
                continue
            try:
                self._run_job(entry)
            except BaseException as e:  # noqa: BLE001 — a staging failure
                # must degrade to serial, never reach threading.excepthook
                with self._lock:
                    self._error = e
                    self._stats.worker_errors += 1
                    self._disabled = True
                self._publish_empty(entry)

    def _publish_empty(self, entry: _Staged) -> None:
        entry.vals = [None] * self.n_shards
        entry.tokens = [None] * self.n_shards
        entry.done.set()

    def _run_job(self, entry: _Staged) -> None:
        j = entry.it
        prep_j = self._prep_of(j)
        rows_j = prep_j["rows"]
        window = [self._prep_of(k)["rows"] for k in range(entry.base, j)]
        vals: List[Any] = [None] * self.n_shards
        tokens: List[Any] = [None] * self.n_shards
        hazards = 0
        for s in range(self.n_shards):
            # read-after-write hazard: batch j reads a row some window batch
            # will update -> do NOT stage this shard (its serial lookup at
            # consume time sees the landed updates — exactness over overlap)
            if any(len(np.intersect1d(rows_j[s], w[s], assume_unique=True)) for w in window):
                hazards += 1
                continue
            if self._shard_token is not None:
                tokens[s] = self._shard_token(s)
            vals[s] = self._stage_fn(s, j, prep_j, entry.ctx)
        if hazards:
            with self._lock:
                self._stats.hazard_serialized += hazards
        # prune the peek memo below the oldest window any future job can need
        for k in [k for k in self._prep_memo if k < entry.base]:
            del self._prep_memo[k]
        entry.prep = prep_j
        entry.vals = vals
        entry.tokens = tokens
        entry.done.set()

    def _prep_of(self, it: int) -> Prep:
        p = self._prep_memo.get(it)
        if p is None:
            p = self._prepare(it)
            self._prep_memo[it] = p
        return p

"""Closed-loop straggler scheduling: EPS-driven auto-demotion / re-admission.

PR 4 made replica membership mutable but only ever changed it by *injected*
fault (``--crash-at`` / ``--join-at``); a straggler silently dragged quality
(its updates go stale) and, in ``fixed_rate`` mode, dragged the whole cohort
to its pace. This module closes the detect → demote → re-admit loop — in the
spirit of BagPipe's measure-then-schedule approach — turning the windowed
``EPSMeter`` from a dashboard into a controller: the last un-elastic decision
in the stack (who is a member) becomes measured, not declared.

``StragglerPolicy`` is a deterministic state machine over per-slot EPS
observations (DESIGN.md §9):

    healthy --breach persists window_s--> suspect --> DEMOTED ("leave")
    demoted --healthy probes persist probation_s--> probation --> re-admitted
                                                                  ("join")

* Demotion: a slot's EPS stays below ``eps_floor_frac`` x the live median
  for a full ``window_s`` (two observations minimum — a single dip is never
  acted on).
* Re-admission: a demoted slot's EPS stays at or above ``readmit_frac`` x
  the live median for a full ``probation_s`` of healthy probe observations.
* Hysteresis: ``readmit_frac > eps_floor_frac``, so a slot must prove MORE
  than marginal health to come back — a borderline slot parks as demoted
  instead of flapping through the membership log.
* Quorum: the controller never demotes below ``min_active`` live slots, and
  it only re-admits slots IT demoted — crashed slots belong to the fault
  harness, joining slots to their bootstrap.

The policy is runtime-agnostic: ``ThreadedShadowRunner`` feeds it real
busy-time EPS readings (``elp.SlotEPS``) from the shadow thread each round;
``StragglerSchedule`` adapts it into a deterministic
``MembershipSchedule``-compatible event source for ``HogwildSim``, where the
per-slot rates come from a scripted trace — same controller, reproducible
trajectories.

Supervision (PR 6, DESIGN.md §10): the ``core.supervision.Supervisor`` watch
loop also ticks the policy, on the SAME clock domain (``time.perf_counter``),
so membership decisions keep flowing while the thread that normally evaluates
the policy — the shadow thread — is itself dead or being restarted. Two
threads may therefore call ``observe`` concurrently; the state machine is
lock-guarded so a transition is never evaluated twice against one
observation window.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.elp import median_eps
from repro.core.membership import MembershipSchedule

HEALTHY = "healthy"
SUSPECT = "suspect"
DEMOTED = "demoted"
PROBATION = "probation"


@dataclass(frozen=True)
class PolicyConfig:
    """Tuning knobs for ``StragglerPolicy`` (defaults favor stability over
    reaction speed; benchmarks/elastic_bench.py uses a snappier profile)."""

    eps_floor_frac: float = 0.5   # demote below this fraction of live median
    readmit_frac: float = 0.75    # re-admit at/above this fraction (hysteresis)
    window_s: float = 1.0         # breach must persist this long to demote
    probation_s: float = 1.0      # healthy probes must persist this long
    min_active: int = 2           # never demote below this many live slots
    # Quality signals (PR 5 follow-on, DESIGN.md §14): pace is not the only
    # way a slot poisons the cohort. ``loss_div_frac`` demotes a slot whose
    # loss EMA stays above (1 + frac) x the cohort median loss for a full
    # window — a diverging trajectory at healthy pace. ``staleness_max``
    # demotes a slot whose last landed sync is older than this (the
    # caller's clock units: wall seconds threaded, iterations in the sim) —
    # its deltas are too stale to merge safely. Both default off; staleness
    # never blocks RE-admission (a demoted slot's age grows by
    # construction — only the pace/loss probes can clear it).
    loss_div_frac: Optional[float] = None
    staleness_max: Optional[float] = None

    def validate(self) -> "PolicyConfig":
        if not 0.0 < self.eps_floor_frac <= 1.0:
            raise ValueError(f"eps_floor_frac must be in (0, 1], " f"got {self.eps_floor_frac}")
        if self.readmit_frac <= self.eps_floor_frac:
            raise ValueError(
                f"readmit_frac ({self.readmit_frac}) must be > "
                f"eps_floor_frac ({self.eps_floor_frac}) — the hysteresis "
                f"band is what stops a borderline slot from flapping")
        if self.window_s <= 0 or self.probation_s < 0:
            raise ValueError(
                f"need window_s > 0 and probation_s >= 0, got "
                f"window_s={self.window_s}, "
                f"probation_s={self.probation_s}"
            )
        if self.min_active < 1:
            raise ValueError(f"min_active must be >= 1, got {self.min_active}")
        if self.loss_div_frac is not None and self.loss_div_frac <= 0:
            raise ValueError(f"loss_div_frac must be > 0, got {self.loss_div_frac}")
        if self.staleness_max is not None and self.staleness_max <= 0:
            raise ValueError(f"staleness_max must be > 0, got {self.staleness_max}")
        return self


@dataclass(frozen=True)
class PolicyAction:
    """One controller decision, with provenance for the membership log."""

    kind: str  # "demote" | "readmit"
    slot: int
    reason: str


@dataclass
class _SlotState:
    state: str = HEALTHY
    since: float = 0.0  # entry time of a timed state (suspect/probation)
    # the live median the slot was judged against when demoted: the
    # re-admission bar when no OTHER eligible slot remains to compare
    # against (health must be proven, never defaulted)
    ref_eps: float = 0.0


class StragglerPolicy:
    """EPS-driven membership controller. Feed it per-slot rate observations
    via ``observe``; it returns the demote/re-admit actions to apply.

    Deterministic: actions depend only on the observation sequence (no
    internal clocks — ``now`` is a caller-supplied timestamp, wall seconds
    in the threaded runner, the iteration counter in ``StragglerSchedule``).
    """

    def __init__(self, config: Optional[PolicyConfig] = None, n_slots: int = 0):
        self.config = (config or PolicyConfig()).validate()
        if n_slots < 1:
            raise ValueError(f"need n_slots >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        # guarded-by-writes: _lock — fixed slot list; states move under _lock,
        # lock-free reads (state/demoted_slots) see a coherent latest state
        self._slots = [_SlotState() for _ in range(self.n_slots)]
        # (now, slot, from_state, to_state) — observability + tests
        self.transitions: List[Tuple[float, int, str, str]] = []  # guarded-by-writes: _lock
        # observe() may be called from two threads (the shadow round AND the
        # supervisor's tick while the shadow thread is down/restarting)
        self._lock = threading.Lock()

    def demoted_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s.state in (DEMOTED, PROBATION)]

    def state(self, slot: int) -> str:
        return self._slots[slot].state

    def _move(self, now: float, slot: int, to: str) -> None:  # holds-lock: _lock
        st = self._slots[slot]
        self.transitions.append((now, slot, st.state, to))
        st.state, st.since = to, now

    def observe(
        self,
        now: float,
        eps_by_slot: Mapping[int, float],
        active: Sequence[bool],
        eligible: Optional[Sequence[bool]] = None,
        *,
        loss_by_slot: Optional[Mapping[int, float]] = None,
        staleness_by_slot: Optional[Mapping[int, float]] = None,
    ) -> List[PolicyAction]:
        """One controller round.

        ``active``: the membership mask (who is currently training AND
        syncing). ``eligible``: slots with a live host behind them (the
        threaded runner passes its thread-alive flags so a trainer that
        simply FINISHED — whose rate decays to zero — is neither demoted
        nor re-admitted); defaults to all-eligible. ``loss_by_slot`` /
        ``staleness_by_slot``: optional quality observations (per-slot loss
        EMA, seconds/iterations since the slot's last landed sync) — only
        consulted when the matching ``PolicyConfig`` knob is set.
        """
        with self._lock:
            return self._observe_locked(
                now, eps_by_slot, active, eligible,
                loss_by_slot=loss_by_slot, staleness_by_slot=staleness_by_slot)

    # holds-lock: _lock
    def _observe_locked(
        self,
        now: float,
        eps_by_slot: Mapping[int, float],
        active: Sequence[bool],
        eligible: Optional[Sequence[bool]],
        *,
        loss_by_slot: Optional[Mapping[int, float]] = None,
        staleness_by_slot: Optional[Mapping[int, float]] = None,
    ) -> List[PolicyAction]:
        cfg = self.config
        if eligible is None:
            eligible = [True] * self.n_slots
        live = [i for i in range(self.n_slots) if i < len(active) and active[i] and eligible[i]]
        # The median's base is the live cohort PLUS our own demoted slots,
        # so probation probes stay comparable to the cohort that demoted
        # them. One straggler among R cannot drag the median: it is the
        # middle, not the mean. (If the base ever degenerates to a demoted
        # slot alone, re-admission falls back to that slot's demotion-time
        # reference median — see below.)
        base = [i for i in range(self.n_slots)
                if eligible[i] and ((i < len(active) and active[i])
                                    or self._slots[i].state in (DEMOTED,
                                                                PROBATION))]
        median = median_eps(eps_by_slot.get(i, 0.0) for i in base)
        actions: List[PolicyAction] = []
        if median <= 0.0:
            return actions  # no signal yet (startup) — never act blind
        floor = cfg.eps_floor_frac * median
        n_live = len(live)
        # cohort median loss for the divergence check: over the live slots
        # with a finite observation (a slot with no loss yet never skews it)
        loss_med = 0.0
        if cfg.loss_div_frac is not None and loss_by_slot:
            lv = [float(loss_by_slot[i]) for i in live
                  if i in loss_by_slot and float(loss_by_slot[i]) == float(loss_by_slot[i])]
            if len(lv) >= 2:
                loss_med = median_eps(lv)

        def _breach(slot: int, eps: float) -> Optional[str]:
            # pace first (the original signal), then the quality signals —
            # the FIRST breach names the demotion, so provenance stays
            # single-cause and parseable
            if eps < floor:
                return (f"straggler: eps {eps:.0f} < "
                        f"{cfg.eps_floor_frac:.2f} x live median {median:.0f} "
                        f"for {cfg.window_s:g}s")
            if cfg.loss_div_frac is not None and loss_med > 0.0 and loss_by_slot:
                loss = float(loss_by_slot.get(slot, float("nan")))
                if loss == loss and loss > (1.0 + cfg.loss_div_frac) * loss_med:
                    return (f"loss-divergence: loss {loss:.4f} > "
                            f"(1 + {cfg.loss_div_frac:g}) x cohort median "
                            f"{loss_med:.4f} for {cfg.window_s:g}s")
            if cfg.staleness_max is not None and staleness_by_slot is not None:
                age = float(staleness_by_slot.get(slot, 0.0))
                if age > cfg.staleness_max:
                    return (f"staleness: {age:.3g} since last landed sync > "
                            f"{cfg.staleness_max:g} for {cfg.window_s:g}s")
            return None

        for slot in range(self.n_slots):
            st = self._slots[slot]
            eps = eps_by_slot.get(slot, 0.0)
            if st.state in (HEALTHY, SUSPECT):
                if slot not in live:
                    # crashed / left / finished outside our control: forget
                    # any suspicion, but the slot is not ours to re-admit
                    if st.state == SUSPECT:
                        self._move(now, slot, HEALTHY)
                    continue
                reason = _breach(slot, eps)
                if reason is None:
                    if st.state == SUSPECT:
                        self._move(now, slot, HEALTHY)
                    continue
                if st.state == HEALTHY:
                    self._move(now, slot, SUSPECT)
                    continue
                # suspect with the breach still in force
                if now - st.since >= cfg.window_s and n_live > cfg.min_active:
                    st.ref_eps = median  # the bar it must clear to return
                    self._move(now, slot, DEMOTED)
                    n_live -= 1
                    actions.append(PolicyAction("demote", slot, reason))
            else:  # DEMOTED | PROBATION — only slots WE demoted get here
                if not eligible[slot]:
                    continue  # host gone; hold state, never re-admit a ghost
                # when no OTHER eligible slot remains, the median degenerates
                # to this slot's own rate and any pace would pass — hold it
                # to the median it was demoted against instead
                ref = (median if any(i != slot for i in base) else st.ref_eps)
                # a still-divergent loss fails the probe too — pace alone
                # must not re-admit a slot whose trajectory is off the rails
                # (staleness deliberately NOT consulted: a demoted slot's
                # sync age grows by construction)
                diverged = (
                    cfg.loss_div_frac is not None and loss_med > 0.0
                    and loss_by_slot is not None
                    and float(loss_by_slot.get(slot, loss_med))
                    > (1.0 + cfg.loss_div_frac) * loss_med)
                if ref <= 0.0 or eps < cfg.readmit_frac * ref or diverged:
                    if st.state == PROBATION:
                        self._move(now, slot, DEMOTED)
                    continue
                if st.state == DEMOTED:
                    self._move(now, slot, PROBATION)
                    continue
                if now - st.since >= cfg.probation_s:
                    self._move(now, slot, HEALTHY)
                    actions.append(PolicyAction(
                        "readmit", slot,
                        f"probation passed: eps {eps:.0f} >= "
                        f"{cfg.readmit_frac:.2f} x reference median "
                        f"{ref:.0f} for {cfg.probation_s:g}s"))
        return actions


class StragglerSchedule(MembershipSchedule):
    """Adapt a ``StragglerPolicy`` into the deterministic event source
    ``HogwildSim`` already consumes (``events_at(t)``), so closed-loop
    demotion/re-admission is reproducible in the simulator.

    The per-slot rates come from ``rates(t, slot)`` — a scripted trace (the
    sim itself is deterministic, so "slowness" must be declared, exactly
    like ``FaultSpec`` declares crashes). The policy's clock is the
    iteration counter: ``window_s`` / ``probation_s`` are read in
    iterations here.

    Events are generated lazily as the sim asks for each iteration and
    cached, so re-reading an earlier iteration (or ``__iter__``) replays
    rather than re-evaluating.
    """

    def __init__(
        self,
        policy: StragglerPolicy,
        rates: Callable[[int, int], float],
        *,
        start_active: Optional[Sequence[bool]] = None,
        losses: Optional[Callable[[int, int], float]] = None,
        staleness: Optional[Callable[[int, int], float]] = None,
    ):
        super().__init__([])
        self.policy = policy
        self.rates = rates
        # optional quality traces (scripted, like rates): per-slot loss EMA
        # and sync-staleness age feeding the PolicyConfig quality knobs
        self.losses = losses
        self.staleness = staleness
        n = policy.n_slots
        self._active = ([True] * n if start_active is None else [bool(b) for b in start_active])
        if len(self._active) != n:
            raise ValueError(f"start_active has {len(self._active)} slots, " f"policy has {n}")
        self._emitted: Dict[int, List[Tuple[str, int, str]]] = {}
        self._next_t = 0

    def max_slot(self) -> int:
        return self.policy.n_slots - 1

    def events_at(self, t: int) -> List[Tuple[str, int, str]]:
        # evaluate every iteration up to t exactly once (the sim calls with
        # monotonically increasing t; a resumed run skips the gap in one go)
        while self._next_t <= t:
            tt = self._next_t
            self._next_t += 1
            n = self.policy.n_slots
            eps = {s: float(self.rates(tt, s)) for s in range(n)}
            loss_by = (
                {s: float(self.losses(tt, s)) for s in range(n)}
                if self.losses is not None else None)
            stale_by = (
                {s: float(self.staleness(tt, s)) for s in range(n)}
                if self.staleness is not None else None)
            out: List[Tuple[str, int, str]] = []
            for a in self.policy.observe(
                    float(tt), eps, list(self._active),
                    loss_by_slot=loss_by, staleness_by_slot=stale_by):
                kind = "leave" if a.kind == "demote" else "join"
                self._active[a.slot] = a.kind == "readmit"
                out.append((kind, a.slot, a.reason))
            if out:
                self._emitted[tt] = out
        return self._emitted.get(t, [])

    def __iter__(self):
        return iter(
            (t, kind, slot) for t, evs in sorted(self._emitted.items()) for kind, slot, _ in evs
        )

    def __len__(self) -> int:
        return sum(len(v) for v in self._emitted.values())

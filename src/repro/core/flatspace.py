"""Flat replica-space: the persistent packed parameter layout the sync engine
runs on (DESIGN.md §3).

The dense replica pytree is packed ONCE at init into a contiguous
``(R, n_rows, 128)`` fp32 buffer — 128 is the TPU lane width, ``n_rows`` is
padded up to a whole number of kernel blocks — and every background sync
becomes a single fused Pallas launch over that buffer:

* no per-sync ``jax.tree.map`` fan-out over leaves,
* no per-sync concat+pad flatten (the old ``easgd_update/ops._flatten``),
* launch snapshots are one contiguous copy (EASGD) or one replica-mean
  reduction (MA/BMUF — the landing only ever reads the snapshot's mean,
  so the snapshot itself shrinks from R*N to N floats),
* the buffer layout is donation-friendly: the training step consumes and
  re-emits the same contiguous block, so XLA can update it in place.

Packing casts every leaf to fp32 (the sync algorithms do their math in fp32
anyway); unpacking restores each leaf's dtype and shape. The round trip is
lossless for float32/bfloat16/float16 leaves because fp32 is a superset of
both half formats.

Elastic membership (DESIGN.md §8): the replica axis is CAPACITY-padded. A
runner allocates its buffer once at ``(R_max, n_rows, 128)`` — ``R_max`` from
``core.membership.Membership`` — and join/leave/fail only flip bits in the
active-slot mask: no reallocation, no retrace of the training step, and dead
rows cost zero HBM traffic in the fused sync kernels (their ids are simply
absent from the scalar-prefetch row sets).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

LANE = 128  # TPU lane width: last dim of every flat buffer
DEFAULT_BLOCK = 256  # fp32 sublane-aligned rows per kernel grid block


@dataclasses.dataclass(frozen=True)
class FlatSpace:
    """Static description of the packed layout for one replica's pytree.

    Built once from a template pytree (arrays or ShapeDtypeStructs); the
    pack/unpack methods are pure jnp and jit/vmap-friendly, so runners can
    fuse them into their train step while the sync path stays flat.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    total: int  # live parameters per replica
    n_rows: int  # padded rows of LANE floats (multiple of `block`)
    block: int  # kernel grid block height (rows)

    @classmethod
    def from_tree(cls, tree: Pytree, block: int = DEFAULT_BLOCK) -> "FlatSpace":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("FlatSpace needs at least one leaf")
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        packable = {jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)}
        bad = sorted({str(d) for d in dtypes if d not in packable})
        if bad:
            raise TypeError(
                f"FlatSpace packs through fp32, which is lossless only for "
                f"f32/bf16/f16 leaves; got {bad}. Keep integer/f64 state out "
                f"of the dense replica tree.")
        sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
        total = int(sum(sizes))
        n_rows = max(1, -(-total // (LANE * block))) * block
        return cls(treedef, shapes, dtypes, sizes, total, n_rows, block)

    # -- derived ------------------------------------------------------------
    @property
    def slots(self) -> int:
        """fp32 slots per replica row-plane (>= total; tail is zero padding)."""
        return self.n_rows * LANE

    @property
    def n_blocks(self) -> int:
        return self.n_rows // self.block

    def buffer_bytes(self, n_replicas: int) -> int:
        return n_replicas * self.slots * 4

    # -- single replica -----------------------------------------------------
    def pack(self, tree: Pytree) -> jnp.ndarray:
        """Pytree -> contiguous (n_rows, LANE) fp32 plane."""
        leaves = jax.tree_util.tree_leaves(tree)
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        flat = jnp.pad(flat, (0, self.slots - self.total))
        return flat.reshape(self.n_rows, LANE)

    def unpack(self, plane: jnp.ndarray) -> Pytree:
        """(n_rows, LANE) plane -> pytree with original shapes/dtypes."""
        vec = plane.reshape(-1)[: self.total]
        out, off = [], 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            out.append(vec[off : off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -- replica stacks -----------------------------------------------------
    def pack_stack(self, stack: Pytree) -> jnp.ndarray:
        """Pytree with leading replica dim R -> (R, n_rows, LANE) fp32 buffer."""
        leaves = jax.tree_util.tree_leaves(stack)
        R = leaves[0].shape[0]
        flat = jnp.concatenate([l.reshape(R, -1).astype(jnp.float32) for l in leaves], axis=1)
        flat = jnp.pad(flat, ((0, 0), (0, self.slots - self.total)))
        return flat.reshape(R, self.n_rows, LANE)

    def unpack_stack(self, buf: jnp.ndarray) -> Pytree:
        """(R, n_rows, LANE) buffer -> pytree stack with leading replica dim."""
        R = buf.shape[0]
        vec = buf.reshape(R, -1)[:, : self.total]
        out, off = [], 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            out.append(vec[:, off : off + size].reshape((R,) + shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def unpack_replica(self, buf: jnp.ndarray, i: int) -> Pytree:
        return self.unpack(buf[i])

    def broadcast(self, tree: Pytree, n_replicas: int) -> jnp.ndarray:
        """Pack one pytree and replicate it into a fresh (R, n_rows, LANE) buffer."""
        plane = self.pack(tree)
        return jnp.broadcast_to(plane, (n_replicas,) + plane.shape).copy()


# Contiguous launch snapshot: one fused copy of the whole replica buffer
# (vs the old per-leaf jax.tree.map(jnp.copy, ...) fan-out).
snapshot = jax.jit(lambda buf: buf.copy())

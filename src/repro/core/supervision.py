"""Failure-domain supervision: heartbeats, bounded restarts, degradation.

ShadowSync's isolation property (paper §3.3) cuts both ways: because training
never blocks on the sync engine, the sync engine can die and training will
*silently* continue as unsynchronized Hogwild forever. PRs 4-5 made trainer
slots a supervised failure domain (membership + the straggler controller);
this module extends the same closed-loop treatment to the remaining
long-lived threads — the shadow/sync thread, the fixed-rate monitor — and to
any other component that can express "I am alive" as a heartbeat.

``Supervisor`` owns three mechanisms (DESIGN.md §10):

* **Heartbeat registry** — every supervised thread registers under a name and
  beats its heartbeat as it makes progress (a shadow round, a trainer
  iteration). A thread is *failed* when its ``threading.Thread`` object is no
  longer alive, and *stalled* when its heartbeat is older than
  ``heartbeat_deadline_s`` while the thread still nominally runs (e.g. wedged
  inside a blocking call).

* **Restart policy** — a registration may carry a ``restart`` factory. When
  the thread fails or stalls, the supervisor starts a replacement through the
  factory after an exponential backoff (``backoff_s * backoff_factor **
  attempt``), up to ``max_restarts`` attempts. ShadowSync makes this safe for
  the sync thread specifically: training never blocked on it, so a restarted
  shadow thread simply resumes background rounds against the *live*
  membership state. Restart budgets are per-name and never reset — a
  crash-looping component converges to escalation instead of flapping.

* **Degradation ladder** — when the restart budget is exhausted (or the
  registration is watch-only), the supervisor *escalates*: it calls the
  registration's ``on_give_up`` callback exactly once and marks the name
  degraded. The runner's ladder for the sync engine is: keep training
  locally (isolation means nothing breaks), log a ``degraded`` membership
  event with provenance, and force one final foreground sync at shutdown so
  the run still converges to a synchronized model (core/runners.py).

Watch-only registrations (``restart=None``, e.g. trainer threads, whose
state is slot-owned and already supervised by membership + the straggler
policy) get stall/failed *detection* — a ``stall`` event with provenance —
but never a restart.

The watch loop also drives a caller-supplied ``tick`` callback every check
interval. ``ThreadedShadowRunner`` points it at the straggler-policy step, so
the scheduler keeps its clock even while the thread that normally evaluates
it (the shadow thread) is the thing being restarted — the supervisor and the
policy share one clock domain (``time.perf_counter``), which is why
``StragglerPolicy.observe`` is now lock-guarded (core/scheduler.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass(frozen=True)
class SupervisorConfig:
    """Tuning knobs for the supervision loop (DESIGN.md §10.1)."""

    heartbeat_deadline_s: float = 5.0  # stale beyond this => stalled
    check_interval_s: float = 0.02     # watch-loop cadence
    max_restarts: int = 3              # per supervised name, never reset
    backoff_s: float = 0.1             # first restart delay
    backoff_factor: float = 2.0        # exponential growth per attempt

    def validate(self) -> "SupervisorConfig":
        if self.heartbeat_deadline_s <= 0:
            raise ValueError(
                f"heartbeat_deadline_s must be > 0, got " f"{self.heartbeat_deadline_s}"
            )
        if self.check_interval_s <= 0:
            raise ValueError(f"check_interval_s must be > 0, got " f"{self.check_interval_s}")
        if self.max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got " f"{self.max_restarts}")
        if self.backoff_s < 0 or self.backoff_factor < 1.0:
            raise ValueError(
                f"need backoff_s >= 0 and backoff_factor >= 1, "
                f"got backoff_s={self.backoff_s}, "
                f"backoff_factor={self.backoff_factor}"
            )
        return self


@dataclass(frozen=True)
class SupervisionEvent:
    """One supervision decision, with provenance for logs and CI floors.

    ``kind``: ``"stall"`` (heartbeat went stale), ``"death"`` (thread object
    no longer alive), ``"restart"`` (replacement started), ``"degraded"``
    (restart budget exhausted / watch-only give-up). ``t`` is
    ``time.perf_counter`` — the same clock domain the straggler policy and
    the membership event log use."""

    kind: str
    name: str
    t: float
    reason: str = ""


@dataclass
class _Supervised:
    thread: threading.Thread
    restart: Optional[Callable[[], threading.Thread]]
    on_give_up: Optional[Callable[[str], None]]
    last_beat: float = 0.0
    restarts: int = 0
    degraded: bool = False
    # pending failure: time the death/stall was first seen (backoff anchors
    # here); None when the thread is currently believed healthy
    failed_at: Optional[float] = None
    failure_reason: str = ""
    # a stalled-but-alive thread we walked away from: its generation token
    # is bumped so the zombie exits at its next round boundary instead of
    # fighting its replacement
    generation: int = 0


class Supervisor:
    """Heartbeat-driven thread supervision with bounded restarts.

    Thread-safety: ``beat`` is called from the supervised threads, ``register``
    / ``deregister`` from whoever owns them, and the watch loop from the
    supervisor's own thread — all state transitions take ``_lock``. The
    ``restart`` factory and ``on_give_up`` callback are invoked *outside* the
    lock (they start threads / take runner locks of their own).
    """

    def __init__(
        self,
        config: Optional[SupervisorConfig] = None,
        *,
        clock: Callable[[], float] = time.perf_counter,
        tick: Optional[Callable[[], None]] = None,
    ):
        self.config = (config or SupervisorConfig()).validate()
        self.clock = clock
        self.tick = tick
        self._lock = threading.Lock()
        # guarded-by-writes: _lock — registry mutates under _lock; beat() and
        # the name-keyed getters do lock-free dict reads (never iterate)
        self._sup: Dict[str, _Supervised] = {}
        self._stop = threading.Event()
        # hogwild-race: ok — start/stop are caller-serialized lifecycle methods
        self._thread: Optional[threading.Thread] = None
        # hogwild-race: ok — the single watch thread appends; readers snapshot post-run
        self.events: List[SupervisionEvent] = []

    # -- registry ------------------------------------------------------------
    def register(
        self,
        name: str,
        thread: threading.Thread,
        *,
        restart: Optional[Callable[[], threading.Thread]] = None,
        on_give_up: Optional[Callable[[str], None]] = None,
    ) -> None:
        """Supervise ``thread`` under ``name``. ``restart`` (if given) must
        return a NEW started thread continuing the same work; ``on_give_up``
        fires exactly once when the restart budget is exhausted (or, for
        watch-only registrations, on the first failure)."""
        with self._lock:
            if name in self._sup:
                raise ValueError(f"{name!r} is already supervised")
            self._sup[name] = _Supervised(
                thread=thread, restart=restart, on_give_up=on_give_up, last_beat=self.clock()
            )

    def beat(self, name: str) -> None:
        """Record liveness progress for ``name`` (cheap; called per round /
        per iteration from the supervised thread itself)."""
        s = self._sup.get(name)
        if s is not None:
            s.last_beat = self.clock()  # single float store: atomic enough

    def deregister(self, name: str) -> None:
        """Clean exit: the thread finished its work; stop watching it."""
        with self._lock:
            self._sup.pop(name, None)

    def generation(self, name: str) -> int:
        """Current generation token for ``name``. A supervised loop should
        capture its generation at spawn and exit once it is superseded —
        that is how a stalled-but-alive zombie stands down after the
        supervisor has already started its replacement."""
        s = self._sup.get(name)
        return s.generation if s is not None else 0

    def thread(self, name: str) -> Optional[threading.Thread]:
        """The CURRENT thread object for ``name`` (follows restarts)."""
        s = self._sup.get(name)
        return s.thread if s is not None else None

    def is_degraded(self, name: str) -> bool:
        s = self._sup.get(name)
        return bool(s is not None and s.degraded)

    def restarts(self, name: str) -> int:
        s = self._sup.get(name)
        return s.restarts if s is not None else 0

    def degraded_names(self) -> List[str]:
        with self._lock:
            return [n for n, s in self._sup.items() if s.degraded]

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("supervisor already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._watch_loop, name="supervisor", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=timeout)
            self._thread = None

    # -- the watch loop ------------------------------------------------------
    def _watch_loop(self) -> None:
        while not self._stop.wait(self.config.check_interval_s):
            try:
                self.check_once()
            except Exception:  # supervision must outlive a bad callback
                pass
            if self.tick is not None:
                try:
                    self.tick()
                except Exception:
                    pass

    def check_once(self) -> List[SupervisionEvent]:
        """One supervision pass (public for deterministic tests: drive it
        with an injected clock instead of the background loop). Returns the
        events emitted this pass."""
        now = self.clock()
        cfg = self.config
        emitted: List[SupervisionEvent] = []
        to_restart: List[tuple] = []
        to_give_up: List[tuple] = []
        with self._lock:
            for name, s in self._sup.items():
                if s.degraded:
                    continue
                if s.failed_at is None:
                    alive = s.thread.is_alive()
                    stale = now - s.last_beat > cfg.heartbeat_deadline_s
                    if alive and not stale:
                        continue
                    kind = "death" if not alive else "stall"
                    s.failed_at = now
                    s.failure_reason = (
                        f"thread exited" if not alive else
                        f"heartbeat stale {now - s.last_beat:.2f}s > "
                        f"deadline {cfg.heartbeat_deadline_s:g}s")
                    ev = SupervisionEvent(kind, name, now, s.failure_reason)
                    self.events.append(ev)
                    emitted.append(ev)
                    if not alive and s.restart is None:
                        # watch-only + clean-ish death: give up immediately
                        pass
                # pending failure: restart after backoff, or escalate
                if s.restart is not None and s.restarts < cfg.max_restarts:
                    due = s.failed_at + cfg.backoff_s * (cfg.backoff_factor ** s.restarts)
                    if now >= due:
                        s.restarts += 1
                        s.generation += 1  # fence out a stalled zombie
                        to_restart.append((name, s))
                else:
                    s.degraded = True
                    to_give_up.append((name, s))
        for name, s in to_restart:
            new_thread = s.restart()
            with self._lock:
                s.thread = new_thread
                s.failed_at = None
                s.last_beat = self.clock()
            ev = SupervisionEvent(
                "restart",
                name,
                self.clock(),
                f"attempt {s.restarts}/{cfg.max_restarts} after " f"{s.failure_reason}",
            )
            self.events.append(ev)
            emitted.append(ev)
        for name, s in to_give_up:
            ev = SupervisionEvent(
                "degraded", name, self.clock(),
                f"restart budget exhausted "
                f"({s.restarts}/{cfg.max_restarts}) after "
                f"{s.failure_reason}" if s.restart is not None else
                f"watch-only: {s.failure_reason}")
            self.events.append(ev)
            emitted.append(ev)
            if s.on_give_up is not None:
                s.on_give_up(name)
        return emitted

"""SPMD (mesh-level) realization of ShadowSync for the LLM-scale architectures.

ShadowSync mode ("shadow"): dense params carry a leading replica dim R sharded
over the replica axis (``pod``). Each replica group trains independently —
``train_step``'s lowered HLO contains NO collective over the replica axis (a
property tests assert). ``sync_step`` is a SEPARATE compiled program owning all
cross-replica traffic, dispatched by the host shadow thread at its own cadence.

Baseline mode ("syncdp"): classic fully-synchronous data parallelism — gradients
all-reduce over (pod, data) inside every step. This is the foreground strategy
the paper compares against (its cost shows up as per-step collective bytes in the
roofline; cf. FR-EASGD's saturation in Fig 5).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import sync as S
from repro.models import transformer, whisper
from repro.optim import Optimizer

Pytree = Any


def _loss_fn(cfg: ArchConfig, remat_policy: str = "full") -> Callable:
    if cfg.family == "audio":
        return lambda p, b: whisper.loss_fn(p, cfg, b)
    return lambda p, b: transformer.loss_fn(p, cfg, b, remat=True, remat_policy=remat_policy)


def init_params(cfg: ArchConfig, key) -> Pytree:
    if cfg.family == "audio":
        return whisper.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def stack_replicas(params: Pytree, n: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def _accum_grads(
    loss_fn: Callable, params: Pytree, batch: Pytree, n_microbatches: int, grad_dtype=jnp.float32
) -> Tuple[Pytree, jnp.ndarray]:
    """Gradient accumulation: scan over microbatches (batch dim split K-ways) so
    live activations scale with the microbatch, not the global batch. Grads
    accumulate in ``grad_dtype`` (fp32 default; bf16 is a hillclimb option that
    halves grad all-reduce bytes). With K=1 this is a plain value_and_grad."""
    if n_microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, loss
    mb = jax.tree.map(
        lambda x: x.reshape(n_microbatches, x.shape[0] // n_microbatches, *x.shape[1:]),
        batch,
    )
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)

    def body(carry, b):
        acc_g, acc_l = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        acc_g = jax.tree.map(lambda a, g: a + g.astype(grad_dtype), acc_g, grads)
        return (acc_g, acc_l + loss), None

    from repro.models.layers import uscan

    (acc_g, acc_l), _ = uscan(body, (g0, jnp.zeros((), jnp.float32)), mb)
    k = float(n_microbatches)
    return jax.tree.map(lambda g: g / k, acc_g), acc_l / k


def make_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    mode: str,
    n_microbatches: int = 1,
    grad_dtype: str = "float32",
    remat_policy: str = "full",
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, loss).

    mode="shadow": leaves carry a leading replica dim; grads stay replica-local.
    mode="syncdp": plain synchronous DP (grads all-reduce over every batch axis)."""
    loss_fn = _loss_fn(cfg, remat_policy)
    gdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[grad_dtype]

    if mode == "shadow":
        def train_step(params, opt_state, batch):
            def one(p, st, b):
                grads, loss = _accum_grads(loss_fn, p, b, n_microbatches, gdt)
                p2, st2 = opt.update(p, st, grads)
                return p2, st2, loss

            # NOTE: per-replica losses are returned UN-reduced — averaging them
            # on-device would insert a (scalar) cross-pod all-reduce into the
            # training step, breaking the zero-cross-pod-traffic property.
            # Each trainer reports its own loss, exactly as in the paper.
            p2, st2, loss = jax.vmap(one, spmd_axis_name="pod")(params, opt_state, batch)
            return p2, st2, loss

        return train_step

    def train_step(params, opt_state, batch):
        grads, loss = _accum_grads(loss_fn, params, batch, n_microbatches, gdt)
        p2, st2 = opt.update(params, opt_state, grads)
        return p2, st2, loss

    return train_step


def make_sync_step(cfg: ArchConfig, sync_cfg: S.SyncConfig) -> Callable:
    """The background program. Owns ALL cross-replica communication.

    Uniform signature across every registered algorithm:
    ``sync_step(params_stack, algo_state) -> (params_stack, algo_state)``,
    where ``algo_state`` is the opaque state from
    ``algorithms.get(name).init_state(params, sync_cfg)`` (None for the
    stateless ones — jit treats None as an empty pytree, so one compiled
    program shape serves them all)."""
    from repro.core import algorithms

    return algorithms.get(sync_cfg.algo).make_sync_step(sync_cfg)


def make_prefill_step(cfg: ArchConfig, s_max: int) -> Callable:
    if cfg.family == "audio":
        def prefill(params, batch):
            enc_out = whisper.encode(params, cfg, batch["frames"])
            hidden = whisper.decode_full(params, cfg, batch["tokens"], enc_out, return_hidden=True)
            logits = hidden[:, -1, :] @ params["embed"]["table"].T
            cache = whisper.init_cache(cfg, batch["tokens"].shape[0], s_max)
            cross = whisper.build_cross_cache(params, cfg, enc_out)
            return logits, {"self": cache["self"], "cross": cross}

        return prefill

    def prefill(params, batch):
        return transformer.prefill(
            params,
            cfg,
            batch["tokens"],
            s_max,
            prefix_embeds=batch.get("prefix_embeds"),
        )

    return prefill


def make_decode_step(cfg: ArchConfig) -> Callable:
    if cfg.family == "audio":
        def decode(params, cache, token, pos):
            return whisper.decode_step(params, cfg, cache, token, pos)

        return decode

    def decode(params, cache, token, pos):
        return transformer.decode_step(params, cfg, cache, token, pos)

    return decode


def init_cache(cfg: ArchConfig, batch: int, s_max: int) -> Pytree:
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, s_max)
    return transformer.init_cache(cfg, batch, s_max)

"""Elastic replica membership: join/leave/fail as a first-class runtime concept.

ShadowSync's central systems claim is that decoupling synchronization from
training buys robustness and elasticity (paper §1, §3.3): a slow or dead
trainer cannot block the others, and capacity can change mid-run. This module
is the one place that truth lives:

* ``Membership`` — a thread-safe replica slot table with capacity ``R_max``,
  a per-slot status (``active | joining | dead``), a monotonically increasing
  epoch (bumped on every transition), and an event log. Every layer of the
  sync stack consumes it instead of a frozen ``R``:

  - ``FlatSpace`` buffers are allocated capacity-padded at ``(R_max, n_rows,
    128)`` once; join/leave/fail never reallocate or retrace — only the
    active mask changes (DESIGN.md §8).
  - The fused sync kernels take the active row set via scalar prefetch, so a
    dead slot costs zero HBM traffic; MA/BMUF means divide by the LIVE
    count; gossip's rotating matching is drawn over the active set only.
  - ``SyncAlgorithm.on_join`` / ``on_leave`` bootstrap/drop replicas through
    the registry, so every algorithm gets elasticity for free.
  - ``ThreadedShadowRunner``'s shadow thread reads membership each round and
    simply skips dead slots — training never blocks on a crash.

* ``MembershipSchedule`` — a deterministic (iteration, event, slot) script
  for reproducible elasticity experiments in ``HogwildSim``.

* ``FaultSpec`` — the ThreadedShadowRunner fault-injection harness config:
  per-slot straggler slowdown, crash-at-iteration, join-at-iteration, plus
  the PR-6 chaos domains — sync-thread crash/stall rounds, trainer
  exceptions, and PS-shard loss (DESIGN.md §10).

Transitions (anything else raises ``ValueError``):

    dead --join--> joining --activate--> active --fail/leave--> dead
                   joining --fail-----------------------------> dead

Besides slot transitions, the event log also carries *annotations*
(``Membership.note``): non-transition events from the other failure domains
— ``degraded`` (the supervisor exhausted the sync engine's restart budget),
``sync_restart``, ``ps_fail`` / ``ps_recover`` (``slot`` is the SHARD id
there) — so one log tells the whole robustness story with provenance.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

DEAD = 0
ACTIVE = 1
JOINING = 2

_STATUS_NAMES = {DEAD: "dead", ACTIVE: "active", JOINING: "joining"}


@dataclass(frozen=True)
class MembershipEvent:
    """One transition, as recorded in ``Membership.events``.

    ``reason`` is provenance: "" for legacy/injected transitions, a
    human-readable cause for controller decisions (e.g. the straggler
    policy's demotion evidence — core/scheduler.py). ``t`` is the wall
    timestamp of the transition (``time.perf_counter`` domain; diagnostics
    only — deterministic consumers compare ``(kind, slot)``)."""

    # transitions: "join" | "activate" | "leave" | "fail"
    # annotations (Membership.note — no status change, no epoch bump):
    # "degraded" | "sync_restart" | "ps_fail" | "ps_recover" (slot = shard)
    kind: str
    slot: int
    epoch: int  # epoch AFTER the transition (unchanged for annotations)
    reason: str = ""
    t: float = 0.0


class Membership:
    """Thread-safe replica slot table (capacity ``R_max``).

    Slots ``[0, n_active)`` start active; the rest start dead (spare
    capacity). All reads return copies — callers never see a mask mutate
    under them mid-round.
    """

    def __init__(self, n_active: int, R_max: Optional[int] = None):
        if R_max is None:
            R_max = n_active
        if not 0 < n_active <= R_max:
            raise ValueError(
                f"need 0 < n_active <= R_max, " f"got n_active={n_active}, R_max={R_max}"
            )
        self.R_max = int(R_max)
        self._status = np.full((self.R_max,), DEAD, np.int8)  # guarded-by: _lock
        self._status[:n_active] = ACTIVE
        self._epoch = 0  # guarded-by: _lock
        self._lock = threading.Lock()
        # guarded-by-writes: _lock — appends serialized; readers take len()
        # prefixes of an append-only list, which is safe under the GIL.
        self.events: List[MembershipEvent] = []

    @classmethod
    def from_mask(cls, active: Sequence[bool]) -> "Membership":
        """Arbitrary initial pattern (e.g. spare slots interleaved with the
        initial cohort, as a join_at fault schedule produces)."""
        active = np.asarray(active, bool)
        if not active.any():
            raise ValueError("need at least one initially active slot")
        m = cls(1, R_max=len(active))
        m._status[:] = np.where(active, ACTIVE, DEAD)
        return m

    # -- reads ---------------------------------------------------------------
    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def status(self, slot: int) -> str:
        with self._lock:
            return _STATUS_NAMES[int(self._status[slot])]

    def active_mask(self) -> np.ndarray:
        """(R_max,) bool copy — slots currently training AND syncing."""
        with self._lock:
            return self._status == ACTIVE

    def active_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active_mask())

    @property
    def n_active(self) -> int:
        return int(self.active_mask().sum())

    def snapshot(self) -> Tuple[int, np.ndarray]:
        """(epoch, active_mask) read atomically — what a shadow round pins."""
        with self._lock:
            return self._epoch, self._status == ACTIVE

    # -- transitions ---------------------------------------------------------
    def _transition(
        self, slot: int, allowed: Iterable[int], to: int, kind: str, reason: str = ""
    ) -> MembershipEvent:
        if not 0 <= slot < self.R_max:
            raise ValueError(f"slot {slot} out of range [0, {self.R_max})")
        with self._lock:
            cur = int(self._status[slot])
            if cur not in allowed:
                raise ValueError(
                    f"cannot {kind} slot {slot}: status is "
                    f"{_STATUS_NAMES[cur]!r} (need "
                    f"{[_STATUS_NAMES[a] for a in allowed]})")
            self._status[slot] = to
            self._epoch += 1
            ev = MembershipEvent(kind, slot, self._epoch, reason, time.perf_counter())
            self.events.append(ev)
            return ev

    def join(self, slot: int, reason: str = "") -> MembershipEvent:
        """dead -> joining: the slot is being bootstrapped (``on_join``)."""
        return self._transition(slot, (DEAD,), JOINING, "join", reason)

    def activate(self, slot: int, reason: str = "") -> MembershipEvent:
        """joining -> active: bootstrap finished; the slot trains and syncs."""
        return self._transition(slot, (JOINING,), ACTIVE, "activate", reason)

    def leave(self, slot: int, reason: str = "") -> MembershipEvent:
        """active -> dead: planned departure (capacity scale-down or a
        straggler demotion — ``reason`` records which)."""
        return self._transition(slot, (ACTIVE,), DEAD, "leave", reason)

    def fail(self, slot: int, reason: str = "") -> MembershipEvent:
        """active|joining -> dead: crash. The sync stack just stops reading
        the slot; nothing blocks, nothing reallocates."""
        return self._transition(slot, (ACTIVE, JOINING), DEAD, "fail", reason)

    def note(self, kind: str, slot: int = -1, reason: str = "") -> MembershipEvent:
        """Append a non-transition annotation to the event log: provenance
        from the OTHER failure domains (sync-engine degradation, PS-shard
        loss/recovery) so one log tells the whole robustness story. No
        status changes, no epoch bump; ``slot`` is -1 for cohort-level
        events and the shard id for ``ps_*`` events."""
        with self._lock:
            ev = MembershipEvent(kind, slot, self._epoch, reason, time.perf_counter())
            self.events.append(ev)
            return ev

    def __repr__(self) -> str:
        with self._lock:
            s = "".join({DEAD: ".", ACTIVE: "A", JOINING: "j"}[int(x)] for x in self._status)
            return f"Membership(R_max={self.R_max}, epoch={self._epoch}, [{s}])"


# ---------------------------------------------------------------------------
# Deterministic schedule (HogwildSim) and fault harness (ThreadedShadowRunner)
# ---------------------------------------------------------------------------

_SCHEDULE_KINDS = ("fail", "leave", "join")


class MembershipSchedule:
    """Deterministic (iteration, kind, slot) script for HogwildSim.

    Events fire at the START of the named iteration, before that iteration's
    training step, in the order given. Example::

        MembershipSchedule([(6, "fail", 2), (10, "join", 2)])
    """

    def __init__(self, events: Sequence[Tuple[int, str, int]]):
        for t, kind, slot in events:
            if kind not in _SCHEDULE_KINDS:
                raise ValueError(
                    f"unknown schedule event kind {kind!r}; " f"one of {_SCHEDULE_KINDS}"
                )
            if t < 0 or slot < 0:
                raise ValueError(f"bad schedule entry {(t, kind, slot)}")
        self._events = sorted(events, key=lambda e: e[0])

    def max_slot(self) -> int:
        return max((s for _, _, s in self._events), default=-1)

    def events_at(self, t: int) -> List[Tuple[str, int]]:
        return [(kind, slot) for tt, kind, slot in self._events if tt == t]

    def __iter__(self):
        return iter(self._events)

    def __len__(self) -> int:
        return len(self._events)


@dataclass(frozen=True)
class FaultSpec:
    """ThreadedShadowRunner fault-injection harness (DESIGN.md §8.4).

    * ``straggler_sleep_s[slot]`` — extra seconds slept per iteration: a
      degraded host. In ``mode="shadow"`` only that trainer slows down; in
      ``mode="fixed_rate"`` every trainer blocks at the sync barrier until
      the straggler arrives — the paper's Fig-5 contrast, restated as fault
      tolerance.
    * ``straggler_until[slot]`` — the straggler sleep applies only while the
      slot's LOCAL iteration is below this bound (a transient degradation —
      e.g. a co-tenant burst — that ends; absent means degraded for the whole
      run). This is what the closed-loop controller's re-admission story
      exercises: demote while degraded, re-admit once the pace recovers.
    * ``crash_at[slot]`` — the trainer dies (thread exits, membership
      ``fail``) when it reaches this local iteration.
    * ``join_at[slot]`` — the slot starts dead and joins (bootstrap via
      ``SyncAlgorithm.on_join``) once the initial cohort's fastest trainer
      has passed this iteration.
    * ``raise_at[slot]`` — the trainer RAISES (an injected software bug, not
      a clean simulated death) at this local iteration; exercises the
      runner's exception capture + re-raise-with-provenance path.
    * ``sync_crash_at`` — the shadow/sync thread dies (raises) at the start
      of this background ROUND (cumulative across restarts; injected once).
      The supervisor must detect the death and restart the thread against
      live membership (DESIGN.md §10.2).
    * ``sync_stall_at`` / ``sync_stall_s`` — the shadow thread STALLS (sleeps
      ``sync_stall_s`` without dying) at this round; the supervisor detects
      the stale heartbeat, fences the zombie out by generation, and starts a
      replacement.
    * ``ps_fail_at[shard]`` — embedding PS ``shard`` fails (live state lost)
      once cohort progress reaches this iteration; lookups fall back to the
      background snapshot, updates retry-then-drop (embeddings/shards.py).
    * ``ps_recover_after_s`` — seconds after a PS failure at which the
      supervisor rehydrates the shard from its snapshot (a replacement host
      coming up). Shards still down at shutdown are always rehydrated so the
      final state includes every shard.
    """

    straggler_sleep_s: Dict[int, float] = field(default_factory=dict)
    straggler_until: Dict[int, int] = field(default_factory=dict)
    crash_at: Dict[int, int] = field(default_factory=dict)
    join_at: Dict[int, int] = field(default_factory=dict)
    raise_at: Dict[int, int] = field(default_factory=dict)
    sync_crash_at: Optional[int] = None
    sync_stall_at: Optional[int] = None
    sync_stall_s: float = 10.0
    ps_fail_at: Dict[int, int] = field(default_factory=dict)
    ps_recover_after_s: float = 0.25

    def validate(self, R_max: int) -> "FaultSpec":
        for slot in self.straggler_until:
            if slot not in self.straggler_sleep_s:
                raise ValueError(
                    f"straggler_until names slot {slot} but "
                    f"straggler_sleep_s does not degrade it")
        for name, d in (
            ("straggler_sleep_s", self.straggler_sleep_s),
            ("straggler_until", self.straggler_until),
            ("crash_at", self.crash_at),
            ("join_at", self.join_at),
            ("raise_at", self.raise_at),
        ):
            for slot in d:
                if not 0 <= slot < R_max:
                    raise ValueError(f"{name} slot {slot} out of range " f"[0, {R_max})")
        for name, v in (
            ("sync_crash_at", self.sync_crash_at), ("sync_stall_at", self.sync_stall_at)
        ):
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")
        if self.sync_stall_s <= 0:
            raise ValueError(f"sync_stall_s must be > 0, got " f"{self.sync_stall_s}")
        if self.ps_recover_after_s < 0:
            raise ValueError(f"ps_recover_after_s must be >= 0, got " f"{self.ps_recover_after_s}")
        for shard, it in self.ps_fail_at.items():
            if shard < 0 or it < 0:
                raise ValueError(
                    f"bad ps_fail_at entry {shard}:{it} "
                    f"(shard and iteration must be >= 0; the "
                    f"runner validates shard ids against its "
                    f"plan)"
                )
        return self

"""Tuning-free sync<->async mode switching from live cohort dispersion.

PR 5 closed the *membership* loop (demote/re-admit one straggler); this
module closes the *mode* loop: GBA (PAPERS.md) shows the production-scale
lever is switching the WHOLE cohort's training mode at runtime from observed
heterogeneity. A homogeneous cohort gets ``fixed_rate`` (foreground barrier
— best trajectory quality); a skewed one gets ``shadow`` (background sync —
best throughput, nobody drags anybody). The operator no longer picks a mode
up front; the run earns it from its own meters.

``ModeController`` is a deterministic two-state machine over dispersion
observations (DESIGN.md §14):

    fixed_rate --dispersion >= skew_high persists window_s--> shadow
    shadow     --dispersion <= skew_low  persists window_s--> fixed_rate

* Dispersion: how far the cohort's busy-EPS spread stretches past the live
  median — ``max(max/median, median/min)`` over slots with signal, so one
  slow outlier (the usual trigger: median/min blows up) and one fast
  outlier both register. 1.0 == perfectly homogeneous.
* Hysteresis: ``skew_high > skew_low``, so a cohort hovering between the
  bands parks in its current mode instead of flapping; a breach must
  persist a full ``window_s`` (two observations minimum — a single spike
  is never acted on).
* Min-dwell: after any switch the controller holds the new mode for
  ``min_dwell_s`` regardless of the signal — a mode switch costs a
  barrier drain or a catch-up sync, so it must never oscillate at the
  observation rate.
* Quality: the caller may fold in a ``quality_skew`` (per-slot loss-EMA
  divergence vs the cohort median — the PR 5 follow-on signals); the
  controller judges the max of pace and quality skew, so a replica whose
  trajectory diverges pushes toward shadow even at healthy pace.

The controller is runtime-agnostic, exactly like ``StragglerPolicy``:
``ThreadedShadowRunner`` feeds it real busy-EPS dispersion each shadow
round (wall-clock domain); ``ControllerModeSchedule`` adapts it into a
deterministic per-iteration mode trace for ``HogwildSim`` (iteration-clock
domain), where the per-slot rates come from a scripted trace — same state
machine, reproducible trajectories. ``observe`` is lock-guarded: in the
threaded runner both the shadow thread and the supervisor's backup tick may
evaluate it concurrently, and a transition must never fire twice against
one observation window.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.elp import median_eps

MODES = ("shadow", "fixed_rate")


@dataclass(frozen=True)
class ModeConfig:
    """Tuning knobs for ``ModeController`` (defaults favor stability;
    benchmarks/elastic_bench.py uses a snappier profile)."""

    skew_high: float = 2.0    # fixed_rate -> shadow above this dispersion
    skew_low: float = 1.3     # shadow -> fixed_rate at/below this
    window_s: float = 1.0     # breach must persist this long to switch
    min_dwell_s: float = 2.0  # hold a freshly entered mode at least this long
    start_mode: str = "fixed_rate"

    def validate(self) -> "ModeConfig":
        if self.start_mode not in MODES:
            raise ValueError(f"start_mode must be one of {MODES}, got {self.start_mode!r}")
        if not self.skew_low >= 1.0:
            raise ValueError(
                f"skew_low must be >= 1.0 (dispersion of a homogeneous "
                f"cohort), got {self.skew_low}")
        if self.skew_high <= self.skew_low:
            raise ValueError(
                f"skew_high ({self.skew_high}) must be > skew_low "
                f"({self.skew_low}) — the hysteresis band is what stops a "
                f"borderline cohort from flapping between modes")
        if self.window_s <= 0 or self.min_dwell_s < 0:
            raise ValueError(
                f"need window_s > 0 and min_dwell_s >= 0, got "
                f"window_s={self.window_s}, min_dwell_s={self.min_dwell_s}")
        return self


@dataclass(frozen=True)
class ModeDecision:
    """One controller decision, with provenance for the membership log."""

    target: str  # the mode to enter
    reason: str


class ModeController:
    """Dispersion-driven mode controller. Feed it skew observations via
    ``observe``; it returns the mode switch to apply (or None).

    Deterministic: decisions depend only on the observation sequence (no
    internal clocks — ``now`` is caller-supplied, wall seconds in the
    threaded runner, the iteration counter in ``ControllerModeSchedule``).
    """

    def __init__(self, config: Optional[ModeConfig] = None):
        self.config = (config or ModeConfig()).validate()
        # guarded-by-writes: _lock — moves under _lock on a switch decision;
        # lock-free reads (the trainers' per-iteration mode check) see a
        # coherent latest mode
        self._mode = self.config.start_mode
        self._mode_since: Optional[float] = None  # guarded-by: _lock
        self._breach_since: Optional[float] = None  # guarded-by: _lock
        # (now, from_mode, to_mode, reason) — observability + tests
        self.transitions: List[Tuple[float, str, str, str]] = []  # guarded-by-writes: _lock
        # observe() may be called from two threads (the shadow round AND the
        # supervisor's backup tick while the shadow thread is restarting)
        self._lock = threading.Lock()

    @property
    def mode(self) -> str:
        return self._mode

    @staticmethod
    def dispersion(
        eps_by_slot: Mapping[int, float],
        active: Sequence[bool],
        eligible: Optional[Sequence[bool]] = None,
    ) -> float:
        """Cohort pace spread: ``max(max/median, median/min)`` busy-EPS over
        the live slots with signal. Returns 0.0 (no signal — never act
        blind) with fewer than two measurable slots."""
        n = len(active)
        if eligible is None:
            eligible = [True] * n
        vals = [
            float(eps_by_slot.get(i, 0.0))
            for i in range(n)
            if active[i] and eligible[i] and eps_by_slot.get(i, 0.0) > 0.0
        ]
        if len(vals) < 2:
            return 0.0
        med = median_eps(vals)
        if med <= 0.0:
            return 0.0
        return max(max(vals) / med, med / min(vals))

    def observe(
        self, now: float, dispersion: float, quality_skew: float = 0.0
    ) -> Optional[ModeDecision]:
        """One controller round over the current skew reading. Returns the
        switch to apply, or None. The caller applies the handoff (barrier
        drain / catch-up sync) — the controller only decides."""
        with self._lock:
            return self._observe_locked(now, float(dispersion), float(quality_skew))

    # holds-lock: _lock
    def _observe_locked(
        self, now: float, dispersion: float, quality_skew: float
    ) -> Optional[ModeDecision]:
        cfg = self.config
        if self._mode_since is None:
            self._mode_since = now  # dwell clock starts at first observation
        if dispersion <= 0.0:
            self._breach_since = None
            return None  # no signal yet (startup) — never act blind
        skew = max(dispersion, quality_skew)
        if self._mode == "fixed_rate":
            breach, target = skew >= cfg.skew_high, "shadow"
            why = (f"dispersion {skew:.2f} >= skew_high {cfg.skew_high:g} "
                   f"for {cfg.window_s:g}s: cohort skewed, barrier would "
                   f"drag everyone to the straggler's pace")
        else:
            breach, target = skew <= cfg.skew_low, "fixed_rate"
            why = (f"dispersion {skew:.2f} <= skew_low {cfg.skew_low:g} "
                   f"for {cfg.window_s:g}s: cohort homogeneous, foreground "
                   f"sync buys quality at no throughput cost")
        if not breach:
            # healthy for the current mode, or parked between the bands:
            # either way the breach streak is broken
            self._breach_since = None
            return None
        if self._breach_since is None:
            self._breach_since = now
            return None
        if now - self._breach_since < cfg.window_s:
            return None
        if now - self._mode_since < cfg.min_dwell_s:
            return None  # breach persists but the dwell holds — keep parking
        self.transitions.append((now, self._mode, target, why))
        self._mode = target
        self._mode_since = now
        self._breach_since = None
        return ModeDecision(target, why)


class ModeSchedule:
    """A scripted, deterministic per-iteration mode trace for ``HogwildSim``:
    ``[(iteration, mode), ...]`` switch points, evaluated on the iteration
    clock. Iterations before the first switch point run ``start_mode``."""

    def __init__(
        self,
        events: Sequence[Tuple[int, str]],
        *,
        start_mode: str = "shadow",
    ):
        if start_mode not in MODES:
            raise ValueError(f"start_mode must be one of {MODES}, got {start_mode!r}")
        evs = sorted((int(t), str(m)) for t, m in events)
        for t, m in evs:
            if m not in MODES:
                raise ValueError(f"mode schedule names unknown mode {m!r} at iteration {t}")
        self._events = evs
        self.start_mode = start_mode

    def mode_at(self, t: int) -> str:
        mode = self.start_mode
        for tt, m in self._events:
            if tt > t:
                break
            mode = m
        return mode

    def switch_points(self) -> List[Tuple[int, str]]:
        return list(self._events)


class ControllerModeSchedule(ModeSchedule):
    """Adapt a ``ModeController`` into the deterministic mode trace
    ``HogwildSim`` consumes (``mode_at(t)``), so closed-loop mode switching
    is reproducible in the simulator.

    The per-slot rates come from ``rates(t, slot)`` — a scripted trace
    (the sim is deterministic, so "slowness" must be declared, exactly like
    ``StragglerSchedule``). The controller's clock is the iteration
    counter: ``window_s`` / ``min_dwell_s`` are read in iterations here.
    An optional ``quality(t, slot)`` trace feeds the loss-divergence side
    of the decision the same way.

    Modes are evaluated lazily as the sim asks for each iteration and
    cached, so re-reading an earlier iteration replays rather than
    re-evaluating — two runs over the same schedule object (or two fresh
    objects with the same inputs) produce identical trajectories.
    """

    def __init__(
        self,
        controller: ModeController,
        rates: Callable[[int, int], float],
        n_slots: int,
        *,
        quality: Optional[Callable[[int, int], float]] = None,
    ):
        super().__init__([], start_mode=controller.mode)
        if n_slots < 1:
            raise ValueError(f"need n_slots >= 1, got {n_slots}")
        self.controller = controller
        self.rates = rates
        self.quality = quality
        self.n_slots = int(n_slots)
        self._mode_by_t: Dict[int, str] = {}
        self._next_t = 0

    def mode_at(self, t: int) -> str:
        # evaluate every iteration up to t exactly once (the sim calls with
        # monotonically increasing t; a resumed run skips the gap in one go)
        while self._next_t <= t:
            tt = self._next_t
            self._next_t += 1
            eps = {s: float(self.rates(tt, s)) for s in range(self.n_slots)}
            disp = ModeController.dispersion(eps, [True] * self.n_slots)
            q = 0.0
            if self.quality is not None:
                lv = [float(self.quality(tt, s)) for s in range(self.n_slots)]
                vals = [v for v in lv if v > 0.0]
                if len(vals) >= 2:
                    med = median_eps(vals)
                    if med > 0.0:
                        q = max(vals) / med
            dec = self.controller.observe(float(tt), disp, quality_skew=q)
            if dec is not None:
                self._events.append((tt, dec.target))
            self._mode_by_t[tt] = self.controller.mode
        return self._mode_by_t[t]

"""Pluggable sync-algorithm API: one registry powering all three substrates.

The paper's framework claim is that ShadowSync is "generic to host various
types of synchronization algorithms". This module makes that claim an API:
a ``SyncAlgorithm`` bundles an algorithm's full lifecycle for BOTH sync
engines plus its analytic cost model, and a global registry
(``register`` / ``get`` / ``names``) is the ONLY dispatch point — the
runners (`core/runners.py`), the SPMD sync step (`core/spmd.py`), the
launcher (`launch/train.py`), and the benchmark (`benchmarks/sync_bench.py`)
are all algorithm-agnostic. Adding an algorithm is one registry entry; it
immediately runs in HogwildSim (flat + pytree), ThreadedShadowRunner, the
SPMD sync_step, and the sync benchmark. See DESIGN.md §6.

Lifecycle hooks (state is OPAQUE to every caller — ``SimState.algo_state``):

* ``init_state(w0, cfg)`` / ``init_state_flat(plane0, cfg, fs)`` — per-run
  algorithm state (EASGD: the sync-PS copy; BMUF: global model + block
  momentum; gossip: the round counter; MA: None).
* ``land(stack, state, snap, mask, cfg)`` — the pytree oracle: pure,
  jit-friendly math over replica stacks (leading dim R). ``snap`` is the
  launch snapshot (None: sync against the current stack), ``mask`` the
  fired-replica mask (None: all). Algorithms are free to ignore ``mask``
  (the decentralized mean algorithms treat every landing as global).
* ``launch_snapshot_flat(buf, mask, cfg, fs)`` / ``land_flat(...)`` — the
  flat-engine path: host-level hooks that dispatch the fused Pallas kernels
  (`kernels/{easgd,ma,bmuf,gossip}_update`). The base class provides a
  correct (unfused) fallback that routes through the pytree oracle, so a
  new algorithm only NEEDS the oracle; fused kernels are an override.
* ``make_shadow_round(cfg, fs)`` — builds the ThreadedShadowRunner's
  background round: a host callable mutating the per-trainer planes/pytrees
  in place while trainer threads keep moving (Algorithm 1).
* ``make_sync_step(cfg)`` — the SPMD background program: a pure jittable
  ``(params_stack, algo_state) -> (params_stack, algo_state)`` owning all
  cross-replica traffic.
* ``pytree_sync_bytes`` / ``flat_sync_bytes`` / ``min_stream_ratio`` /
  ``flat_ref_fns`` — the analytic HBM-stream model and CPU-timeable oracle
  callables consumed by ``benchmarks/sync_bench.py``.

Elastic membership (DESIGN.md §8): every hook that lands or launches a sync
accepts an ``active`` mask (host numpy, from ``core.membership.Membership``)
and two lifecycle hooks dispatch through the registry so all algorithms get
elasticity for free:

* ``on_join`` / ``on_join_flat`` — bootstrap a joining replica slot from the
  live cohort (default: the live replica mean; EASGD: the sync-PS copy).
* ``on_leave`` / ``on_leave_flat`` — drop a departing slot from algorithm
  state (default: nothing to drop — no built-in keeps per-replica state).
* ``land_elastic`` — the membership-aware pytree landing: the mean built-ins
  divide by the LIVE count and skip dead slots; gossip draws its rotating
  matching over the active set only; the generic default intersects the
  fired mask with ``active`` and delegates to ``land``.

On the flat engine the active row ids flow into the fused kernels via scalar
prefetch, so dead slots contribute zero HBM traffic at launch and landing.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatspace
from repro.core import sync as S
from repro.core.flatspace import LANE, FlatSpace
from repro.kernels.bmuf_update import ops as bmuf_ops
from repro.kernels.bmuf_update.ref import bmuf_update_ref
from repro.kernels.easgd_update import ops as easgd_ops
from repro.kernels.easgd_update.ref import easgd_round_ref
from repro.kernels.gossip_update import ops as gossip_ops
from repro.kernels.ma_update import ops as ma_ops
from repro.kernels.ma_update.ref import ma_update_ref, replica_mean_ref

Pytree = Any

_gather = jax.jit(lambda buf, idx: buf[idx])


def _fired_ids(mask, R: int) -> np.ndarray:
    return np.arange(R) if mask is None else np.flatnonzero(np.asarray(mask))


def _intersect(mask, active):
    """Host-level AND of two optional (R,) bool masks (None == all-true)."""
    if active is None:
        return mask
    if mask is None:
        return np.asarray(active, bool)
    return np.asarray(mask, bool) & np.asarray(active, bool)


def _active_rows(active) -> jnp.ndarray:
    """(A,) int32 live row ids for the scalar-prefetch kernels."""
    return jnp.asarray(np.flatnonzero(np.asarray(active)), jnp.int32)


@functools.lru_cache(maxsize=None)
def _land_jit(algo: "SyncAlgorithm", cfg) -> Callable:
    """Cached jit of an algorithm's pytree oracle (mask traced)."""
    return jax.jit(lambda stack, state, snap, mask: algo.land(stack, state, snap, mask, cfg))


def _stack_planes(ws: List[jnp.ndarray]) -> jnp.ndarray:
    return jnp.stack(ws)


def _stack_trees(ws: List[Pytree]) -> Pytree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ws)


# ---------------------------------------------------------------------------
# Strategy protocol
# ---------------------------------------------------------------------------

class SyncAlgorithm:
    """Base strategy. Subclasses MUST implement ``land`` (the pytree oracle)
    and set ``name``; everything else has a correct generic default, so a
    one-method algorithm runs end-to-end on every substrate. Built-ins
    override the flat hooks with their fused Pallas kernels."""

    name: str = ""
    centralized: bool = False
    # what launch_snapshot_flat produces: "copy" | "gather" | "mean"
    snapshot_kind: str = "copy"
    # floor asserted by sync_bench on pytree_sync_bytes / flat_sync_bytes
    min_stream_ratio: float = 1.0

    # -- pytree engine (the numerical oracle; also the SPMD substrate) -------
    def init_state(self, w0: Pytree, cfg: "S.SyncConfig") -> Any:
        return None

    def land(
        self,
        stack: Pytree,
        state: Any,
        snap: Optional[Pytree],
        mask: Optional[jnp.ndarray],
        cfg: "S.SyncConfig",
    ) -> Tuple[Pytree, Any]:
        raise NotImplementedError

    def land_elastic(
        self,
        stack: Pytree,
        state: Any,
        snap: Optional[Pytree],
        mask,
        active,
        cfg: "S.SyncConfig",
        launch_active=None,
    ) -> Tuple[Pytree, Any]:
        """Membership-aware pytree landing (host-level hook, not jitted).

        ``mask`` is the fired mask, ``active`` the CURRENT membership mask,
        ``launch_active`` the membership mask when the sync launched (both
        host numpy or None; None == all slots). Default: intersect fired with
        both masks and delegate to the jitted ``land`` oracle — correct for
        algorithms that respect ``mask``. The mean built-ins override this to
        divide by the live count and land only on live rows; gossip draws its
        matching over the launch-time active set.
        """
        eff = _intersect(_intersect(mask, launch_active), active)
        eff_arr = None if eff is None else jnp.asarray(eff)
        return _land_jit(self, cfg)(stack, state, snap, eff_arr)

    # -- elastic membership lifecycle (DESIGN.md §8) --------------------------
    def on_join(
        self, stack: Pytree, slot: int, state: Any, active, cfg: "S.SyncConfig"
    ) -> Tuple[Pytree, Any]:
        """Bootstrap a joining replica slot from the live cohort (pytree
        engine). ``active`` is the membership mask BEFORE the join — the new
        slot is not yet in it. Default: the live replica mean."""
        mean = S.masked_replica_mean(stack, jnp.asarray(active))
        return S.tree_set(stack, slot, mean), state

    def on_join_flat(
        self, buf: jnp.ndarray, slot: int, state: Any, active, cfg: "S.SyncConfig", fs: FlatSpace
    ) -> Tuple[jnp.ndarray, Any]:
        """Flat-engine join bootstrap. Default: fused live-mean kernel into
        the joining slot's plane — one launch, dead rows never streamed."""
        mean = ma_ops.replica_mean_rows_op(buf, _active_rows(active), block=fs.block)
        return buf.at[slot].set(mean), state

    def on_leave(self, state: Any, slot: int, cfg: "S.SyncConfig") -> Any:
        """Drop a departing/failed slot from algorithm state. No built-in
        keeps per-replica state, so the default keeps ``state`` unchanged;
        algorithms that shard state by replica must override."""
        return state

    def on_leave_flat(self, state: Any, slot: int, cfg: "S.SyncConfig", fs: FlatSpace) -> Any:
        return self.on_leave(state, slot, cfg)

    # -- flat engine ----------------------------------------------------------
    def init_state_flat(self, plane0: jnp.ndarray, cfg: "S.SyncConfig", fs: FlatSpace) -> Any:
        return self.init_state(fs.unpack(plane0), cfg)

    def launch_snapshot_flat(
        self,
        buf: jnp.ndarray,
        mask,
        cfg: "S.SyncConfig",
        fs: FlatSpace,
        state: Any = None,
        active=None,
    ) -> jnp.ndarray:
        """Fallback: one contiguous copy of the whole replica buffer.
        ``state`` is the algorithm's opaque state at launch time (gossip uses
        it to pick the round's participant rows); ``active`` the membership
        mask at launch."""
        return flatspace.snapshot(buf)

    def land_flat(
        self,
        buf: jnp.ndarray,
        state: Any,
        snap,
        mask,
        cfg: "S.SyncConfig",
        fs: FlatSpace,
        active=None,
    ) -> Tuple[jnp.ndarray, Any]:
        """Fallback: unpack -> pytree oracle -> repack, inside one jit."""
        if active is None:
            fn = _flat_fallback(self, cfg, fs)
            mask_arr = None if mask is None else jnp.asarray(mask)
            return fn(buf, state, snap, mask_arr)
        # elastic fallback: route through the membership-aware pytree hook
        # (host-level; fused-kernel algorithms override for zero dead-slot
        # traffic)
        stack = fs.unpack_stack(buf)
        snap_t = fs.unpack_stack(snap) if snap is not None else None
        new, state = self.land_elastic(stack, state, snap_t, mask, active, cfg)
        return fs.pack_stack(new), state

    # -- ThreadedShadowRunner background round --------------------------------
    def make_shadow_round(
        self, cfg: "S.SyncConfig", fs: Optional[FlatSpace]
    ) -> Callable[[List, Any], Tuple[Any, int]]:
        """Returns round(ws, state) -> (state, n_syncs); mutates ``ws`` (the
        per-trainer planes or pytrees) in place. Fallback: stack, land against
        the current state (no snapshot — the threaded shadow reads live), and
        slice back."""
        if fs is not None:
            def rnd(ws, state):
                buf, state = self.land_flat(_stack_planes(ws), state, None, None, cfg, fs)
                for i in range(len(ws)):
                    ws[i] = buf[i]
                return state, 1
        else:
            land = jax.jit(lambda stack, st_: self.land(stack, st_, None, None, cfg))

            def rnd(ws, state):
                new, state = land(_stack_trees(ws), state)
                for i in range(len(ws)):
                    ws[i] = S.tree_slice(new, i)
                return state, 1
        return rnd

    # -- SPMD background program ----------------------------------------------
    def make_sync_step(self, cfg: "S.SyncConfig") -> Callable:
        """Uniform jittable signature across all algorithms."""
        def sync_step(params_stack, algo_state=None):
            return self.land(params_stack, algo_state, None, None, cfg)

        return sync_step

    # -- analytic HBM-stream model (fp32 bytes per full sync cycle) -----------
    def pytree_sync_bytes(self, r: int, n: int) -> int:
        # generic: snapshot copy (2RN) + one read+write land pass (3RN)
        return 4 * (2 * r * n + 3 * r * n)

    def flat_sync_bytes(self, r: int, n: int, *, fired: Optional[int] = None) -> int:
        # fallback flat engine does the same work as the pytree path
        return self.pytree_sync_bytes(r, n)

    def flat_ref_fns(self, cfg: "S.SyncConfig", fs: FlatSpace) -> Tuple[Callable, Callable]:
        """(snapshot_fn(buf) -> snap, land_fn(buf, state, snap) -> (buf, state)):
        jitted, NON-donating, all-replicas-fired oracle versions of the flat
        cycle — what sync_bench times on CPU (Pallas targets TPU; interpret-
        mode timing is not meaningful)."""
        def land(buf, state, snap):
            new, state = self.land(fs.unpack_stack(buf), state, fs.unpack_stack(snap), None, cfg)
            return fs.pack_stack(new), state

        return jax.jit(lambda buf: buf.copy()), jax.jit(land)


@functools.lru_cache(maxsize=None)
def _flat_fallback(algo: SyncAlgorithm, cfg, fs: FlatSpace) -> Callable:
    def run(buf, state, snap, mask):
        stack = fs.unpack_stack(buf)
        snap_t = fs.unpack_stack(snap) if snap is not None else None
        new, state = algo.land(stack, state, snap_t, mask, cfg)
        return fs.pack_stack(new), state

    return jax.jit(run)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, SyncAlgorithm] = {}


def register(algo, *, override: bool = False) -> SyncAlgorithm:
    """Register an algorithm instance (or class — instantiated with no args).
    Usable as a class decorator: ``@register`` above a SyncAlgorithm subclass."""
    if isinstance(algo, type):
        cls, algo = algo, algo()
    else:
        cls = None
    if not algo.name:
        raise ValueError(f"{type(algo).__name__} must set a non-empty .name")
    if algo.name in _REGISTRY and not override:
        raise ValueError(
            f"sync algorithm {algo.name!r} already registered " "(pass override=True to replace)"
        )
    _REGISTRY[algo.name] = algo
    return cls if cls is not None else algo


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get(name: str) -> SyncAlgorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown sync algorithm {name!r}; " f"registered: {list(names())}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# EASGD (centralized; paper Algorithm 2)
# ---------------------------------------------------------------------------

@register
class EASGD(SyncAlgorithm):
    name = "easgd"
    centralized = True
    snapshot_kind = "gather"  # compact (F, n, 128) copy of only the fired rows
    min_stream_ratio = 1.5

    def init_state(self, w0, cfg):
        return jax.tree.map(jnp.copy, w0)  # the sync-PS copy

    def land(self, stack, state, snap, mask, cfg):
        return S.easgd_round(stack, state, cfg.alpha, mask=mask, snapshot=snap)

    def init_state_flat(self, plane0, cfg, fs):
        return jnp.copy(plane0)  # (n_rows, 128) fp32 PS plane

    def launch_snapshot_flat(self, buf, mask, cfg, fs, state=None, active=None):
        """Self-describing snapshot: a compact gather of the fired live rows
        PLUS their ids, so a slot that dies while the sync is in flight can
        be dropped at landing without disturbing positional alignment."""
        fired = _fired_ids(_intersect(mask, active), buf.shape[0])
        return _gather(buf, jnp.asarray(fired, jnp.int32)), tuple(int(i) for i in fired)

    def land_flat(self, buf, state, snap, mask, cfg, fs, active=None):
        if snap is None:  # fixed-rate: gather from the current buffer — the
            # round op donates ``buf``, so the snapshot must be separate
            fired = _fired_ids(_intersect(mask, active), buf.shape[0])
            if fired.size == 0:
                return buf, state
            fired = jnp.asarray(fired, jnp.int32)
            return easgd_ops.easgd_round_op(
                buf, state, _gather(buf, fired), fired, cfg.alpha, block=fs.block
            )
        snap_rows, ids = snap
        ids = np.asarray(ids, np.int64)
        # a slot that died mid-flight neither moves the PS nor lands
        keep = np.ones(ids.shape, bool) if active is None else np.asarray(active)[ids]
        if not keep.any():
            return buf, state
        if not keep.all():
            snap_rows = _gather(snap_rows, jnp.asarray(np.flatnonzero(keep), jnp.int32))
            ids = ids[keep]
        return easgd_ops.easgd_round_op(
            buf, state, snap_rows, jnp.asarray(ids, jnp.int32), cfg.alpha, block=fs.block
        )

    def on_join(self, stack, slot, state, active, cfg):
        # a joiner adopts the sync-PS copy — the centralized consensus point
        return S.tree_set(stack, slot, state), state

    def on_join_flat(self, buf, slot, state, active, cfg, fs):
        return buf.at[slot].set(state), state

    def make_shadow_round(self, cfg, fs):
        if fs is not None:
            pair = lambda ps, w: easgd_ops.easgd_pair_flat_op(ps, w, cfg.alpha, block=fs.block)
        else:
            pair = jax.jit(lambda ps, w: S.easgd_pair_update(ps, w, cfg.alpha))

        def rnd(ws, state):
            # shadow threads reach the PS one replica at a time (Algorithm 2)
            for i in range(len(ws)):
                state, ws[i] = pair(state, ws[i])
            return state, len(ws)

        return rnd

    def pytree_sync_bytes(self, r, n):
        # copy(2RN) + per-replica scan: lerp_ps(3N) + lerp_wi(3N)
        # + masked keep_ps(3N) + keep_wi(3N)
        return 4 * (2 * r * n + 12 * r * n)

    def flat_sync_bytes(self, r, n, *, fired=None):
        # fired-rows gather(2FN) + round kernel: r(FN stack + FN snap + N ps)
        # + w(FN stack + N ps); un-fired replicas cost nothing, at launch OR
        # landing.
        f = r if fired is None else fired
        return 4 * (2 * f * n + (2 * f * n + n) + (f * n + n))

    def flat_ref_fns(self, cfg, fs):
        def land(buf, ps, snap):
            fired = jnp.arange(buf.shape[0], dtype=jnp.int32)
            return easgd_round_ref(buf, ps, snap, fired, cfg.alpha)

        return jax.jit(lambda buf: buf.copy()), jax.jit(land)


# ---------------------------------------------------------------------------
# Model Averaging (decentralized; paper Algorithm 3)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _ma_elastic_jit(algo: "MA", cfg) -> Callable:
    return jax.jit(lambda stack, state, snap, active, launch_active: (
        S.ma_round(stack, cfg.alpha, snapshot=snap, active=launch_active, land_active=active), state
    ))


@register
class MA(SyncAlgorithm):
    name = "ma"
    snapshot_kind = "mean"  # the landing only ever reads the snapshot's mean
    min_stream_ratio = 2.0

    def land(self, stack, state, snap, mask, cfg):
        return S.ma_round(stack, cfg.alpha, snapshot=snap), state

    def land_elastic(self, stack, state, snap, mask, active, cfg, launch_active=None):
        if active is None and launch_active is None:
            return super().land_elastic(stack, state, snap, mask, active, cfg)
        # mean over the LAUNCH-time live set (that is what the background
        # AllReduce saw); the pull-back lands on the CURRENT live rows.
        if launch_active is None:
            launch_active = active
        return _ma_elastic_jit(self, cfg)(
            stack,
            state,
            snap,
            None if active is None else jnp.asarray(active),
            jnp.asarray(launch_active),
        )

    def launch_snapshot_flat(self, buf, mask, cfg, fs, state=None, active=None):
        if active is None:
            return ma_ops.replica_mean_op(buf, block=fs.block)
        return ma_ops.replica_mean_rows_op(buf, _active_rows(active), block=fs.block)

    def land_flat(self, buf, state, snap, mask, cfg, fs, active=None):
        if active is None:
            mean = snap if snap is not None else ma_ops.replica_mean_op(buf, block=fs.block)
            return ma_ops.ma_sync_op(buf, mean, cfg.alpha, block=fs.block), state
        rows = _active_rows(active)
        mean = snap if snap is not None else ma_ops.replica_mean_rows_op(buf, rows, block=fs.block)
        return ma_ops.ma_sync_rows_op(buf, mean, rows, cfg.alpha, block=fs.block), state

    def make_shadow_round(self, cfg, fs):
        if fs is not None:
            # slice-free decentralized round: one fused mean over the stacked
            # planes, then per-plane elastic pull-backs landing on the
            # CURRENT plane — trainers kept moving while the mean was in
            # flight (paper §3.3).
            plane_mean = jax.jit(lambda *planes: ma_ops.replica_mean_op(
                jnp.stack(planes), block=fs.block
            ))
            pullback = jax.jit(lambda plane, mean: ma_ops.ma_sync_op(
                plane[None], mean, cfg.alpha, block=fs.block
            )[0])

            def rnd(ws, state):
                mean = plane_mean(*ws)
                for i in range(len(ws)):
                    ws[i] = pullback(ws[i], mean)
                return state, 1
        else:
            land = jax.jit(lambda stack: S.ma_round(stack, cfg.alpha))

            def rnd(ws, state):
                new = land(_stack_trees(ws))
                for i in range(len(ws)):
                    ws[i] = S.tree_slice(new, i)
                return state, 1
        return rnd

    def pytree_sync_bytes(self, r, n):
        # copy(2RN) + mean(RN+N) + broadcast(N+RN) + lerp(2RN+RN)
        rn = r * n
        return 4 * (2 * rn + (rn + n) + (n + rn) + 3 * rn)

    def flat_sync_bytes(self, r, n, *, fired=None):
        # launch mean(RN+N) + pull-back kernel(r RN+N, w RN)
        rn = r * n
        return 4 * ((rn + n) + (2 * rn + n))

    def flat_ref_fns(self, cfg, fs):
        return (
            jax.jit(replica_mean_ref),
            jax.jit(lambda buf, st_, mean: (ma_update_ref(buf, mean, cfg.alpha), st_)),
        )


# ---------------------------------------------------------------------------
# BMUF (decentralized; paper Algorithm 4)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _bmuf_elastic_jit(algo: "BMUF", cfg) -> Callable:
    return jax.jit(
        lambda stack, state, snap, active, launch_active: S.bmuf_round(
            stack,
            state,
            cfg.alpha,
            eta=cfg.eta,
            block_momentum=cfg.block_momentum,
            nesterov=cfg.nesterov,
            snapshot=snap,
            active=launch_active,
            land_active=active,
        )
    )


def _bmuf_plane_step(mean, wg, vel, cfg):
    """N-sized BMUF global step on flat planes; returns (look, wg', vel')."""
    desc = mean - wg
    vel = cfg.block_momentum * vel + cfg.eta * desc
    wg = wg + vel
    look = wg + cfg.block_momentum * vel if cfg.nesterov else wg
    return look, wg, vel


@register
class BMUF(SyncAlgorithm):
    name = "bmuf"
    snapshot_kind = "mean"
    min_stream_ratio = 2.0

    def init_state(self, w0, cfg):
        return S.BMUFState.init(w0)

    def land(self, stack, state, snap, mask, cfg):
        return S.bmuf_round(
            stack,
            state,
            cfg.alpha,
            eta=cfg.eta,
            block_momentum=cfg.block_momentum,
            nesterov=cfg.nesterov,
            snapshot=snap,
        )

    def init_state_flat(self, plane0, cfg, fs):
        return S.BMUFState(
            w_global=jnp.copy(plane0), velocity=jnp.zeros((fs.n_rows, LANE), jnp.float32)
        )

    def land_elastic(self, stack, state, snap, mask, active, cfg, launch_active=None):
        if active is None and launch_active is None:
            return super().land_elastic(stack, state, snap, mask, active, cfg)
        if launch_active is None:
            launch_active = active
        return _bmuf_elastic_jit(self, cfg)(
            stack,
            state,
            snap,
            None if active is None else jnp.asarray(active),
            jnp.asarray(launch_active),
        )

    def launch_snapshot_flat(self, buf, mask, cfg, fs, state=None, active=None):
        if active is None:
            return ma_ops.replica_mean_op(buf, block=fs.block)
        return ma_ops.replica_mean_rows_op(buf, _active_rows(active), block=fs.block)

    def land_flat(self, buf, state, snap, mask, cfg, fs, active=None):
        if active is None:
            mean = snap if snap is not None else ma_ops.replica_mean_op(buf, block=fs.block)
            new, wg, vel = bmuf_ops.bmuf_sync_op(
                buf,
                mean,
                state.w_global,
                state.velocity,
                cfg.alpha,
                eta=cfg.eta,
                block_momentum=cfg.block_momentum,
                nesterov=cfg.nesterov,
                block=fs.block,
            )
            return new, S.BMUFState(w_global=wg, velocity=vel)
        rows = _active_rows(active)
        mean = snap if snap is not None else ma_ops.replica_mean_rows_op(buf, rows, block=fs.block)
        new, wg, vel = bmuf_ops.bmuf_sync_rows_op(
            buf,
            mean,
            state.w_global,
            state.velocity,
            rows,
            cfg.alpha,
            eta=cfg.eta,
            block_momentum=cfg.block_momentum,
            nesterov=cfg.nesterov,
            block=fs.block,
        )
        return new, S.BMUFState(w_global=wg, velocity=vel)

    def make_shadow_round(self, cfg, fs):
        if fs is not None:
            plane_mean = jax.jit(lambda *planes: ma_ops.replica_mean_op(
                jnp.stack(planes), block=fs.block
            ))
            state_step = jax.jit(lambda mean, wg, vel: _bmuf_plane_step(mean, wg, vel, cfg))
            pullback = jax.jit(lambda plane, look: ma_ops.ma_sync_op(
                plane[None], look, cfg.alpha, block=fs.block
            )[0])

            def rnd(ws, state):
                # real block momentum in the background: mean -> N-sized
                # global step -> per-plane pull-back toward the look-ahead,
                # landing on the CURRENT planes (paper §3.3).
                mean = plane_mean(*ws)
                look, wg, vel = state_step(mean, state.w_global, state.velocity)
                for i in range(len(ws)):
                    ws[i] = pullback(ws[i], look)
                return S.BMUFState(w_global=wg, velocity=vel), 1
        else:
            land = jax.jit(lambda stack, st_: S.bmuf_round(
                stack,
                st_,
                cfg.alpha,
                eta=cfg.eta,
                block_momentum=cfg.block_momentum,
                nesterov=cfg.nesterov,
            ))

            def rnd(ws, state):
                new, state = land(_stack_trees(ws), state)
                for i in range(len(ws)):
                    ws[i] = S.tree_slice(new, i)
                return state, 1
        return rnd

    def pytree_sync_bytes(self, r, n):
        # MA chain + desc/velocity/w_global updates (r 2N + w N each)
        rn = r * n
        return 4 * (2 * rn + (rn + n) + (n + rn) + 3 * rn + 9 * n)

    def flat_sync_bytes(self, r, n, *, fired=None):
        # launch mean(RN+N) + fused landing(r RN+3N, w RN+2N)
        rn = r * n
        return 4 * ((rn + n) + (2 * rn + 5 * n))

    def flat_ref_fns(self, cfg, fs):
        def land(buf, state, mean):
            new, wg, vel = bmuf_update_ref(
                buf,
                mean,
                state.w_global,
                state.velocity,
                cfg.alpha,
                eta=cfg.eta,
                block_momentum=cfg.block_momentum,
                nesterov=cfg.nesterov,
            )
            return new, S.BMUFState(w_global=wg, velocity=vel)

        return jax.jit(replica_mean_ref), jax.jit(land)


# ---------------------------------------------------------------------------
# Gossip (decentralized, pairwise, partial participation; ADPSGD-style —
# the algorithm FAMILY the pre-registry API could not express)
# ---------------------------------------------------------------------------

def _ring_partner(R: int, shift) -> jnp.ndarray:
    """Rotating perfect matching over replica ids 0..R-1.

    Position k of the rotated ring holds id (k + shift) % R; consecutive ring
    positions pair up. Returns (R,) int32 ``partner`` — an involution; a
    self-partner means unpaired this round (the odd one out when R is odd).
    jit-friendly: ``shift`` (the algorithm's round counter) may be traced.
    Successive shifts alternate the matchings, so the union of pair edges
    over rounds is a connected ring — pairwise averaging mixes globally
    without any collective.
    """
    order = (jnp.arange(R, dtype=jnp.int32) + shift) % R
    npair = R // 2
    a, b = order[0:2 * npair:2], order[1:2 * npair:2]
    partner = jnp.arange(R, dtype=jnp.int32).at[a].set(b).at[b].set(a)
    return partner


def _ring_partner_np(R: int, shift: int) -> List[int]:
    """Host mirror of `_ring_partner`."""
    order = [(k + shift) % R for k in range(R)]
    partner = list(range(R))
    for k in range(0, R - 1, 2):
        a, b = order[k], order[k + 1]
        partner[a], partner[b] = b, a
    return partner


def _ring_partner_active_np(active: np.ndarray, shift: int) -> List[int]:
    """Rotating matching drawn over the ACTIVE slots only (elastic
    membership): the ring is formed on the live ids, then mapped back to
    global slot numbers. Dead slots are their own partner (never paired)."""
    active = np.asarray(active, bool)
    R = active.shape[0]
    ids = np.flatnonzero(active)
    partner = list(range(R))
    sub = _ring_partner_np(len(ids), shift)
    for k, g in enumerate(ids):
        partner[int(g)] = int(ids[sub[k]])
    return partner


def _gossip_participants_np(
    mask: Optional[np.ndarray], R: int, shift: int, active: Optional[np.ndarray] = None
):
    """Participant rows of a gossip round, host-side (flat-engine operands).

    A ring pair is ACTIVE when either member's shadow clock fired — the
    initiator pulls its passive partner into the exchange (ADPSGD), so even
    a round with a single fired replica synchronizes. Under elastic
    membership (``active`` given) the ring is drawn over the live slots only
    and dead slots can neither fire nor be pulled in. Returns
    (rows, self_pos, partner_pos): the sorted replica ids of all active-pair
    members (== the rows the launch snapshot gathers, and the rows that
    land), plus each one's own/partner position inside that snapshot.
    """
    if active is None:
        partner = _ring_partner_np(R, shift)
        m = np.ones((R,), bool) if mask is None else np.asarray(mask).astype(bool)
    else:
        partner = _ring_partner_active_np(active, shift)
        m = (
            np.ones((R,), bool) if mask is None else np.asarray(mask).astype(bool)
        ) & np.asarray(active, bool)
    rows = [i for i in range(R) if partner[i] != i and (m[i] or m[partner[i]])]
    pos = {rid: k for k, rid in enumerate(rows)}
    self_pos = [pos[i] for i in rows]
    partner_pos = [pos[partner[i]] for i in rows]
    return rows, self_pos, partner_pos


@functools.lru_cache(maxsize=None)
def _gossip_elastic_jit(algo: "Gossip", cfg) -> Callable:
    def run(stack, snap, mask, partner, active):
        R = jax.tree.leaves(stack)[0].shape[0]
        src = snap if snap is not None else stack
        ids = jnp.arange(R, dtype=jnp.int32)
        # a pair forms when either member fired at LAUNCH; the landing then
        # only touches rows that are STILL live (a slot that died mid-flight
        # is skipped, its partner still lands from the snapshot mix)
        pair_live = (partner != ids) & (mask | mask[partner])
        if active is not None:
            pair_live = pair_live & active

        def land_leaf(x, x_snap):
            xs = x_snap.astype(jnp.float32)
            mix = 0.5 * (xs + xs[partner])
            new = (1.0 - cfg.alpha) * x.astype(jnp.float32) + cfg.alpha * mix
            keep = pair_live.reshape((R,) + (1,) * (x.ndim - 1))
            return jnp.where(keep, new, x.astype(jnp.float32)).astype(x.dtype)

        return jax.tree.map(land_leaf, stack, src)

    return jax.jit(run)


@register
class Gossip(SyncAlgorithm):
    name = "gossip"
    snapshot_kind = "gather"
    min_stream_ratio = 2.0

    def init_state(self, w0, cfg):
        return jnp.zeros((), jnp.int32)  # round counter drives pair rotation

    def init_state_flat(self, plane0, cfg, fs):
        return self.init_state(None, cfg)

    def land(self, stack, state, snap, mask, cfg):
        R = jax.tree.leaves(stack)[0].shape[0]
        mask = jnp.ones((R,), bool) if mask is None else jnp.asarray(mask)
        src = snap if snap is not None else stack
        ids = jnp.arange(R, dtype=jnp.int32)
        partner = _ring_partner(R, state)
        # a pair is active when EITHER member fired: the initiator pulls its
        # passive partner into the exchange (ADPSGD) — a singleton-fire
        # round still synchronizes.
        active = (partner != ids) & (mask | mask[partner])

        def land_leaf(x, x_snap):
            xs = x_snap.astype(jnp.float32)
            mix = 0.5 * (xs + xs[partner])
            new = (1.0 - cfg.alpha) * x.astype(jnp.float32) + cfg.alpha * mix
            keep = active.reshape((R,) + (1,) * (x.ndim - 1))
            return jnp.where(keep, new, x.astype(jnp.float32)).astype(x.dtype)

        return jax.tree.map(land_leaf, stack, src), state + 1

    def land_elastic(self, stack, state, snap, mask, active, cfg, launch_active=None):
        if active is None and launch_active is None:
            return super().land_elastic(stack, state, snap, mask, active, cfg)
        if launch_active is None:
            launch_active = active
        # the matching was drawn at LAUNCH, over the then-live slots
        partner = _ring_partner_active_np(launch_active, int(state))
        mask_arr = (jnp.asarray(np.asarray(launch_active, bool)) if mask is None
                    else jnp.asarray(np.asarray(mask, bool)))
        new = _gossip_elastic_jit(self, cfg)(
            stack,
            snap,
            mask_arr,
            jnp.asarray(partner, jnp.int32),
            None if active is None else jnp.asarray(active),
        )
        return new, state + 1

    def launch_snapshot_flat(self, buf, mask, cfg, fs, state=None, active=None):
        # Self-describing snapshot: a compact gather of exactly the
        # active-pair members' rows PLUS the pairing that produced it, so the
        # landing never has to re-derive the participant set from state that
        # may have moved while the sync was in flight (ADPSGD: the initiator
        # picks its partner at launch). Under elastic membership the ring is
        # drawn over the live slots only.
        rows, self_pos, partner_pos = _gossip_participants_np(
            mask, buf.shape[0], 0 if state is None else int(state), active=active
        )
        return (_gather(buf, jnp.asarray(rows, jnp.int32)), rows, self_pos, partner_pos)

    def land_flat(self, buf, state, snap, mask, cfg, fs, active=None):
        if snap is None:  # fixed-rate: pair and gather at landing time (the
            # round op donates ``buf``, so the snapshot must be separate)
            snap = self.launch_snapshot_flat(buf, mask, cfg, fs, state, active=active)
        snap_rows, rows, self_pos, partner_pos = snap
        new_state = state + 1
        if active is not None and rows:
            # a slot that died mid-flight is skipped; its live partner still
            # lands from the snapshot mix gathered at launch
            act = np.asarray(active, bool)
            kept = [k for k, rid in enumerate(rows) if act[rid]]
            rows = [rows[k] for k in kept]
            self_pos = [self_pos[k] for k in kept]
            partner_pos = [partner_pos[k] for k in kept]
        if not rows:
            return buf, new_state
        new = gossip_ops.gossip_round_op(
            buf,
            snap_rows,
            jnp.asarray(rows, jnp.int32),
            jnp.asarray(self_pos, jnp.int32),
            jnp.asarray(partner_pos, jnp.int32),
            cfg.alpha,
            block=fs.block,
        )
        return new, new_state

    def make_shadow_round(self, cfg, fs):
        if fs is not None:
            pair = lambda a, b: gossip_ops.gossip_pair_flat_op(a, b, cfg.alpha, block=fs.block)
        else:
            def pair_tree(a, b):
                mix = jax.tree.map(
                    lambda x, y: 0.5 * (x.astype(jnp.float32) + y.astype(jnp.float32)), a, b
                )
                return S.lerp(a, mix, cfg.alpha), S.lerp(b, mix, cfg.alpha)

            pair = jax.jit(pair_tree)

        def rnd(ws, state):
            R = len(ws)
            partner = _ring_partner_np(R, int(state))
            for i in range(R):
                if partner[i] > i:  # exchange each pair once
                    ws[i], ws[partner[i]] = pair(ws[i], ws[partner[i]])
            return state + 1, 1

        return rnd

    def pytree_sync_bytes(self, r, n):
        # copy(2RN) + partner gather(2RN) + mix(3RN) + lerp(3RN) + where(4RN)
        return 4 * (2 * r * n + 12 * r * n)

    def flat_sync_bytes(self, r, n, *, fired=None):
        # participant-rows gather(2PN) + round kernel per participant:
        # r(PN stack + 2PN snap) + w(PN stack); inactive pairs cost nothing.
        # With f initiators the active pairs pull in at most f partners.
        f = r if fired is None else fired
        p = min(2 * f, 2 * (r // 2))
        return 4 * (2 * p * n + 3 * p * n + p * n)

    def flat_ref_fns(self, cfg, fs):
        def land(buf, state, snap):
            R = buf.shape[0]
            ids = jnp.arange(R, dtype=jnp.int32)
            partner = _ring_partner(R, state)
            mix = 0.5 * (snap + snap[partner])
            new = jnp.where(
                (partner != ids)[:, None, None], (1.0 - cfg.alpha) * buf + cfg.alpha * mix, buf
            )
            return new, state + 1

        return jax.jit(lambda buf: buf.copy()), jax.jit(land)

"""Synthetic LM token stream (order-1 Markov chain) for smoke tests and examples.

A Markov teacher gives the LM something learnable (loss can drop below the uniform
entropy), unlike i.i.d.-uniform tokens.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp


def make_transition(vocab: int, seed: int = 0, concentration: float = 0.3) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (vocab, vocab)) / concentration
    return jax.nn.softmax(logits, axis=-1)


def gen_batch(trans: jnp.ndarray, seed: int, batch_idx: int, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    vocab = trans.shape[0]
    key = jax.random.fold_in(jax.random.PRNGKey(seed), batch_idx)
    k0, kc = jax.random.split(key)
    t0 = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, jnp.log(trans[tok] + 1e-9))
        return nxt, nxt

    keys = jax.random.split(kc, seq - 1)
    _, rest = jax.lax.scan(step, t0, keys)
    tokens = jnp.concatenate([t0[None], rest], axis=0).T  # (B, S)
    return {"tokens": tokens}


def stream(trans: jnp.ndarray, seed: int, batch: int, seq: int, n_batches: int) -> Iterator[Dict[str, jnp.ndarray]]:
    for i in range(n_batches):
        yield gen_batch(trans, seed, i, batch, seq)

"""Prefetching host loader — the analogue of the paper's shared reader service.

The reader service in the paper decouples feature engineering from training via a
per-trainer local queue; here a background thread fills a bounded queue so the
training loop never blocks on data generation (and we can deliberately
under-provision it to reproduce the paper's reader-bottleneck observation in
§4.1.1, where the S-EASGD sync gap collapsed to ~1).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class PrefetchLoader:
    def __init__(self, make_batch: Callable[[int], object], n_batches: int,
                 prefetch: int = 4, delay_s: float = 0.0):
        """make_batch(i) -> batch. ``delay_s`` simulates an under-provisioned
        reader service (data bottleneck)."""
        self._make = make_batch
        self._n = n_batches
        self._delay = delay_s
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._started = False

    def _fill(self):
        import time

        for i in range(self._n):
            if self._delay:
                time.sleep(self._delay)
            self._q.put(self._make(i))
        self._q.put(None)

    def __iter__(self) -> Iterator:
        if not self._started:
            self._thread.start()
            self._started = True
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

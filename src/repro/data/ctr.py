"""Synthetic CTR data with ground-truth logistic structure.

The production datasets in the paper are private; we generate clicks from a hidden
teacher (true per-row embedding vectors + a random interaction MLP) so that (a)
loss decreases are meaningful, (b) different sync algorithms are comparable on an
identical stream, and (c) the stream is one-pass by construction: batch ``i`` is a
pure function of (seed, i) and is never revisited — matching the paper's one-pass
training constraint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CTRTeacher:
    """Hidden ground-truth model; fields are device arrays."""

    true_rows: jnp.ndarray  # (total_rows, k) true latent per categorical row
    w_dense: jnp.ndarray  # (n_dense, k)
    w_out: jnp.ndarray  # (k,)
    bias: jnp.ndarray  # ()


def make_teacher(cfg, seed: int = 0, k: int = 8) -> CTRTeacher:
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    total = int(sum(cfg.table_sizes))
    return CTRTeacher(
        true_rows=jax.random.normal(k1, (total, k)) * 0.8,
        w_dense=jax.random.normal(k2, (cfg.n_dense_features, k)) * 0.5,
        w_out=jax.random.normal(k3, (k,)),
        bias=jnp.asarray(-1.5),  # base CTR well below 50%, like real ads data
    )


def _offsets(cfg) -> jnp.ndarray:
    return jnp.asarray(
        np.concatenate([[0], np.cumsum(cfg.table_sizes)[:-1]]).astype(np.int32)
    )


def gen_batch(cfg, teacher: CTRTeacher, seed: int, batch_idx: int, batch_size: int) -> Dict[str, jnp.ndarray]:
    """Pure function of (seed, batch_idx): the one-pass stream."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), batch_idx)
    kd, ks, kl = jax.random.split(key, 3)
    F, m = cfg.n_sparse_features, cfg.multi_hot
    dense = jax.random.normal(kd, (batch_size, cfg.n_dense_features))
    sizes = jnp.asarray(cfg.table_sizes)
    # Zipf-ish skew: square a uniform to concentrate on low ids (hot rows).
    u = jax.random.uniform(ks, (batch_size, F, m))
    idx = jnp.minimum((u * u * sizes[None, :, None]).astype(jnp.int32), sizes[None, :, None] - 1)

    rows = idx + _offsets(cfg)[None, :, None]
    latent = jnp.sum(jnp.take(teacher.true_rows, rows, axis=0), axis=(1, 2))  # (B, k)
    latent = latent / (F * m) + dense @ teacher.w_dense
    score = jnp.tanh(latent) @ teacher.w_out + teacher.bias
    prob = jax.nn.sigmoid(score)
    labels = jax.random.bernoulli(kl, prob).astype(jnp.float32)
    return {"dense": dense, "sparse": idx, "labels": labels}


def stream(cfg, teacher: CTRTeacher, seed: int, batch_size: int,
           n_batches: int, start: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    for i in range(start, start + n_batches):
        yield gen_batch(cfg, teacher, seed, i, batch_size)


def normalized_entropy(bce: float, base_ctr: float) -> float:
    """The paper's quality metric style: BCE normalized by the entropy of the
    background CTR [He et al. 2014]."""
    p = base_ctr
    h = -(p * np.log(p) + (1 - p) * np.log(1 - p))
    return float(bce / h)

"""Concurrency-contract grammar and registries (DESIGN.md §12).

The static checker reads *directives* — structured trailing / standalone
comments — out of each source file and binds them to fields, statements,
or functions:

    # guarded-by: <lock>         every access to the field must hold <lock>
    # guarded-by-writes: <lock>  stores/mutations must hold <lock>; lock-free
                                 reads are part of the contract (Hogwild)
    # swap-published             the field is only ever REBOUND to a freshly
                                 built immutable value — never mutated in place
    # swap-published: elements   fixed-slot container: elements are wholesale
                                 rebound (x[i] = fresh); deeper mutation is a
                                 violation
    # hogwild-race: ok — <why>   on a field declaration: deliberately lock-free
                                 by design; on any other statement: waive the
                                 guarded-by check for that one statement
    # holds-lock: <lock>         on a def: every caller holds <lock>; the body
                                 is analyzed as if inside `with <lock>`
    # lock-blocking: ok — <why>  on a def or statement: waive the
                                 no-blocking-under-lock check there

Several directives may share one comment, separated by ';'. Lock names are
the dotted source text of the lock expression with a leading ``self.``
stripped, so ``with self._state_lock:`` discharges ``guarded-by:
_state_lock`` and a closure lock ``ex_lock`` is named literally.

The registries below are the per-class contract table the issue calls for:
``SHARED_CLASSES`` marks classes whose instances are handed across threads
even though they spawn none themselves (every public method is then a
potential thread entry point), and records the one-line justification for
each pure-annotation (waiver) resolution. ``KERNEL_CALLS`` / ``BLOCKING``
name the calls the no-blocking-under-lock pass treats as dispatch or
blocking.
"""

from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Directive model
# ---------------------------------------------------------------------------

# Directive kinds, as they appear in source. `hogwild-race` and
# `lock-blocking` take an "ok" argument (with optional " — reason" tail);
# the guarded/holds kinds take a lock name; swap-published takes an
# optional "elements".
KINDS = (
    "guarded-by",
    "guarded-by-writes",
    "swap-published",
    "hogwild-race",
    "holds-lock",
    "lock-blocking",
)

_DIRECTIVE_RE = re.compile(
    r"(?P<kind>guarded-by-writes|guarded-by|swap-published|hogwild-race"
    r"|holds-lock|lock-blocking)"
    r"(?:\s*:\s*(?P<arg>[^;#]*))?"
)


@dataclass(frozen=True)
class Directive:
    """One parsed annotation, bound to the physical line it sits on."""

    kind: str
    arg: str  # lock name, "elements", "ok", or "ok — reason"
    line: int  # 1-based physical line of the comment token
    trailing: bool  # True: shares the line with code; False: standalone
    reason: str = ""  # text after an em/double dash in the arg, if any

    @property
    def lock(self) -> str:
        """The lock name for guarded-by / guarded-by-writes / holds-lock."""
        return self.arg

    def is_ok(self) -> bool:
        return self.arg.split("—")[0].split("--")[0].strip().lower() == "ok"


@dataclass(frozen=True)
class Violation:
    """One contract violation. ``code`` is stable for tests/CI grepping."""

    code: str  # GB01 | SP01 | BL01 | SH01 | CT01
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


# Human-readable legend, used by scripts/check_concurrency.py --explain.
CODES: Dict[str, str] = {
    "GB01": "guarded field accessed outside its declared lock",
    "SP01": "swap-published field mutated in place (must be rebound wholesale)",
    "BL01": "blocking call / kernel dispatch while holding a lock",
    "SH01": "shared mutable attribute with no concurrency annotation",
    "CT01": "malformed or misplaced contract annotation",
}


def _split_reason(raw: str) -> Tuple[str, str]:
    """Split "ok — reason" / "ok -- reason" into (head, reason)."""
    for sep in ("—", "--"):
        if sep in raw:
            head, _, tail = raw.partition(sep)
            return head.strip(), tail.strip()
    return raw.strip(), ""


def parse_directives(source: str, path: str = "<string>") -> List[Directive]:
    """Extract every contract directive from ``source``.

    Uses the tokenizer (not regexes over raw lines) so directives inside
    string literals are never picked up, and so we can tell trailing
    comments (code precedes them on the line) from standalone ones.
    """
    out: List[Directive] = []
    code_lines: set[int] = set()
    comments: List[Tuple[int, str]] = []
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except tokenize.TokenError:
        return out
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            comments.append((tok.start[0], tok.string))
        elif tok.type not in (
            tokenize.NL,
            tokenize.NEWLINE,
            tokenize.INDENT,
            tokenize.DEDENT,
            tokenize.ENDMARKER,
        ):
            for ln in range(tok.start[0], tok.end[0] + 1):
                code_lines.add(ln)
    for line, text in comments:
        body = text.lstrip("#").strip()
        for part in body.split(";"):
            m = _DIRECTIVE_RE.match(part.strip())
            if not m or m.start() != 0:
                continue
            kind = m.group("kind")
            raw_arg = (m.group("arg") or "").strip()
            arg, reason = _split_reason(raw_arg)
            out.append(
                Directive(
                    kind=kind,
                    arg=arg,
                    line=line,
                    trailing=line in code_lines,
                    reason=reason,
                )
            )
    return out


# ---------------------------------------------------------------------------
# Field contracts (what a directive resolves to once bound to a field)
# ---------------------------------------------------------------------------


@dataclass
class FieldContract:
    """Resolved concurrency contract for one attribute / closure variable."""

    name: str
    guarded_by: Optional[str] = None  # all accesses under this lock
    guarded_writes: Optional[str] = None  # stores under this lock, reads free
    swap_published: bool = False
    swap_elements: bool = False  # "swap-published: elements"
    hogwild_ok: bool = False
    decl_lines: List[int] = field(default_factory=list)

    def merge(self, d: Directive) -> Optional[str]:
        """Fold one more directive in; return an error string on conflict."""
        if d.kind == "guarded-by":
            if self.guarded_by not in (None, d.lock):
                return f"conflicting guarded-by locks for '{self.name}'"
            self.guarded_by = d.lock
        elif d.kind == "guarded-by-writes":
            if self.guarded_writes not in (None, d.lock):
                return f"conflicting guarded-by-writes locks for '{self.name}'"
            self.guarded_writes = d.lock
        elif d.kind == "swap-published":
            self.swap_published = True
            if d.arg == "elements":
                self.swap_elements = True
            elif d.arg not in ("", "elements"):
                return f"swap-published takes no argument or 'elements', got '{d.arg}'"
        elif d.kind == "hogwild-race":
            if not d.is_ok():
                return f"hogwild-race directive must say 'ok', got '{d.arg}'"
            self.hogwild_ok = True
        else:
            return f"directive '{d.kind}' cannot annotate a field"
        self.decl_lines.append(d.line)
        return None

    @property
    def annotated(self) -> bool:
        return bool(
            self.guarded_by or self.guarded_writes or self.swap_published or self.hogwild_ok
        )


# ---------------------------------------------------------------------------
# Per-class contract table
# ---------------------------------------------------------------------------

# Classes whose instances are shared across threads even though the class
# itself spawns none: the runners hand them to trainer / shadow / monitor /
# supervisor threads. For these, every public method is treated as a
# distinct thread entry point, so any mutable attribute reached from >= 2
# methods needs an annotation. Classes that DO spawn threads
# (ThreadedShadowRunner, Supervisor, PrefetchLoader) are picked up
# automatically from their Thread(...) call sites and need no registration.
SHARED_CLASSES: Dict[str, str] = {
    "Membership": "slot status table read by every thread, mutated via _transition",
    "EPSMeter": "global examples/s meter: trainers add, monitor/scheduler read",
    "SlotEPS": "per-slot pace meters: owner slot ticks, scheduler reads",
    "StragglerPolicy": "scheduler observed from monitor + supervision ticks, read by trainers",
    "Supervisor": "heartbeats arrive from every supervised thread",
    "EmbeddingShards": "PS shard table: trainers look up, shadow updates, supervisor heals",
    "CachedStore": "two-tier store: trainer lookups race the prefetcher's migrations",
    "StepPipeline": "staged-lookup double buffer: the owning trainer stages/consumes, "
    "the stager thread publishes entries via per-entry Events",
    "ModeController": "mode state machine: shadow round + supervision tick both "
    "observe, trainers read .mode lock-free",
}

# One-line justifications for every pure-annotation (waiver) resolution on
# the current tree — the issue requires each to be recorded here. Keys are
# "<module>.<Class>.<field>" or "<module>.<scope>" for statement waivers.
WAIVER_JUSTIFICATIONS: Dict[str, str] = {
    # --- hogwild-race: ok fields -----------------------------------------
    "runners.ThreadedShadowRunner._w0": "written once before any thread starts; read-only after",
    "runners.ThreadedShadowRunner.emb": "bound pre-spawn in run(); rebinding after spawn is a bug",
    "runners.ThreadedShadowRunner.iter_count": "slot-owned counters; cross-slot reads are pacing "
    "hints where staleness is tolerable",
    "runners.ThreadedShadowRunner._shadow_rounds": "single logical writer (generation-fenced "
    "shadow incarnation); reads are post-join or advisory",
    "runners.ThreadedShadowRunner._sync_excs": "append-only post-mortem log; list.append is "
    "atomic under the GIL",
    "runners.ThreadedShadowRunner._sync_degraded": "single bool store from the give-up hook; "
    "read post-join",
    "runners.ThreadedShadowRunner._sync_stalled": "same single-store post-join contract as "
    "_sync_degraded",
    "runners.ThreadedShadowRunner._sync_crash_t": "same single-store post-join contract as "
    "_sync_degraded",
    "runners.ThreadedShadowRunner._sync_count_at_restart": "restart hook (one supervision "
    "thread) appends; read post-join",
    "runners.ThreadedShadowRunner._ps_injected": "only the supervision tick callback touches "
    "it, and ticks are serialized by the single supervisor thread",
    "runners.ThreadedShadowRunner._tick_count": "same single-tick-owner contract as "
    "_ps_injected",
    "runners.ThreadedShadowRunner.slot_eps": "slot-owned meters: owner slot ticks its cell, "
    "scheduler reads are pacing hints (SlotEPS is itself in SHARED_CLASSES)",
    "runners.run.losses": "slot-owned lists; merged only after join",
    "runners.run.trainer_wall": "slot-owned wall-clock cells; read after join",
    "membership.Membership.events": "appends under _lock; external readers snapshot via list()",
    "elp.EPSMeter._buckets": "single-writer deque; eps() snapshots via list(deque) which is "
    "atomic under the GIL (documented thread model in elp.py)",
    "elp.SlotEPS._busy": "slot-owned virtual clocks: only the owner slot ticks its cell",
    "elp.SlotEPS._meters": "fixed list of per-slot meters: only owner slot i mutates "
    "_meters[i]; scheduler reads others' eps() as a pacing hint",
    "supervision.Supervisor.events": "single supervision thread appends; readers snapshot "
    "post-run",
    "supervision.Supervisor._thread": "start/stop are caller-serialized lifecycle methods",
    "shards.EmbeddingShards.dropped_updates": "lossy-by-design failure counters; element += "
    "races only ever under-count",
    "shards.EmbeddingShards.stale_lookups": "same lossy counter contract as dropped_updates",
    "shards.EmbeddingShards.states": "lock-free Hogwild element swap; try_update re-checks "
    "shard health post-dispatch so a racing failover only drops (never corrupts) the write",
    "cache.CachedStore.freq": "frequency stats feed eviction ranking only; lost increments "
    "shift ranks, never correctness",
    "cache.CachedStore._pinned": "prefetcher rebinds a fresh mask wholesale; trainers read "
    "whichever mask is current (stale pin set costs one extra cold fetch, never correctness)",
    "cache.CachedStore.stats": "hit/miss counters are diagnostic; torn increments tolerated",
    "shards.EmbeddingShards.incarnations": "bumped under _lock on fail AND recover; the "
    "pipeline's lock-free reads are an advisory drain token (a missed bump only rereads "
    "serially, never lands a stale plane — consume re-checks at the entry Event)",
    "pipeline.StepPipeline._buf": "owner-thread-confined: stage/consume/drain all run on "
    "the one trainer thread that owns the pipeline; the stager never touches the dict",
    "pipeline.StepPipeline._prep_memo": "worker-thread-confined peek memo: only the stager "
    "thread reads/writes it",
    "runners.ThreadedShadowRunner._pipes": "slot-owned cells: each trainer binds and drives "
    "only its own pipeline; no cross-slot access",
    "runners.ThreadedShadowRunner._pipe_stats": "slot-owned cells written in the slot's "
    "finally; merged after join",
    # --- lock-blocking: ok scopes ----------------------------------------
    "runners.ThreadedShadowRunner._bootstrap_join": "admission must be atomic with the "
    "membership transition; joins are rare and bounded (one stack + on_join hook)",
    "runners.run._prefetch_step": "the non-blocking _prefetch_gate IS the round's mutual "
    "exclusion — no other thread can wait on it",
    "cache.CachedStore._apply_migration": "migration scatters are bounded row copies; doing "
    "them optimistically would break eviction-writeback-before-slot-reuse exactness",
}

# Callables treated as kernel dispatch / device work by the
# no-blocking-under-lock pass, beyond anything bound from jax.jit(...) or
# called via a jnp./jax. dotted path. Matched on the final attribute /
# name segment of the call.
KERNEL_CALLS = frozenset(
    {
        # fused Pallas kernels + their jit'd wrappers
        "embedding_bag_op",
        "sparse_adagrad_op",
        # PS shard device paths
        "shard_lookup",
        "shard_update",
        "try_update",
        # tiered-cache device paths
        "prefetch",
        "merged",
        "lookup",
        "update",
        # algorithm lifecycle hooks that stack/scatter device arrays
        "on_join",
        "on_join_flat",
        "land_flat",
        "land_elastic",
        "_shadow_round",
        # a whole background sync round is kernel dispatch wholesale
        "_round_over_active",
        # building a CachedStore moves whole tables host->device
        "CachedStore",
    }
)

# Call tails that look like kernel/blocking names but are known-safe.
KERNEL_ALLOW_PREFIXES = frozenset({"os.path", "dict", "meta", "total", "info"})

# Blocking primitives: sleeping, joining a thread, waiting on a barrier or
# condition (waiting on the *held* condition is legal — Condition.wait
# releases its lock while blocked).
BLOCKING_QUALNAMES = frozenset({"time.sleep"})
BLOCKING_METHODS = frozenset({"join", "wait"})

# Method names that mutate their receiver in place. Used both to decide a
# field is "mutable" for the unannotated-shared check and to flag in-place
# mutation through swap-published fields. `put`/`get`/`join` (queue.Queue)
# and `note`/`observe` (domain verbs) are deliberately absent.
MUTATOR_METHODS = frozenset(
    {
        "append",
        "appendleft",
        "extend",
        "insert",
        "remove",
        "pop",
        "popleft",
        "clear",
        "add",
        "discard",
        "update",
        "setdefault",
        "set",
        "sort",
        "reverse",
    }
)

# Keyword names whose callable arguments become thread entry points
# (Supervisor.register(..., restart=..., on_give_up=...),
# SupervisorConfig(tick=...), Thread(target=...)).
CALLABLE_KWARGS = frozenset({"target", "restart", "tick", "on_give_up"})

"""Machine-checked concurrency contracts for the free-threaded sync stack.

ShadowSync is a deliberately racy program: Hogwild lock-free PS reads
coexist with lock-guarded meters, Condition barriers, and atomically
swap-published immutable states. The invariants that make that safe used
to live only in comments; this package makes them machine-checked.

- ``contracts``    — the annotation grammar (``# guarded-by: <lock>`` et
  al.), the per-class shared-state registry, and the kernel/blocking call
  tables the checkers consult.
- ``static_check`` — an AST pass over ``src/repro`` enforcing guarded-by,
  swap-publish, and no-blocking-under-lock (DESIGN.md §12).
- ``lockdep``      — a test-time instrumented ``threading.Lock`` /
  ``Condition`` that records the acquisition graph, fails on lock-order
  cycles, and catches held-lock blocking calls the static pass can't see.

Run the static pass via ``scripts/check_concurrency.py`` (wired into the
CI ``analyze`` job).
"""

from repro.analysis.contracts import (
    Directive,
    Violation,
    parse_directives,
)
from repro.analysis.static_check import check_path, check_source

__all__ = [
    "Directive",
    "Violation",
    "parse_directives",
    "check_path",
    "check_source",
]

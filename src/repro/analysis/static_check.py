"""AST static analysis enforcing the DESIGN.md §12 concurrency contracts.

Three passes over every function in a file, sharing one scope model:

1. **guarded-by / swap-publish** — every access to an annotated field is
   classified (load / store / elem-store / elem-aug / attr-mutate /
   deep-mutate / mutate-call / aug) and checked against its contract and
   the set of locks held at that point (``with`` blocks, linear
   ``acquire()``/``release()`` tracking, and ``# holds-lock`` caller
   obligations).
2. **no-blocking-under-lock** — inside any held-lock region, calls that
   dispatch device work (jit-bound callables, ``jnp.``/``jax.`` paths,
   the ``KERNEL_CALLS`` registry) or block (``time.sleep``, thread
   ``join``, ``wait`` on anything but the held condition) are violations.
3. **unannotated shared state** — thread entry points are discovered from
   ``Thread(target=...)`` / supervisor-callback call sites (plus, for
   ``SHARED_CLASSES``, every public method); a mutable attribute or
   closure variable reachable from >= 2 entry points with no contract is
   a violation.

Known, documented limitations (the lockdep runtime harness covers the
gap): only ``self.<attr>`` and closure-variable accesses are tracked —
mutation through a local alias (``st = self._slots[i]; st.state = x``)
is invisible; blocking detection is registry-based, not effect-inferred;
"freshly built" for swap-publish rebinds is convention, not checked.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.contracts import (
    BLOCKING_QUALNAMES,
    CALLABLE_KWARGS,
    FieldContract,
    KERNEL_CALLS,
    MUTATOR_METHODS,
    SHARED_CLASSES,
    Violation,
    parse_directives,
)

# threading constructors that make a with-able lock, and the wider set of
# internally-synchronized primitives exempt from the shared-state check.
_LOCK_CTORS = frozenset({"Lock", "RLock", "Condition"})
_SYNC_CTORS = _LOCK_CTORS | frozenset(
    {"Event", "Semaphore", "BoundedSemaphore", "Barrier", "Queue", "SimpleQueue"}
)

_NONLOAD = frozenset(
    {"store", "aug", "elem-store", "elem-aug", "attr-mutate", "deep-mutate", "mutate-call"}
)
# Kinds that mutate *through* the field value rather than rebinding it.
_IN_PLACE = frozenset({"attr-mutate", "deep-mutate", "mutate-call"})


def _dotted(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _norm(name: str) -> str:
    return name[5:] if name.startswith("self.") else name


@dataclass
class Access:
    name: str
    kind: str
    line: int
    held: Tuple[str, ...]
    scope: "_Scope"
    stmt_span: Tuple[int, int]
    is_self: bool
    owner: Optional["_Scope"] = None  # resolved later for closure vars


class _ClassInfo:
    def __init__(self, name: str):
        self.name = name
        self.locks: Set[str] = set()  # attr names bound to Lock/RLock/Condition
        self.sync_attrs: Set[str] = set()  # any threading/queue primitive attr
        self.jit_attrs: Set[str] = set()  # attrs bound from jax.jit(...)
        self.thread_attrs: Set[str] = set()  # attrs bound from threading.Thread(...)
        self.methods: Dict[str, "_Scope"] = {}
        self.contracts: Dict[str, FieldContract] = {}
        self.decl_spans: Dict[str, Set[Tuple[int, int]]] = {}
        self.creates_threads = False


class _Scope:
    """One function (method, nested function, or module-level def)."""

    def __init__(self, node, qual: str, cls: Optional[_ClassInfo], parent: Optional["_Scope"]):
        self.node = node
        self.qual = qual
        self.cls = cls
        self.parent = parent
        self.children: Dict[str, "_Scope"] = {}
        self.assumed: Tuple[str, ...] = ()  # holds-lock
        self.block_waived = False  # lock-blocking: ok on the def
        self.local_locks: Set[str] = set()
        self.local_sync: Set[str] = set()
        self.local_threads: Set[str] = set()
        self.local_names: Set[str] = set()
        self.nonlocals: Set[str] = set()
        self.calls: Set["_Scope"] = set()
        self.thread_refs: List["_Scope"] = []  # resolved thread-entry callables
        self.accesses: List[Access] = []
        self.var_contracts: Dict[str, FieldContract] = {}
        self.var_decl_spans: Dict[str, Set[Tuple[int, int]]] = {}

    @property
    def is_method(self) -> bool:
        return self.cls is not None and self.parent is None

    def resolve_var(self, name: str) -> Optional["_Scope"]:
        """Owning function scope for a Name access made inside this scope."""
        if name in self.local_names and name not in self.nonlocals:
            return self
        s = self.parent
        while s is not None:
            if name in s.local_names and name not in s.nonlocals:
                return s
            s = s.parent
        return None

    def known_lock(self, name: str) -> bool:
        if self.cls and name in self.cls.locks:
            return True
        s: Optional[_Scope] = self
        while s is not None:
            if name in s.local_locks:
                return True
            s = s.parent
        return False


class _FileAnalysis:
    def __init__(self, source: str, path: str, registered: Dict[str, str]):
        self.source = source
        self.path = path
        self.registered = registered
        self.violations: List[Violation] = []
        self.classes: Dict[str, _ClassInfo] = {}
        self.scopes: List[_Scope] = []
        self.module_funcs: Dict[str, _Scope] = {}
        self.hogwild_spans: List[Tuple[int, int]] = []
        self.blocking_spans: List[Tuple[int, int]] = []
        self.stmt_scope: Dict[int, Tuple[ast.stmt, Optional[_ClassInfo], Optional[_Scope]]] = {}
        self.all_stmts: List[Tuple[ast.stmt, Optional[_ClassInfo], Optional[_Scope]]] = []

    # -- helpers ----------------------------------------------------------

    def err(self, code: str, line: int, msg: str) -> None:
        self.violations.append(Violation(code, self.path, line, msg))

    def _span(self, node: ast.AST) -> Tuple[int, int]:
        return (node.lineno, getattr(node, "end_lineno", node.lineno))

    def waived(self, line: int, spans: List[Tuple[int, int]]) -> bool:
        return any(a <= line <= b for a, b in spans)

    # -- phase 1: build scopes --------------------------------------------

    def build(self) -> None:
        try:
            tree = ast.parse(self.source)
        except SyntaxError as e:  # pragma: no cover - tree is syntax-clean in CI
            self.err("CT01", e.lineno or 1, f"syntax error: {e.msg}")
            return
        self.tree = tree
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._build_func(node, node.name, None, None)
            elif isinstance(node, ast.ClassDef):
                ci = _ClassInfo(node.name)
                self.classes[node.name] = ci
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        sc = self._build_func(sub, f"{node.name}.{sub.name}", ci, None)
                        ci.methods[sub.name] = sc

    def _build_func(
        self,
        node,
        qual: str,
        cls: Optional[_ClassInfo],
        parent: Optional[_Scope],
    ) -> _Scope:
        sc = _Scope(node, qual, cls, parent)
        self.scopes.append(sc)
        if parent is not None:
            parent.children[node.name] = sc
        elif cls is None:
            self.module_funcs[node.name] = sc
        for arg in (node.args.posonlyargs + node.args.args + node.args.kwonlyargs):
            sc.local_names.add(arg.arg)
        if node.args.vararg:
            sc.local_names.add(node.args.vararg.arg)
        if node.args.kwarg:
            sc.local_names.add(node.args.kwarg.arg)
        self._index_stmts(node.body, cls, sc)
        for stmt in ast.walk(node):
            if stmt is node:
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # direct children only; deeper ones are built recursively
                if self._enclosing_func(stmt, node) is node:
                    self._build_func(stmt, f"{qual}.{stmt.name}", cls, sc)
        self._collect_bindings(sc)
        return sc

    def _enclosing_func(self, target: ast.AST, root: ast.AST) -> Optional[ast.AST]:
        """The innermost def in ``root`` that contains ``target`` (not target)."""
        found: List[ast.AST] = []

        def rec(n: ast.AST, stack: List[ast.AST]) -> None:
            if n is target:
                found.extend(stack)
                return
            is_def = isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
            if is_def:
                stack = stack + [n]
            for c in ast.iter_child_nodes(n):
                rec(c, stack)

        rec(root, [root] if isinstance(root, (ast.FunctionDef, ast.AsyncFunctionDef)) else [])
        return found[-1] if found else None

    def _index_stmts(self, body: Iterable[ast.stmt], cls, sc) -> None:
        for s in body:
            self.all_stmts.append((s, cls, sc))
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # inner statements indexed when that scope is built
            for sub_body in (
                getattr(s, "body", None),
                getattr(s, "orelse", None),
                getattr(s, "finalbody", None),
            ):
                if isinstance(sub_body, list):
                    self._index_stmts(sub_body, cls, sc)
            for h in getattr(s, "handlers", []) or []:
                self._index_stmts(h.body, cls, sc)

    def _collect_bindings(self, sc: _Scope) -> None:
        """Locals, nonlocals, lock/jit/thread bindings for one scope."""
        own = self._own_statements(sc)
        for s in own:
            if isinstance(s, ast.Nonlocal):
                sc.nonlocals.update(s.names)
            elif isinstance(s, ast.Global):
                sc.nonlocals.update(s.names)  # treat like non-local: not ours
            for sub in self._walk_no_defs(s):
                targets: List[ast.expr] = []
                value: Optional[ast.expr] = None
                if isinstance(sub, ast.Assign):
                    targets, value = sub.targets, sub.value
                elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                    targets, value = [sub.target], sub.value
                elif isinstance(sub, (ast.For, ast.comprehension)):
                    targets = [sub.target]
                elif isinstance(sub, ast.withitem) and sub.optional_vars is not None:
                    targets = [sub.optional_vars]
                elif isinstance(sub, ast.ExceptHandler) and sub.name:
                    sc.local_names.add(sub.name)
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            sc.local_names.add(n.id)
                if value is None or not isinstance(value, ast.Call):
                    continue
                ctor = _dotted(value.func) or ""
                tail = ctor.rsplit(".", 1)[-1]
                for t in targets:
                    name = _dotted(t)
                    if name is None:
                        continue
                    if name.startswith("self.") and sc.cls is not None:
                        attr = _norm(name)
                        if "." in attr:
                            continue
                        if tail in _LOCK_CTORS:
                            sc.cls.locks.add(attr)
                        if tail in _SYNC_CTORS:
                            sc.cls.sync_attrs.add(attr)
                        if tail == "jit" or ctor.endswith("jax.jit"):
                            sc.cls.jit_attrs.add(attr)
                        if tail == "Thread":
                            sc.cls.thread_attrs.add(attr)
                    elif isinstance(t, ast.Name):
                        if tail in _LOCK_CTORS:
                            sc.local_locks.add(t.id)
                        if tail in _SYNC_CTORS:
                            sc.local_sync.add(t.id)
                        if tail == "Thread":
                            sc.local_threads.add(t.id)

    def _own_statements(self, sc: _Scope) -> List[ast.stmt]:
        """Statements lexically in ``sc`` but not in a nested def."""
        out: List[ast.stmt] = []

        def rec(body: Iterable[ast.stmt]) -> None:
            for s in body:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                out.append(s)
                for sub_body in (
                    getattr(s, "body", None),
                    getattr(s, "orelse", None),
                    getattr(s, "finalbody", None),
                ):
                    if isinstance(sub_body, list):
                        rec(sub_body)
                for h in getattr(s, "handlers", []) or []:
                    rec(h.body)

        rec(sc.node.body)
        return out

    def _walk_no_defs(self, node: ast.AST) -> Iterable[ast.AST]:
        stack = [node]
        while stack:
            n = stack.pop()
            yield n
            for c in ast.iter_child_nodes(n):
                if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    continue
                stack.append(c)

    # -- phase 2: bind directives -----------------------------------------

    def bind_directives(self) -> None:
        directives = parse_directives(self.source, self.path)
        # map: every def line -> scope, for holds-lock / lock-blocking on defs
        def_by_line = {sc.node.lineno: sc for sc in self.scopes}
        for d in directives:
            target = self._stmt_for(d)
            sc_def = def_by_line.get(target[0].lineno) if target else None
            if d.kind == "holds-lock":
                if sc_def is None:
                    self.err("CT01", d.line, "holds-lock must annotate a def line")
                else:
                    sc_def.assumed = sc_def.assumed + (d.lock,)
                continue
            if d.kind == "lock-blocking":
                if not d.is_ok():
                    self.err("CT01", d.line, f"lock-blocking must say 'ok', got '{d.arg}'")
                elif sc_def is not None:
                    sc_def.block_waived = True
                elif target is not None:
                    self.blocking_spans.append(self._span(target[0]))
                else:
                    self.err("CT01", d.line, "lock-blocking bound to no statement")
                continue
            # field-shaped directives
            decl = self._as_declaration(target) if target else None
            if decl is not None:
                fc_map, span_map, key = decl
                fc = fc_map.setdefault(key, FieldContract(key))
                conflict = fc.merge(d)
                if conflict:
                    self.err("CT01", d.line, conflict)
                span_map.setdefault(key, set()).add(self._span(target[0]))
            elif d.kind == "hogwild-race":
                if target is None:
                    self.err("CT01", d.line, "hogwild-race waiver bound to no statement")
                elif not d.is_ok():
                    self.err("CT01", d.line, f"hogwild-race must say 'ok', got '{d.arg}'")
                else:
                    self.hogwild_spans.append(self._span(target[0]))
            else:
                self.err(
                    "CT01",
                    d.line,
                    f"'{d.kind}' must annotate a simple assignment to a field "
                    "(self.<attr> or a local variable declaration)",
                )

    def _stmt_for(self, d) -> Optional[Tuple[ast.stmt, Optional[_ClassInfo], Optional[_Scope]]]:
        if d.trailing:
            best = None
            for item in self.all_stmts:
                s = item[0]
                a, b = self._span(s)
                if a <= d.line <= b:
                    if best is None or (b - a) < (self._span(best[0])[1] - self._span(best[0])[0]):
                        best = item
            # a directive trailing a def line binds to the def statement
            if best is None:
                for sc in self.scopes:
                    a, b = self._span(sc.node)
                    if a <= d.line <= b:
                        return (sc.node, sc.cls, sc.parent)
            return best
        nxt = None
        for item in self.all_stmts:
            if item[0].lineno > d.line:
                if nxt is None or item[0].lineno < nxt[0].lineno:
                    nxt = item
        for sc in self.scopes:
            if sc.node.lineno > d.line and (nxt is None or sc.node.lineno < nxt[0].lineno):
                nxt = (sc.node, sc.cls, sc.parent)
        return nxt

    def _as_declaration(self, item):
        """If the statement is a simple single-target assignment, return the
        (contract-map, decl-span-map, field-name) triple it declares into."""
        s, cls, sc = item
        target: Optional[ast.expr] = None
        if isinstance(s, ast.Assign) and len(s.targets) == 1:
            target = s.targets[0]
        elif isinstance(s, ast.AnnAssign):
            target = s.target
        if target is None:
            return None
        if (
            isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"
        ):
            owner_cls = cls if cls is not None else (sc.cls if sc else None)
            if owner_cls is None:
                return None
            return (owner_cls.contracts, owner_cls.decl_spans, target.attr)
        if isinstance(target, ast.Name) and sc is not None:
            return (sc.var_contracts, sc.var_decl_spans, target.id)
        return None

    # -- phase 3: walk function bodies ------------------------------------

    def walk_all(self) -> None:
        for sc in self.scopes:
            _BodyWalker(self, sc).run()

    # -- phase 4: contract enforcement ------------------------------------

    def enforce(self) -> None:
        seen: Set[Tuple[str, int, str]] = set()
        for sc in self.scopes:
            for acc in sc.accesses:
                fc = self._contract_for(acc)
                if fc is None:
                    continue
                if acc.stmt_span in self._decl_spans_for(acc):
                    continue  # the annotated declaration/publish site itself
                if acc.scope.is_method and acc.scope.node.name in ("__init__", "__post_init__"):
                    continue  # constructor runs before the object is published
                if fc.swap_published and acc.kind in _IN_PLACE:
                    self.err(
                        "SP01",
                        acc.line,
                        f"'{acc.name}' is swap-published but mutated in place "
                        f"({acc.kind}); rebind it to a freshly built value",
                    )
                    continue
                if fc.swap_published and not fc.swap_elements and acc.kind in (
                    "elem-store",
                    "elem-aug",
                ):
                    self.err(
                        "SP01",
                        acc.line,
                        f"'{acc.name}' is swap-published (whole-value): element "
                        "assignment is in-place mutation; declare "
                        "'swap-published: elements' if slots are the publish unit",
                    )
                    continue
                if fc.hogwild_ok:
                    continue  # deliberately lock-free (SP01 above still applies)
                lock = fc.guarded_by
                write_lock = fc.guarded_writes
                needs = None
                if lock is not None:
                    needs = lock
                elif write_lock is not None and acc.kind in _NONLOAD:
                    needs = write_lock
                if needs is not None and needs not in acc.held:
                    if self.waived(acc.line, self.hogwild_spans):
                        continue
                    key = ("GB01", acc.line, acc.name)
                    if key in seen:
                        continue
                    seen.add(key)
                    self.err(
                        "GB01",
                        acc.line,
                        f"'{acc.name}' requires lock '{needs}' "
                        f"(held: {list(acc.held) or 'none'}) for {acc.kind}",
                    )

    def _contract_for(self, acc: Access) -> Optional[FieldContract]:
        if acc.is_self:
            cls = acc.scope.cls
            return cls.contracts.get(acc.name) if cls else None
        if acc.owner is not None:
            return acc.owner.var_contracts.get(acc.name)
        return None

    def _decl_spans_for(self, acc: Access) -> Set[Tuple[int, int]]:
        if acc.is_self and acc.scope.cls:
            return acc.scope.cls.decl_spans.get(acc.name, set())
        if acc.owner is not None:
            return acc.owner.var_decl_spans.get(acc.name, set())
        return set()

    # -- phase 5: shared-state check --------------------------------------

    def shared_check(self) -> None:
        roots: Dict[_Scope, str] = {}
        for sc in self.scopes:
            for ref in sc.thread_refs:
                roots[ref] = ref.qual
                if sc.cls is not None:
                    sc.cls.creates_threads = True
        for cls in self.classes.values():
            if cls.name in self.registered:
                for name, m in cls.methods.items():
                    roots.setdefault(m, m.qual)
        reach: Dict[str, Set[_Scope]] = {}
        for root_sc, label in roots.items():
            reach[label] = self._closure({root_sc})
        # the "<main>" context: anything callable from outside a thread —
        # public surface = top-level methods and module-level functions.
        mains = {m for c in self.classes.values() for m in c.methods.values()}
        mains |= set(self.module_funcs.values())
        mains -= set(roots)  # a pure thread body isn't main-callable
        reach["<main>"] = self._closure(mains)

        def contexts_of(scopes: Iterable[_Scope]) -> Set[str]:
            out: Set[str] = set()
            for label, r in reach.items():
                if any(s in r for s in scopes):
                    out.add(label)
            return out

        # self attributes, grouped per class
        by_field: Dict[Tuple[str, str], List[Access]] = {}
        for sc in self.scopes:
            for acc in sc.accesses:
                if acc.is_self and sc.cls is not None:
                    by_field.setdefault((sc.cls.name, acc.name), []).append(acc)
        for (cls_name, fname), accs in sorted(by_field.items()):
            cls = self.classes[cls_name]
            if not (cls.creates_threads or cls_name in self.registered):
                continue
            if fname in cls.sync_attrs or fname in cls.thread_attrs or fname in cls.jit_attrs:
                continue
            fc = cls.contracts.get(fname)
            if fc is not None and fc.annotated:
                continue
            mutating = [
                a
                for a in accs
                if a.kind in _NONLOAD
                and not (
                    a.scope.is_method and a.scope.node.name in ("__init__", "__post_init__")
                )
            ]
            if not mutating:
                continue
            ctx = contexts_of({a.scope for a in accs})
            if len(ctx) >= 2:
                first = min(a.line for a in mutating)
                self.err(
                    "SH01",
                    first,
                    f"'{cls_name}.{fname}' is mutated and reached from "
                    f"{sorted(ctx)} but has no concurrency annotation "
                    "(guarded-by / swap-published / hogwild-race: ok)",
                )
        # closure variables, grouped per owning function
        by_var: Dict[Tuple[_Scope, str], List[Access]] = {}
        for sc in self.scopes:
            for acc in sc.accesses:
                if not acc.is_self and acc.owner is not None:
                    by_var.setdefault((acc.owner, acc.name), []).append(acc)
        for (owner, vname), accs in by_var.items():
            if vname in owner.local_locks or vname in owner.local_sync:
                continue
            if vname in owner.local_threads:
                continue
            fc = owner.var_contracts.get(vname)
            if fc is not None and fc.annotated:
                continue
            nested_mut = [a for a in accs if a.scope is not owner and a.kind in _NONLOAD]
            if not nested_mut:
                continue
            ctx = contexts_of({a.scope for a in accs})
            if len(ctx) >= 2:
                first = min(a.line for a in nested_mut)
                self.err(
                    "SH01",
                    first,
                    f"closure variable '{vname}' of {owner.qual}() is mutated "
                    f"from a nested thread body and reached from {sorted(ctx)} "
                    "but has no concurrency annotation",
                )

    def _closure(self, start: Set[_Scope]) -> Set[_Scope]:
        seen = set(start)
        work = list(start)
        while work:
            sc = work.pop()
            for callee in sc.calls:
                if callee not in seen:
                    seen.add(callee)
                    work.append(callee)
        return seen

    # -- driver ------------------------------------------------------------

    def run(self) -> List[Violation]:
        self.build()
        if not hasattr(self, "tree"):
            return self.violations
        self.bind_directives()
        self.walk_all()
        # resolve closure-var owners now that all scopes exist
        for sc in self.scopes:
            for acc in sc.accesses:
                if not acc.is_self:
                    acc.owner = sc.resolve_var(acc.name)
        self.enforce()
        self.shared_check()
        self.violations.sort(key=lambda v: (v.path, v.line, v.code))
        return self.violations


class _BodyWalker:
    """Walk one function body tracking held locks; record accesses + BL01."""

    def __init__(self, fa: _FileAnalysis, sc: _Scope):
        self.fa = fa
        self.sc = sc
        self.held: List[str] = list(sc.assumed)
        self.manual: List[str] = []

    def run(self) -> None:
        self._body(self.sc.node.body)

    def _all_held(self) -> Tuple[str, ...]:
        return tuple(self.held + self.manual)

    def _body(self, stmts: Iterable[ast.stmt]) -> None:
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return
        if isinstance(s, ast.With):
            acquired: List[str] = []
            for item in s.items:
                self._exprs(item.context_expr, s)
                name = _dotted(item.context_expr)
                if name is not None:
                    norm = _norm(name)
                    if self.sc.known_lock(norm):
                        acquired.append(norm)
            self.held.extend(acquired)
            try:
                self._body(s.body)
            finally:
                if acquired:
                    del self.held[-len(acquired) :]
            return
        if isinstance(s, ast.If):
            self._exprs(s.test, s)
            self._scan_acquire(s.test)
            self._body(s.body)
            self._body(s.orelse)
            return
        if isinstance(s, ast.While):
            self._exprs(s.test, s)
            self._scan_acquire(s.test)
            self._body(s.body)
            self._body(s.orelse)
            return
        if isinstance(s, ast.For):
            self._exprs(s.iter, s)
            self._target(s.target, s, aug=False)
            self._body(s.body)
            self._body(s.orelse)
            return
        if isinstance(s, ast.Try):
            self._body(s.body)
            for h in s.handlers:
                self._body(h.body)
            self._body(s.orelse)
            self._body(s.finalbody)
            return
        # simple statement
        self._collect(s)
        self._scan_acquire(s)

    # -- access collection -------------------------------------------------

    def _collect(self, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            for t in s.targets:
                self._target(t, s, aug=False)
            self._exprs(s.value, s)
        elif isinstance(s, ast.AugAssign):
            self._target(s.target, s, aug=True)
            self._exprs(s.value, s)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self._target(s.target, s, aug=False)
                self._exprs(s.value, s)
        elif isinstance(s, ast.Delete):
            for t in s.targets:
                self._target(t, s, aug=False)
        else:
            self._exprs(s, s)

    def _rec(self, name: str, kind: str, line: int, stmt: ast.stmt, is_self: bool) -> None:
        self.sc.accesses.append(
            Access(
                name=name,
                kind=kind,
                line=line,
                held=self._all_held(),
                scope=self.sc,
                stmt_span=self.fa._span(stmt),
                is_self=is_self,
            )
        )

    def _self_attr(self, node: ast.AST) -> Optional[str]:
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            return node.attr
        return None

    def _target(self, t: ast.expr, stmt: ast.stmt, aug: bool) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._target(e, stmt, aug)
            return
        if isinstance(t, ast.Starred):
            self._target(t.value, stmt, aug)
            return
        attr = self._self_attr(t)
        if attr is not None:
            self._rec(attr, "aug" if aug else "store", t.lineno, stmt, True)
            return
        if isinstance(t, ast.Name):
            self._rec(t.id, "aug" if aug else "store", t.lineno, stmt, False)
            return
        if isinstance(t, ast.Subscript):
            base = t.value
            battr = self._self_attr(base)
            if battr is not None:
                self._rec(battr, "elem-aug" if aug else "elem-store", t.lineno, stmt, True)
            elif isinstance(base, ast.Name):
                self._rec(base.id, "elem-aug" if aug else "elem-store", t.lineno, stmt, False)
            else:
                root = self._mutation_root(base)
                if root is not None:
                    self._rec(root[0], "deep-mutate", t.lineno, stmt, root[1])
                self._exprs(base, stmt)
            self._exprs(t.slice, stmt)
            return
        if isinstance(t, ast.Attribute):
            base = t.value
            battr = self._self_attr(base)
            if battr is not None:
                self._rec(battr, "attr-mutate", t.lineno, stmt, True)
            elif isinstance(base, ast.Name):
                self._rec(base.id, "attr-mutate", t.lineno, stmt, False)
            else:
                root = self._mutation_root(base)
                if root is not None:
                    self._rec(root[0], "deep-mutate", t.lineno, stmt, root[1])
                self._exprs(base, stmt)
            return
        self._exprs(t, stmt)

    def _mutation_root(self, node: ast.AST) -> Optional[Tuple[str, bool]]:
        """Peel subscripts/attrs down to a self.<f> or Name root."""
        while isinstance(node, (ast.Subscript, ast.Attribute)):
            attr = self._self_attr(node)
            if attr is not None:
                return (attr, True)
            node = node.value
        if isinstance(node, ast.Name):
            if node.id == "self":
                return None
            return (node.id, False)
        return None

    def _exprs(self, node: ast.AST, stmt: ast.stmt) -> None:
        for n in self.fa._walk_no_defs(node):
            if isinstance(n, ast.Call):
                self._call(n, stmt)
            attr = self._self_attr(n)
            if attr is not None and isinstance(n.ctx, ast.Load):
                # skip if this load is the receiver of a mutator call —
                # _call already recorded the mutation
                self._rec(attr, "load", n.lineno, stmt, True)
            elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                self._rec(n.id, "load", n.lineno, stmt, False)

    def _call(self, call: ast.Call, stmt: ast.stmt) -> None:
        func = call.func
        dotted = _dotted(func) or ""
        tail = dotted.rsplit(".", 1)[-1] if dotted else ""
        # thread entry points + call-graph edges
        self._edges(call, dotted, tail)
        # mutation through a mutator method
        if isinstance(func, ast.Attribute) and func.attr in MUTATOR_METHODS:
            root = self._mutation_root(func.value)
            if root is not None:
                name, is_self = root
                direct = self._self_attr(func.value)
                kind = "mutate-call" if (direct or isinstance(func.value, ast.Name)) else (
                    "deep-mutate"
                )
                self._rec(name, kind, call.lineno, stmt, is_self)
        # no-blocking-under-lock
        if self._all_held() and not self.sc.block_waived:
            if not self.fa.waived(call.lineno, self.fa.blocking_spans):
                self._check_blocking(call, dotted, tail)

    def _edges(self, call: ast.Call, dotted: str, tail: str) -> None:
        sc = self.sc

        def resolve(ref: ast.expr) -> Optional[_Scope]:
            if isinstance(ref, ast.Name):
                s: Optional[_Scope] = sc
                while s is not None:
                    if ref.id in s.children:
                        return s.children[ref.id]
                    s = s.parent
                return self.fa.module_funcs.get(ref.id)
            rattr = self._self_attr(ref)
            if rattr is not None and sc.cls is not None:
                return sc.cls.methods.get(rattr)
            return None

        callee = resolve(call.func)
        if callee is not None:
            sc.calls.add(callee)
        grab_all = tail in ("Thread", "register")
        for kw in call.keywords:
            if kw.arg in CALLABLE_KWARGS or (grab_all and kw.arg is not None):
                ref = resolve(kw.value)
                if ref is not None:
                    sc.thread_refs.append(ref)
        if grab_all:
            for a in call.args:
                ref = resolve(a)
                if ref is not None:
                    sc.thread_refs.append(ref)

    def _check_blocking(self, call: ast.Call, dotted: str, tail: str) -> None:
        held = self._all_held()
        line = call.lineno

        def hit(why: str) -> None:
            self.fa.err(
                "BL01",
                line,
                f"{why} while holding {list(held)} — move it outside the "
                "critical section or waive with '# lock-blocking: ok — <why>'",
            )

        if dotted in BLOCKING_QUALNAMES:
            hit(f"blocking call {dotted}()")
            return
        base = None
        if isinstance(call.func, ast.Attribute):
            base = call.func.value
        if tail == "wait":
            bname = _norm(_dotted(base) or "") if base is not None else ""
            if bname and bname == held[-1]:
                return  # Condition.wait on the held condition releases it
            hit(f"wait on '{bname or dotted}'")
            return
        if tail == "join":
            if isinstance(base, ast.Constant):
                return  # str.join
            bname = _norm(_dotted(base) or "") if base is not None else ""
            if bname.startswith("os.path"):
                return
            is_thread = False
            if bname and self.sc.cls is not None and bname in self.sc.cls.thread_attrs:
                is_thread = True
            s: Optional[_Scope] = self.sc
            while s is not None and not is_thread:
                if bname in s.local_threads:
                    is_thread = True
                s = s.parent
            if is_thread:
                hit(f"thread join on '{bname}'")
            return
        if dotted.startswith("jnp.") or dotted.startswith("jax."):
            hit(f"device dispatch {dotted}()")
            return
        if self.sc.cls is not None and self._self_attr(call.func) in self.sc.cls.jit_attrs:
            hit(f"jit-compiled call self.{self._self_attr(call.func)}()")
            return
        if tail in KERNEL_CALLS:
            first = dotted.split(".", 1)[0]
            if first in ("np", "numpy", "math", "os", "meta", "info", "total", "d"):
                return
            hit(f"kernel/device call {dotted or tail}()")
            return

    def _scan_acquire(self, node: ast.AST) -> None:
        for n in self.fa._walk_no_defs(node):
            if not isinstance(n, ast.Call) or not isinstance(n.func, ast.Attribute):
                continue
            if n.func.attr not in ("acquire", "release"):
                continue
            name = _dotted(n.func.value)
            if name is None:
                continue
            norm = _norm(name)
            if not self.sc.known_lock(norm):
                continue
            if n.func.attr == "acquire":
                self.manual.append(norm)
            elif norm in self.manual:
                self.manual.remove(norm)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def check_source(
    source: str,
    path: str = "<string>",
    registered: Optional[Dict[str, str]] = None,
) -> List[Violation]:
    """Run all contract passes over one source string."""
    reg = SHARED_CLASSES if registered is None else registered
    return _FileAnalysis(source, path, reg).run()


def check_path(
    root: str,
    registered: Optional[Dict[str, str]] = None,
) -> List[Violation]:
    """Run the checker over every .py file under ``root``.

    ``src/repro/analysis`` itself is excluded: the checker toolkit is not
    part of the free-threaded training stack (its own concurrency is
    exercised by the lockdep test suite instead).
    """
    out: List[Violation] = []
    if os.path.isfile(root):
        with open(root, encoding="utf-8") as f:
            return check_source(f.read(), root, registered)
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in ("__pycache__", "analysis")]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                src = f.read()
            out.extend(check_source(src, path, registered))
    out.sort(key=lambda v: (v.path, v.line, v.code))
    return out

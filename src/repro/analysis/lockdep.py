"""Runtime lock-order and blocking-under-lock detection (lockdep).

The static pass in ``static_check`` is intraprocedural and registry-based;
this harness covers what it can't see. Under ``instrument()``,
``threading.Lock`` / ``threading.Condition`` construct instrumented
wrappers that report into a :class:`LockGraph`:

- **acquisition graph** — every acquisition made while other locks are
  held adds an edge (held-site -> acquired-site). Locks are identified by
  *creation site* (file:line), so the per-instance locks of N shard
  stores collapse into one node and an inversion between two instances of
  the same class is still a cycle. A new edge that closes a cycle raises
  :class:`LockOrderError` in the acquiring thread immediately — the
  inversion is caught even when the interleaving never actually
  deadlocks.
- **held-lock blocking** — ``time.sleep`` and ``Thread.join`` are patched
  to fail if the calling thread holds any instrumented lock.
- **stall detection** — a thread sitting in ``Condition.wait`` (or a
  blocking ``acquire``) keeps its *first* blocked timestamp until it
  finally exits the critical section, so a predicate loop that re-waits
  forever (the PR 5 demote-mid-wait barrier bug) shows up in
  :meth:`LockGraph.stalled` no matter how short each individual timed
  wait is.

Typical use in a test::

    with lockdep.instrument() as graph:
        run_threaded_scenario()
    graph.assert_clean()
"""

from __future__ import annotations

import contextlib
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

__all__ = [
    "LockdepError",
    "LockOrderError",
    "BlockedUnderLockError",
    "LockGraph",
    "DepLock",
    "DepCondition",
    "instrument",
]


class LockdepError(AssertionError):
    """Base for lockdep failures (AssertionError so pytest renders nicely)."""


class LockOrderError(LockdepError):
    """A lock acquisition closed a cycle in the acquisition-order graph."""


class BlockedUnderLockError(LockdepError):
    """sleep/join was called while holding an instrumented lock."""


def _creation_site(skip_files: Tuple[str, ...]) -> str:
    """First stack frame outside this module / threading internals."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not fn.endswith(skip_files):
            return f"{fn.rsplit('/', 1)[-1]}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


_SKIP_FILES = ("lockdep.py", "threading.py", "queue.py")

# Captured before instrument() can ever patch the module attributes —
# the wrappers themselves must build real primitives.
_REAL_LOCK = threading.Lock
_REAL_CONDITION = threading.Condition


@dataclass
class _Blocked:
    site: str
    kind: str  # "acquire" | "cond-wait"
    since: float
    thread: str


class LockGraph:
    """Shared recorder for every instrumented lock in one harness session."""

    def __init__(self) -> None:
        self._mu = threading.Lock()  # guards every field below
        self.edges: Dict[Tuple[str, str], str] = {}  # (a, b) -> recording thread
        self.sites: Set[str] = set()
        self.violations: List[str] = []
        self._held = threading.local()
        self._blocked: Dict[int, _Blocked] = {}  # thread id -> current block
        # thread id -> {cond site -> first wait ts inside the current
        # critical section}; survives timed re-waits, cleared on release
        self._wait_epoch: Dict[int, Dict[str, float]] = {}

    # -- held-lock bookkeeping (per thread; no lock needed) ----------------

    def held(self) -> List["DepLock"]:
        if not hasattr(self._held, "stack"):
            self._held.stack = []
        return self._held.stack

    def held_sites(self) -> List[str]:
        return [lk.site for lk in self.held()]

    # -- events ------------------------------------------------------------

    def on_attempt(self, lock: "DepLock", kind: str = "acquire") -> None:
        tid = threading.get_ident()
        entry = _Blocked(lock.site, kind, time.monotonic(), threading.current_thread().name)
        with self._mu:
            self._blocked[tid] = entry
            if kind == "cond-wait":
                self._wait_epoch.setdefault(tid, {}).setdefault(lock.site, entry.since)

    def on_acquired(self, lock: "DepLock") -> None:
        tid = threading.get_ident()
        new_edges: List[Tuple[str, str]] = []
        with self._mu:
            self._blocked.pop(tid, None)
            self.sites.add(lock.site)
            for h in self.held():
                e = (h.site, lock.site)
                if e not in self.edges:
                    self.edges[e] = threading.current_thread().name
                    new_edges.append(e)
            cycle = self._find_cycle(lock.site) if new_edges else None
            if cycle is not None:
                msg = (
                    "lock-order cycle: "
                    + " -> ".join(cycle)
                    + f" (closed by {threading.current_thread().name})"
                )
                self.violations.append(msg)
        self.held().append(lock)
        if new_edges and cycle is not None:
            raise LockOrderError(msg)

    def on_released(self, lock: "DepLock") -> None:
        tid = threading.get_ident()
        stack = self.held()
        if lock in stack:
            stack.remove(lock)
        with self._mu:
            epoch = self._wait_epoch.get(tid)
            if epoch is not None:
                epoch.pop(lock.site, None)

    def on_wait_returned(self, lock: "DepLock") -> None:
        """Condition.wait re-acquired its lock; stay in the same wait epoch."""
        tid = threading.get_ident()
        with self._mu:
            self._blocked.pop(tid, None)

    def on_attempt_failed(self) -> None:
        """A timed blocking acquire gave up; the thread is no longer blocked."""
        with self._mu:
            self._blocked.pop(threading.get_ident(), None)

    def check_blocking_call(self, what: str) -> None:
        sites = self.held_sites()
        if sites:
            msg = f"{what} called while holding {sites}"
            with self._mu:
                self.violations.append(msg)
            raise BlockedUnderLockError(msg)

    # -- queries -----------------------------------------------------------

    def _find_cycle(self, start: str) -> Optional[List[str]]:
        """DFS from ``start`` back to itself over the edge set. Caller holds _mu."""
        adj: Dict[str, List[str]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
        path: List[str] = [start]
        seen: Set[str] = set()

        def dfs(node: str) -> bool:
            for nxt in adj.get(node, ()):
                if nxt == start:
                    path.append(nxt)
                    return True
                if nxt in seen:
                    continue
                seen.add(nxt)
                path.append(nxt)
                if dfs(nxt):
                    return True
                path.pop()
            return False

        return path if dfs(start) else None

    def snapshot_blocked(self) -> List[_Blocked]:
        with self._mu:
            return list(self._blocked.values())

    def stalled(self, min_seconds: float) -> List[Tuple[str, str, float]]:
        """Threads continuously blocked (acquire or wait-loop) >= min_seconds.

        A ``while not pred: cond.wait(timeout)`` loop counts from its FIRST
        wait in the current critical section — timed re-waits don't reset
        the clock, so a never-satisfied predicate is visible however short
        the individual waits are.
        """
        now = time.monotonic()
        out: List[Tuple[str, str, float]] = []
        with self._mu:
            for tid, b in self._blocked.items():
                first = b.since
                if b.kind == "cond-wait":
                    first = self._wait_epoch.get(tid, {}).get(b.site, b.since)
                dt = now - first
                if dt >= min_seconds:
                    out.append((b.thread, b.site, dt))
            for tid, epoch in self._wait_epoch.items():
                if tid in self._blocked:
                    continue  # already reported above
                for site, first in epoch.items():
                    dt = now - first
                    if dt >= min_seconds:
                        name = f"thread-{tid}"
                        for t in threading.enumerate():
                            if t.ident == tid:
                                name = t.name
                        out.append((name, site, dt))
        return out

    def assert_clean(self) -> None:
        with self._mu:
            if self.violations:
                raise LockdepError("; ".join(self.violations))

    def assert_acyclic(self) -> None:
        with self._mu:
            for site in list(self.sites):
                cycle = self._find_cycle(site)
                if cycle is not None:
                    raise LockOrderError("lock-order cycle: " + " -> ".join(cycle))


class DepLock:
    """Instrumented drop-in for ``threading.Lock``."""

    def __init__(self, graph: LockGraph, site: Optional[str] = None):
        self._real = _REAL_LOCK()
        self.graph = graph
        self.site = site or _creation_site(_SKIP_FILES)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if blocking:
            self.graph.on_attempt(self)
            ok = self._real.acquire(True, timeout)
        else:
            ok = self._real.acquire(False)
        if ok:
            self.graph.on_acquired(self)
        elif blocking:
            self.graph.on_attempt_failed()
        return ok

    def release(self) -> None:
        self.graph.on_released(self)
        self._real.release()

    def locked(self) -> bool:
        return self._real.locked()

    def __enter__(self) -> "DepLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class DepCondition:
    """Instrumented drop-in for ``threading.Condition``."""

    def __init__(self, lock: Optional[DepLock] = None, graph: Optional[LockGraph] = None):
        if graph is None and lock is not None:
            graph = lock.graph
        assert graph is not None, "DepCondition needs a graph or a DepLock"
        self.graph = graph
        self._lock = lock if lock is not None else DepLock(graph, site=None)
        self.site = self._lock.site
        self._real = _REAL_CONDITION(_REAL_LOCK())
        # the real condition wraps its own plain lock; we mirror
        # acquire/release through the DepLock bookkeeping manually
        self._lock._real = self._real._lock  # type: ignore[attr-defined]

    def acquire(self, *a, **kw) -> bool:
        return self._lock.acquire(*a, **kw)

    def release(self) -> None:
        self._lock.release()

    def __enter__(self) -> "DepCondition":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        # Condition.wait releases the lock while blocked: mirror that in
        # the held stack, but keep the wait-epoch alive for stall tracking.
        self.graph.on_attempt(self._lock, kind="cond-wait")
        held = self.graph.held()
        if self._lock in held:
            held.remove(self._lock)
        try:
            return self._real.wait(timeout)
        finally:
            held.append(self._lock)
            self.graph.on_wait_returned(self._lock)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        endtime = None if timeout is None else time.monotonic() + timeout
        result = predicate()
        while not result:
            if endtime is not None:
                remaining = endtime - time.monotonic()
                if remaining <= 0:
                    break
                self.wait(remaining)
            else:
                self.wait(None)
            result = predicate()
        return result

    def notify(self, n: int = 1) -> None:
        self._real.notify(n)

    def notify_all(self) -> None:
        self._real.notify_all()


@contextlib.contextmanager
def instrument(graph: Optional[LockGraph] = None, patch_blocking: bool = True):
    """Patch ``threading.Lock``/``Condition`` (and optionally ``time.sleep``
    + ``Thread.join``) so everything constructed inside the block reports
    into one :class:`LockGraph`, which is yielded.

    Only constructions are patched — code that imported the classes
    ``from threading import Lock`` beforehand, or module-level locks made
    outside the block, stay real. The repro stack constructs its locks at
    instance-build time, which is what makes this work.
    """
    g = graph if graph is not None else LockGraph()
    real_lock = threading.Lock
    real_cond = threading.Condition
    real_sleep = time.sleep
    real_join = threading.Thread.join

    def _internal_caller() -> bool:
        # Primitives built by threading/queue internals (Thread._started's
        # Event, Queue's Conditions, _DummyThread bookkeeping) must stay
        # real: instrumenting them recurses through current_thread() and
        # adds pure noise to the graph.
        fn = sys._getframe(2).f_code.co_filename
        return fn.endswith(("threading.py", "queue.py"))

    def make_lock():
        if _internal_caller():
            return real_lock()
        return DepLock(g)

    def make_cond(lock=None):
        if _internal_caller():
            return real_cond(lock) if lock is not None else real_cond()
        if lock is not None and not isinstance(lock, DepLock):
            # foreign lock (e.g. an RLock): leave it uninstrumented
            return real_cond(lock)
        return DepCondition(lock, graph=g)

    def guarded_sleep(seconds):
        g.check_blocking_call(f"time.sleep({seconds})")
        real_sleep(seconds)

    def guarded_join(self, timeout=None):
        g.check_blocking_call(f"Thread.join({self.name})")
        return real_join(self, timeout)

    threading.Lock = make_lock  # type: ignore[assignment]
    threading.Condition = make_cond  # type: ignore[assignment]
    if patch_blocking:
        time.sleep = guarded_sleep
        threading.Thread.join = guarded_join  # type: ignore[assignment]
    try:
        yield g
    finally:
        threading.Lock = real_lock  # type: ignore[assignment]
        threading.Condition = real_cond  # type: ignore[assignment]
        if patch_blocking:
            time.sleep = real_sleep
            threading.Thread.join = real_join  # type: ignore[assignment]

"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_global / (chips * peak_FLOP/s)
    memory term     = HLO_bytes_global / (chips * HBM_bw)
    collective term = collective_bytes_per_chip / link_bw

``cost_analysis()`` on the post-SPMD program reports *per-device* flops/bytes, so
global = per_device * chips. Collective bytes are parsed from the optimized HLO
(result-shape bytes per collective op; all-reduce counted twice for its
reduce-scatter + all-gather phases) — per-device link traffic, so the chips
factor cancels in the term.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.1 = f32[1024,256]{1,0} all-gather(%x), ...
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^\s]*\s+(" + "|".join(_COLLECTIVES) + r")\("
)
# tuple-result collectives:  = (f32[8,128], f32[8,128]) all-to-all(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes from the optimized per-device HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            b = _shape_bytes(dtype, dims)
            out[kind] += b * (2 if kind == "all-reduce" else 1)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            inner, kind = m.groups()
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(inner))
            out[kind] += b * (2 if kind == "all-reduce" else 1)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    mode: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: Dict[str, int]
    arg_bytes: int
    temp_bytes: int
    out_bytes: int
    model_flops: float = 0.0
    notes: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> Dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, mode: str,
            chips: int, model_flops: float = 0.0, notes: str = "") -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    colls = collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, mode=mode, chips=chips,
        flops_per_chip=float(cost.get("flops", 0.0)),
        bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_chip=float(sum(colls.values())),
        collectives=colls,
        arg_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        out_bytes=int(mem.output_size_in_bytes),
        model_flops=model_flops,
        notes=notes,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D with N = active params (MoE: non-expert + top_k/E of experts +
    shared experts). Decode shapes: D = global_batch tokens (one step)."""
    from repro.roofline.params import active_param_count

    n_active = active_param_count(cfg)
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens

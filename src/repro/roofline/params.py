"""Parameter counting (total and MoE-active) from ArchConfig, without allocation."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ArchConfig


def param_count(cfg: ArchConfig) -> int:
    from repro.core import spmd

    sds = jax.eval_shape(lambda: spmd.init_params(cfg, jax.random.PRNGKey(0)))
    return int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds)))


def _expert_params_per_moe_layer(cfg: ArchConfig) -> int:
    m = cfg.moe
    return m.n_experts * cfg.d_model * m.d_ff_expert * 3  # gate, up, down


def active_param_count(cfg: ArchConfig) -> int:
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = sum(1 for i in range(len(cfg.layer_pattern)) if cfg.is_moe_layer(i)) \
        * (cfg.n_layers // len(cfg.layer_pattern))
    expert_total = n_moe_layers * _expert_params_per_moe_layer(cfg)
    active_frac = m.top_k / m.n_experts
    return int(total - expert_total * (1.0 - active_frac))

"""Shared transformer building blocks (pure-functional, pytree params).

All modules are (init_fn, apply_fn) pairs over plain dicts so that layer stacks can
be jnp-stacked and driven with ``lax.scan``, which keeps the lowered HLO small
enough to compile 88-layer models for a 512-device mesh on one CPU core.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.ctx import constrain

Params = dict

# When True, every lax.scan in the model lowers fully unrolled. Used ONLY by the
# dry-run cost probes: XLA's HloCostAnalysis counts a while-loop body once
# (trip count ignored), so roofline FLOPs/bytes are extracted from unrolled
# straight-line probes (launch/dryrun.py extrapolate_cost) while the deliverable
# program keeps compact scan loops.
_UNROLL_SCANS = False


def set_unroll_scans(v: bool) -> None:
    global _UNROLL_SCANS
    _UNROLL_SCANS = v


def uscan(f, init, xs, **kw):
    if _UNROLL_SCANS:
        kw = dict(kw, unroll=True)
    return lax.scan(f, init, xs, **kw)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    if theta <= 0:
        return x
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window, blockwise for long prefill)
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def _sdpa_block(q, k, v, mask, scale):
    """q: (B,Sq,H,D), k/v: (B,Sk,H,D), mask: (Sq,Sk) or (B,1,Sq,Sk)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_apply(
    p: Params,
    x: jnp.ndarray,
    cfg,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    q_chunk: int = 1024,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). Blockwise over query chunks so the
    (Sq, Sk) score tile never exceeds q_chunk * S."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cross_kv is None:
        k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
        v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_positions = positions
    else:
        enc = cross_kv[0]
        Sk = enc.shape[1]
        k = (enc @ p["wk"]).reshape(B, Sk, cfg.n_kv_heads, hd)
        v = (enc @ p["wv"]).reshape(B, Sk, cfg.n_kv_heads, hd)
        kv_positions = jnp.arange(Sk)
        causal = False
    q = constrain(q, ("batch", None, "model", None))
    k = constrain(_repeat_kv(k, n_rep), ("batch", None, "model", None))
    v = constrain(_repeat_kv(v, n_rep), ("batch", None, "model", None))
    scale = hd ** -0.5
    Sk = k.shape[1]

    def block_mask(q_pos):
        # q_pos: (C,) absolute positions of this query chunk.
        m = jnp.ones((q_pos.shape[0], Sk), bool)
        if causal:
            m = q_pos[:, None] >= kv_positions[None, :]
            if cfg.sliding_window is not None:
                m &= q_pos[:, None] - kv_positions[None, :] < cfg.sliding_window
        return m

    if S % q_chunk:
        # largest chunk that divides S (e.g. whisper's 1500-frame encoder ctx)
        q_chunk = next((c for c in range(min(q_chunk, S), 0, -1) if S % c == 0), S)
    if S <= q_chunk:
        out = _sdpa_block(q, k, v, block_mask(positions), scale)
    else:
        n_chunks = S // q_chunk
        qc = q.reshape(B, n_chunks, q_chunk, cfg.n_heads, hd).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(n_chunks, q_chunk)

        @jax.checkpoint
        def body(_, args):
            # rematted: per-chunk score/prob tiles are recomputed in backward
            # instead of being saved across the whole chunk scan.
            qi, pi = args
            return None, _sdpa_block(qi, k, v, block_mask(pi), scale)

        _, oc = uscan(body, None, (qc, pc))
        out = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.n_heads, hd)
    return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]


def attention_decode(
    p: Params,
    x: jnp.ndarray,
    cfg,
    cache: Params,
    pos: jnp.ndarray,
    *,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. x: (B, 1, d); cache: {"k","v"}: (B, S_max, n_kv, hd); pos scalar."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    if cross_kv is not None:
        k, v = cross_kv  # precomputed encoder K/V: (B, Sk, n_kv, hd)
        mask = jnp.ones((1, k.shape[1]), bool)
        out = _sdpa_block(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep), mask, hd ** -0.5)
        return out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"], cache
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k_new = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    k_new = apply_rope(k_new, pos[None], cfg.rope_theta)
    v_new = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    S_max = cache["k"].shape[1]
    k_all = lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_all = lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    kv_pos = jnp.arange(S_max)
    mask = kv_pos[None, :] <= pos
    if cfg.sliding_window is not None:
        mask &= pos - kv_pos[None, :] < cfg.sliding_window
    out = _sdpa_block(q, _repeat_kv(k_all, n_rep), _repeat_kv(v_all, n_rep), mask, hd ** -0.5)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return out, {"k": k_all, "v": v_all}


def init_attention_cache(cfg, batch: int, s_max: int, dtype) -> Params:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s_max, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, s_max, cfg.n_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, d_model, d_ff, dtype), "w_down": dense_init(k2, d_ff, d_model, dtype)}


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": dense_init(key, vocab, d_model, dtype, scale=0.02)}


def embed_lookup(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token-level CE. logits: (..., V) any float dtype; labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_softmax_ce(
    x: jnp.ndarray,
    w_head: jnp.ndarray,
    labels: jnp.ndarray,
    weights: jnp.ndarray,
    vocab_limit: int,
    chunk: int = 512,
) -> jnp.ndarray:
    """Fused chunked softmax-CE: never materializes the full (B, S, V) logits.

    At 1M tokens x 160k vocab the dense logit tensor is ~100 TB — the single
    biggest activation in LLM training. We scan over sequence chunks, computing
    (B, chunk, V) logits per step, with the chunk body rematerialized in the
    backward pass. x: (B, S, d); w_head: (d, V_padded); labels/weights: (B, S).
    Columns >= vocab_limit (padding) are masked out."""
    B, S, _ = x.shape
    V = w_head.shape[-1]
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
    xs = x.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    ws = weights.reshape(B, n, chunk).transpose(1, 0, 2)
    col_mask = (jnp.arange(V) < vocab_limit)

    @jax.checkpoint
    def body(acc, args):
        xc, lc, wc = args
        logits = (xc @ w_head).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "model"))
        logits = jnp.where(col_mask, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * wc), None

    total, _ = uscan(body, jnp.zeros((), jnp.float32), (xs, ls, ws))
    return total / jnp.maximum(jnp.sum(weights), 1.0)

"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment carve-out:
``input_specs()`` supplies precomputed frame embeddings (B, n_ctx, d_model). We use
sinusoidal positions on both sides (shape-identical to Whisper's learned decoder
positions; noted as an adaptation in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import _mask_padded_logits, padded_vocab
from repro.sharding.ctx import constrain
from repro.models.layers import (
    Params,
    attention_apply,
    attention_decode,
    attention_init,
    dtype_of,
    embed_init,
    embed_lookup,
    gelu_mlp,
    gelu_mlp_init,
    init_attention_cache,
    layernorm,
    layernorm_init,
    uscan,
)


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    return sinusoids_at(jnp.arange(length, dtype=jnp.float32), channels)


def sinusoids_at(positions: jnp.ndarray, channels: int) -> jnp.ndarray:
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layernorm_init(cfg.d_model, dtype),
        "attn": attention_init(k1, cfg, dtype),
        "norm2": layernorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layernorm_init(cfg.d_model, dtype),
        "self_attn": attention_init(k1, cfg, dtype),
        "norm_x": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attention_init(k2, cfg, dtype),
        "norm2": layernorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ArchConfig, key: jax.Array, dtype=None) -> Params:
    dtype = dtype or dtype_of(cfg.dtype)
    n_enc = cfg.encoder.n_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 1)
    enc = [_enc_layer_init(keys[i], cfg, dtype) for i in range(n_enc)]
    dec = [_dec_layer_init(keys[n_enc + i], cfg, dtype) for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(keys[-1], padded_vocab(cfg), cfg.d_model, dtype),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": layernorm_init(cfg.d_model, dtype),
        "dec_norm": layernorm_init(cfg.d_model, dtype),
    }


def encode(params: Params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, n_ctx, d_model) — stubbed conv-frontend output."""
    B, S, _ = frames.shape
    x = constrain(frames + sinusoids(S, cfg.d_model).astype(frames.dtype),
                  ("batch", None, None))
    positions = jnp.arange(S)

    def body(x, lp):
        h = layernorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attention_apply(lp["attn"], h, cfg, positions=positions, causal=False)
        h = layernorm(lp["norm2"], x, cfg.norm_eps)
        return x + gelu_mlp(lp["mlp"], h), None

    x, _ = uscan(body, x, params["enc"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def decode_full(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                enc_out: jnp.ndarray, *, return_kv: bool = False,
                return_hidden: bool = False):
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    x = constrain(x + sinusoids(S, cfg.d_model).astype(x.dtype), ("batch", None, None))
    positions = jnp.arange(S)
    hd = cfg.resolved_head_dim

    def body(x, lp):
        h = layernorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attention_apply(lp["self_attn"], h, cfg, positions=positions)
        hx = layernorm(lp["norm_x"], x, cfg.norm_eps)
        x = x + attention_apply(lp["cross_attn"], hx, cfg, positions=positions,
                                cross_kv=(enc_out, None))
        h = layernorm(lp["norm2"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        cache = None
        if return_kv:
            k = (h @ lp["self_attn"]["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
            v = (h @ lp["self_attn"]["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
            # note: h here is the post-mlp hidden; recompute from pre-self-attn input
            cache = {"k": k, "v": v}
        return x, cache

    x, caches = uscan(body, x, params["dec"])
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    if return_hidden:
        return (x, caches) if return_kv else x
    logits = _mask_padded_logits(x @ params["embed"]["table"].T, cfg)
    return (logits, caches) if return_kv else logits


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    from repro.models.layers import chunked_softmax_ce

    enc_out = encode(params, cfg, batch["frames"])
    hidden = decode_full(params, cfg, batch["tokens"], enc_out, return_hidden=True)
    tokens = batch["tokens"]
    B, S = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    weights = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], axis=1
    )
    return chunked_softmax_ce(
        hidden, params["embed"]["table"].T, labels, weights, cfg.vocab_size
    )


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None) -> Any:
    dtype = dtype or dtype_of(cfg.dtype)
    one = init_attention_cache(cfg, batch, s_max, dtype)
    self_c = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
    hd = cfg.resolved_head_dim
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_ctx, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_ctx, cfg.n_kv_heads, hd), dtype),
    }
    return {"self": self_c, "cross": cross}


def build_cross_cache(params: Params, cfg: ArchConfig, enc_out: jnp.ndarray) -> Params:
    B, Sk, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def per_layer(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, Sk, cfg.n_kv_heads, hd)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, Sk, cfg.n_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(per_layer)(params["dec"])


def decode_step(params: Params, cfg: ArchConfig, cache: Any, token: jnp.ndarray,
                pos: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
    x = embed_lookup(params["embed"], token[:, None])
    x = x + sinusoids_at(pos[None], cfg.d_model).astype(x.dtype)

    def body(x, scanned):
        lp, self_c, cross_c = scanned
        h = layernorm(lp["norm1"], x, cfg.norm_eps)
        mixed, self_c = attention_decode(lp["self_attn"], h, cfg, self_c, pos)
        x = x + mixed
        hx = layernorm(lp["norm_x"], x, cfg.norm_eps)
        mixed, _ = attention_decode(lp["cross_attn"], hx, cfg, self_c,
                                    pos, cross_kv=(cross_c["k"], cross_c["v"]))
        x = x + mixed
        h = layernorm(lp["norm2"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        return x, self_c

    x, new_self = uscan(body, x, (params["dec"], cache["self"], cache["cross"]))
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x @ params["embed"]["table"].T
    return logits[:, 0, : cfg.vocab_size], {"self": new_self, "cross": cache["cross"]}

"""Mamba-2 block via the SSD (state-space duality) chunked algorithm [arXiv:2405.21060].

Train/prefill uses the chunk-parallel matmul formulation (intra-chunk dense masked
attention-like einsums + sequential inter-chunk state recurrence via ``lax.scan``),
which is the TPU-native adaptation of the paper's kernel: the quadratic intra-chunk
part maps to the MXU, the recurrence is O(L/chunk) sequential steps.

Decode keeps a constant-size (ssm_state, conv_state) cache: O(1) per token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import Params, dense_init, rmsnorm, uscan
from repro.sharding.ctx import constrain


def effective_chunk(L: int, chunk: int) -> int:
    """Largest chunk <= cfg chunk that divides L (prefill lengths vary)."""
    if L % chunk == 0:
        return chunk
    return next((c for c in range(min(chunk, L), 0, -1) if L % c == 0), L)


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def mamba2_init(key, cfg, dtype) -> Params:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.exp(
        jax.random.uniform(k3, (H,), jnp.float32)
        * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
        + jnp.log(s.dt_min)
    )
    return {
        "in_proj": dense_init(k1, cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k4, d_inner, cfg.d_model, dtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. u: (B, L, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    L = u.shape[1]
    out = sum(pad[:, i : i + L, :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    s = cfg.ssm
    d_inner, _, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x, B, C = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    return x, B, C


def _ssd_scan(xdt, a, Bm, Cm, h0):
    """Chunked SSD. xdt: (b, nc, q, h, p); a: (b, nc, q, h); Bm/Cm: (b, nc, q, h, n);
    h0: (b, h, p, n). Returns y: (b, nc, q, h, p), h_final."""

    def chunk(h, args):
        xdt_c, a_c, B_c, C_c = args  # (b, q, h, p), (b, q, h), (b, q, h, n) x2
        a_cum = jnp.cumsum(a_c, axis=1)  # (b, q, h)
        # Intra-chunk (masked quadratic part -> MXU-friendly einsums).
        Lmat = jnp.exp(a_cum[:, :, None, :] - a_cum[:, None, :, :])  # (b, q, s, h)
        q_idx = jnp.arange(a_c.shape[1])
        Lmat = jnp.where((q_idx[:, None] >= q_idx[None, :])[None, :, :, None], Lmat, 0.0)
        y_diag = jnp.einsum("bqhn,bshn,bqsh,bshp->bqhp", C_c, B_c, Lmat, xdt_c)
        # Contribution of the carried state.
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", C_c, h, jnp.exp(a_cum))
        # New carried state.
        decay_states = jnp.exp(a_cum[:, -1:, :] - a_cum)  # (b, q, h)
        s_c = jnp.einsum("bqhn,bqh,bqhp->bhpn", B_c, decay_states, xdt_c)
        h_new = jnp.exp(a_cum[:, -1, :])[..., None, None] * h + s_c
        return h_new, y_diag + y_off

    # scan over the chunk axis (xs leading dim), so move nc first.
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xdt, a, Bm, Cm))
    h_final, ys = uscan(chunk, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def mamba2_apply(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Full-sequence (train/prefill). x: (B, L, d_model)."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    Bsz, L, _ = x.shape
    chunk = effective_chunk(L, s.chunk)
    nc = L // chunk

    z, xbc, dt_raw = _split_proj(cfg, constrain(x @ p["in_proj"], ("batch", None, "model")))
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = _split_xbc(cfg, xbc)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    A = -jnp.exp(p["A_log"])  # (H,)
    a = (dt * A).reshape(Bsz, nc, chunk, H)

    xh = xs.reshape(Bsz, L, H, s.headdim).astype(jnp.float32)
    xdt = (xh * dt[..., None]).reshape(Bsz, nc, chunk, H, s.headdim)
    rep = H // s.n_groups
    Bg = Bm.reshape(Bsz, L, s.n_groups, s.d_state).astype(jnp.float32)
    Cg = Cm.reshape(Bsz, L, s.n_groups, s.d_state).astype(jnp.float32)
    Bh = jnp.repeat(Bg, rep, axis=2).reshape(Bsz, nc, chunk, H, s.d_state)
    Ch = jnp.repeat(Cg, rep, axis=2).reshape(Bsz, nc, chunk, H, s.d_state)

    h0 = jnp.zeros((Bsz, H, s.headdim, s.d_state), jnp.float32)
    y, _ = _ssd_scan(xdt, a, Bh, Ch, h0)
    y = y.reshape(Bsz, L, H, s.headdim) + p["D"][:, None] * xh
    y = y.reshape(Bsz, L, d_inner).astype(x.dtype)

    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"]


def init_mamba_cache(cfg, batch: int, dtype) -> Params:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, s.headdim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def mamba2_decode(p: Params, x: jnp.ndarray, cfg, cache: Params) -> Tuple[jnp.ndarray, Params]:
    """One-token step. x: (B, 1, d_model)."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    Bsz = x.shape[0]

    z, xbc, dt_raw = _split_proj(cfg, x @ p["in_proj"])  # (B, 1, *)
    window = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B, H)

    rep = H // s.n_groups
    Bh = jnp.repeat(Bm[:, 0].reshape(Bsz, s.n_groups, s.d_state), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm[:, 0].reshape(Bsz, s.n_groups, s.d_state), rep, axis=1).astype(jnp.float32)
    xh = xs[:, 0].reshape(Bsz, H, s.headdim).astype(jnp.float32)

    h = cache["ssm"] * a[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh, xh
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + p["D"][:, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)

    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"], {"ssm": h, "conv": new_conv}

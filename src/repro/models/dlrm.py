"""DLRM (the paper's model): bottom MLP -> dot interaction -> top MLP [arXiv:1906.00091].

Dense weights ``w`` (MLPs) and embedding tables ``h`` are deliberately SEPARATE
pytrees: ``w`` is replicated per trainer (data parallelism, ShadowSync'd), ``h``
lives on the embedding shards (model parallelism, Hogwild-updated). The training
step computes grads w.r.t. the POOLED embeddings so the table update is a sparse
row scatter — exactly the trainer -> embedding-PS gradient flow of the paper.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def init_dense(cfg, key, dtype=jnp.float32) -> Params:
    """MLP + interaction weights (the ShadowSync-replicated part)."""
    d = cfg.embedding_dim
    n_vec = cfg.n_sparse_features + 1
    top_in = d + n_vec * (n_vec - 1) // 2
    keys = jax.random.split(key, len(cfg.bottom_mlp) + len(cfg.top_mlp))
    bot, dims = [], (cfg.n_dense_features,) + tuple(cfg.bottom_mlp)
    for i in range(len(cfg.bottom_mlp)):
        bot.append({
            "w": dense_init(keys[i], dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    top, dims = [], (top_in,) + tuple(cfg.top_mlp)
    for i in range(len(cfg.top_mlp)):
        top.append({
            "w": dense_init(keys[len(bot) + i], dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return {"bottom": tuple(bot), "top": tuple(top)}


def _mlp(layers, x, final_linear: bool) -> jnp.ndarray:
    n = len(layers)
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if not (final_linear and i == n - 1):
            x = jax.nn.relu(x)
    return x


def interact(bottom_out: jnp.ndarray, pooled: jnp.ndarray) -> jnp.ndarray:
    """Pairwise dot interaction. bottom_out: (B, d); pooled: (B, F, d)."""
    z = jnp.concatenate([bottom_out[:, None, :], pooled], axis=1)  # (B, F+1, d)
    dots = jnp.einsum("bfd,bgd->bfg", z, z)
    n = z.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    return jnp.concatenate([bottom_out, dots[:, iu, ju]], axis=-1)


def forward(w: Params, dense_x: jnp.ndarray, pooled: jnp.ndarray) -> jnp.ndarray:
    """Returns logits (B,)."""
    bot = _mlp(w["bottom"], dense_x, final_linear=False)
    feat = interact(bot, pooled.astype(bot.dtype))
    return _mlp(w["top"], feat, final_linear=True)[:, 0]


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Binary cross-entropy with logits — the paper's normalized-entropy-style metric
    is this loss normalized by the entropy of the base CTR (see core/elp.py)."""
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def dense_loss_and_grads(
    w: Params, dense_x: jnp.ndarray, pooled: jnp.ndarray, labels: jnp.ndarray
) -> Tuple[jnp.ndarray, Params, jnp.ndarray]:
    """Returns (loss, grad_w, grad_pooled) — the latter is shipped to the embedding
    shards for the sparse Hogwild row update."""

    def f(w_, pooled_):
        return bce_loss(forward(w_, dense_x, pooled_), labels)

    loss, (g_w, g_pooled) = jax.value_and_grad(f, argnums=(0, 1))(w, pooled)
    return loss, g_w, g_pooled

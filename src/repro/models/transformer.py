"""Decoder stack builder for dense / moe / ssm / hybrid / vlm families.

Layers are grouped by the repeating ``layer_pattern`` unit (e.g. Jamba's
``MMMAMMMM``) and jnp-stacked over unit repeats so the whole depth is driven by a
single ``lax.scan`` — this keeps lowered HLO size O(unit) instead of O(n_layers),
which is what lets 88-layer x 512-device programs compile quickly on one CPU core.

Three entry points per model: full-sequence ``forward`` (train), ``prefill``
(forward + cache build), and ``decode_step`` (one token against the cache).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import mamba2
from repro.models.layers import (
    Params,
    attention_apply,
    attention_decode,
    attention_init,
    dtype_of,
    embed_init,
    embed_lookup,
    init_attention_cache,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.layers import uscan
from repro.sharding.ctx import constrain


def _unit_info(cfg: ArchConfig) -> Tuple[int, int]:
    unit = len(cfg.layer_pattern)
    assert cfg.n_layers % unit == 0, (cfg.n_layers, cfg.layer_pattern)
    return unit, cfg.n_layers // unit


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim shards over any mesh
    axis (standard practice, cf. MaxText/Megatron). Padded logit columns are
    masked to -inf in the loss and sliced off at decode."""
    return -(-cfg.vocab_size // 256) * 256


def _mask_padded_logits(logits: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    pv = padded_vocab(cfg)
    if pv == cfg.vocab_size:
        return logits
    col = jnp.arange(pv)
    return jnp.where(col < cfg.vocab_size, logits, -1e30)


def _layer_init(key, cfg: ArchConfig, kind: str, layer_idx: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "A":
        p["mixer"] = attention_init(k1, cfg, dtype)
    else:
        p["mixer"] = mamba2.mamba2_init(k1, cfg, dtype)
    if cfg.d_ff > 0 or cfg.moe is not None:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.is_moe_layer(layer_idx):
            p["ffn"] = moe_init(k2, cfg, dtype)
        else:
            p["ffn"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=None) -> Params:
    dtype = dtype or dtype_of(cfg.dtype)
    unit, repeats = _unit_info(cfg)
    keys = jax.random.split(key, cfg.n_layers + 2)
    kinds = cfg.layer_kinds()
    # Stack each unit position over repeats.
    unit_params = []
    for pos in range(unit):
        per_rep = [
            _layer_init(keys[r * unit + pos], cfg, kinds[pos], pos, dtype)
            for r in range(repeats)
        ]
        unit_params.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    params = {
        "embed": embed_init(keys[-2], padded_vocab(cfg), cfg.d_model, dtype),
        "unit": tuple(unit_params),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(keys[-1], (cfg.d_model, padded_vocab(cfg)), jnp.float32)
                  * cfg.d_model ** -0.5).astype(dtype)
        }
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        # anyres projector: maps (stubbed) vision-tower features into d_model.
        params["projector"] = {
            "w": (jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model), jnp.float32)
                  * cfg.d_model ** -0.5).astype(dtype)
        }
    return params


def _apply_layer_full(lp: Params, x, cfg: ArchConfig, kind: str, layer_idx: int,
                      positions, return_kv: bool):
    """One block, full sequence. Returns (x, aux, cache_contrib)."""
    if cfg.parallel_block and kind == "A" and "ffn" in lp:
        return _apply_parallel_layer_full(lp, x, cfg, layer_idx, positions, return_kv)
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    cache_out = None
    if kind == "A":
        mixed = _ckpt_name(attention_apply(lp["mixer"], h, cfg, positions=positions),
                           "attn_out")
        if return_kv:
            hd = cfg.resolved_head_dim
            B, S, _ = h.shape
            k = (h @ lp["mixer"]["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
            from repro.models.layers import apply_rope

            k = apply_rope(k, positions, cfg.rope_theta)
            v = (h @ lp["mixer"]["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
            cache_out = {"k": k, "v": v}
    else:
        mixed = _ckpt_name(mamba2.mamba2_apply(lp["mixer"], h, cfg), "attn_out")
        if return_kv:
            cache_out = _mamba_final_state(lp["mixer"], h, cfg)
    x = x + mixed
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in lp:
        h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if cfg.is_moe_layer(layer_idx):
            y, aux = moe_apply(lp["ffn"], h2, cfg)
        else:
            y = swiglu(lp["ffn"], h2)
        x = x + _ckpt_name(y, "ffn_out")
    return x, aux, cache_out


def _ckpt_name(x, name):
    """Tag post-collective activations so the "save_comm" remat policy can keep
    them: full remat otherwise REPLAYS the forward tensor-parallel all-reduces
    inside the backward pass (measured: ~25% of train collective bytes)."""
    from jax.ad_checkpoint import checkpoint_name

    return checkpoint_name(x, name)


def _apply_parallel_layer_full(lp, x, cfg, layer_idx, positions, return_kv):
    """PaLM-style: one shared pre-norm; attn and ffn branches added together, so
    their model-axis partial sums fuse into a single all-reduce."""
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    mixed = attention_apply(lp["mixer"], h, cfg, positions=positions)
    cache_out = None
    if return_kv:
        hd = cfg.resolved_head_dim
        B, S, _ = h.shape
        from repro.models.layers import apply_rope

        k = apply_rope((h @ lp["mixer"]["wk"]).reshape(B, S, cfg.n_kv_heads, hd),
                       positions, cfg.rope_theta)
        v = (h @ lp["mixer"]["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
        cache_out = {"k": k, "v": v}
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe_layer(layer_idx):
        y, aux = moe_apply(lp["ffn"], h, cfg)
    else:
        y = swiglu(lp["ffn"], h)
    return x + _ckpt_name(mixed + y, "attn_out"), aux, cache_out


def _mamba_final_state(p, h, cfg):
    """Recompute the final (ssm, conv) state for prefill cache handoff."""
    s = cfg.ssm
    zxbcdt = h @ p["in_proj"]
    _, xbc, _ = mamba2._split_proj(cfg, zxbcdt)
    conv_tail = xbc[:, -(s.d_conv - 1):, :]
    # Rerun the SSD scan to get the final state (cheap relative to the block).
    d_inner, H, _ = mamba2._dims(cfg)
    Bsz, L, _ = h.shape
    chunk = mamba2.effective_chunk(L, s.chunk)
    nc = L // chunk
    xbc_conv = mamba2._causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = mamba2._split_xbc(cfg, xbc_conv)
    import jax.nn

    dt = jax.nn.softplus(
        (zxbcdt[..., -H:]).astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    a = (dt * A).reshape(Bsz, nc, chunk, H)
    xh = xs.reshape(Bsz, L, H, s.headdim).astype(jnp.float32)
    xdt = (xh * dt[..., None]).reshape(Bsz, nc, chunk, H, s.headdim)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm.reshape(Bsz, L, s.n_groups, s.d_state).astype(jnp.float32), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(Bsz, L, s.n_groups, s.d_state).astype(jnp.float32), rep, axis=2)
    Bh = Bh.reshape(Bsz, nc, chunk, H, s.d_state)
    Ch = Ch.reshape(Bsz, nc, chunk, H, s.d_state)
    h0 = jnp.zeros((Bsz, H, s.headdim, s.d_state), jnp.float32)
    _, h_final = mamba2._ssd_scan(xdt, a, Bh, Ch, h0)
    return {"ssm": h_final, "conv": conv_tail}


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    *,
    prefix_embeds: Optional[jnp.ndarray] = None,
    remat: bool = False,
    remat_policy: str = "full",
    return_cache: bool = False,
    return_hidden: bool = False,
):
    """tokens: (B, S_text). Returns (logits, aux_loss[, cache]); with
    return_hidden=True, returns final-norm hidden states instead of logits (for
    the chunked-CE loss, which fuses the head projection)."""
    x = embed_lookup(params["embed"], tokens)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype)
        if "projector" in params:
            pe = pe @ params["projector"]["w"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    x = constrain(x, ("batch", None, None))
    positions = jnp.arange(S)
    unit, repeats = _unit_info(cfg)
    kinds = cfg.layer_kinds()

    def unit_body(carry, unit_lp):
        x, aux = carry
        caches = []
        for pos in range(unit):
            x, a, c = _apply_layer_full(
                unit_lp[pos], x, cfg, kinds[pos], pos, positions, return_cache
            )
            x = constrain(x, ("batch", None, None))
            aux = aux + a
            caches.append(c)
        out = tuple(caches) if return_cache else None
        return (x, aux), out

    if remat:
        if remat_policy == "save_comm":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out")
            unit_body = jax.checkpoint(unit_body, policy=policy)
        else:
            unit_body = jax.checkpoint(unit_body)
    (x, aux), caches = uscan(unit_body, (x, jnp.zeros((), jnp.float32)), params["unit"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return (x, aux, caches) if return_cache else (x, aux)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = x @ params["lm_head"]["w"]
    logits = _mask_padded_logits(logits, cfg)
    if return_cache:
        return logits, aux, caches
    return logits, aux


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            *, remat: bool = False, remat_policy: str = "full",
            ce_chunk: int = 512) -> jnp.ndarray:
    """Next-token LM loss (chunked softmax-CE: the (B, S, V) logits are never
    materialized). batch: {"tokens": (B, S)[, "prefix_embeds": (B, P, d)]}."""
    from repro.models.layers import chunked_softmax_ce

    tokens = batch["tokens"]
    hidden, aux = forward(
        params, cfg, tokens, prefix_embeds=batch.get("prefix_embeds"),
        remat=remat, remat_policy=remat_policy, return_hidden=True,
    )
    n_prefix = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    hidden = hidden[:, n_prefix:, :]
    # Predict tokens[t+1] from position t; zero-weight the last position.
    B, S = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    weights = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], axis=1
    )
    head = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    ce = chunked_softmax_ce(hidden, head, labels, weights, cfg.vocab_size, chunk=ce_chunk)
    return ce + aux


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None) -> Any:
    dtype = dtype or dtype_of(cfg.dtype)
    unit, repeats = _unit_info(cfg)
    kinds = cfg.layer_kinds()
    caches = []
    for pos in range(unit):
        if kinds[pos] == "A":
            one = init_attention_cache(cfg, batch, s_max, dtype)
        else:
            one = mamba2.init_mamba_cache(cfg, batch, dtype)
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), one))
    return tuple(caches)


def decode_step(
    params: Params,
    cfg: ArchConfig,
    cache: Any,
    token: jnp.ndarray,
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, Any]:
    """token: (B,) int32; pos: scalar int32. Returns (logits (B, V), new cache)."""
    x = embed_lookup(params["embed"], token[:, None])
    unit, repeats = _unit_info(cfg)
    kinds = cfg.layer_kinds()

    def unit_body(x, scanned):
        unit_lp, unit_cache = scanned
        new_caches = []
        for p_idx in range(unit):
            lp, c = unit_lp[p_idx], unit_cache[p_idx]
            h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
            parallel = cfg.parallel_block and kinds[p_idx] == "A" and "ffn" in lp
            if kinds[p_idx] == "A":
                mixed, c = attention_decode(lp["mixer"], h, cfg, c, pos)
            else:
                mixed, c = mamba2.mamba2_decode(lp["mixer"], h, cfg, c)
            if parallel:
                if cfg.is_moe_layer(p_idx):
                    y, _ = moe_apply(lp["ffn"], h, cfg)
                else:
                    y = swiglu(lp["ffn"], h)
                x = x + mixed + y
            else:
                x = x + mixed
                if "ffn" in lp:
                    h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
                    if cfg.is_moe_layer(p_idx):
                        y, _ = moe_apply(lp["ffn"], h2, cfg)
                    else:
                        y = swiglu(lp["ffn"], h2)
                    x = x + y
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_cache = uscan(unit_body, x, (params["unit"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = x @ params["lm_head"]["w"]
    return logits[:, 0, : cfg.vocab_size], new_cache


def prefill(
    params: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    s_max: int,
    *,
    prefix_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Any]:
    """Full-context forward that also builds the serving cache.

    Returns (last-position logits (B, V), cache padded to s_max). Only the last
    position's logits are projected — the (B, S, V) tensor never exists."""
    hidden, _, layer_caches = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds, return_cache=True,
        return_hidden=True,
    )
    last = hidden[:, -1, :]
    if cfg.tie_embeddings:
        logits = last @ params["embed"]["table"].T
    else:
        logits = last @ params["lm_head"]["w"]
    logits = logits[:, None, :]
    dtype = dtype_of(cfg.dtype)
    unit, repeats = _unit_info(cfg)
    kinds = cfg.layer_kinds()
    caches = []
    for pos in range(unit):
        c = layer_caches[pos]  # stacked over repeats by scan
        if kinds[pos] == "A":
            B, S = c["k"].shape[1], c["k"].shape[2]
            pad = s_max - S
            c = {
                "k": jnp.pad(c["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype),
                "v": jnp.pad(c["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype),
            }
        caches.append(c)
    return logits[:, -1, :], tuple(caches)

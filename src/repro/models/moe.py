"""Mixture-of-Experts layer: top-k router + group-local sort dispatch.

Dispatch is O(T*k*d) + an (E, C, d) expert buffer — no (T, E, C) one-hot tensor is
ever materialized. Tokens are routed in G groups aligned with the data-parallel
sharding (G = product of batch mesh axes, from the activation-sharding context):
the argsort that assigns expert slots runs over each group's local tokens only, so
it lowers to a per-shard sort instead of a distributed sort network; the
(G, E, C/G, d) -> (E, C, d) regroup is the expert-parallel all-to-all. Experts are
sharded over the ``model`` mesh axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Params, dense_init, swiglu, swiglu_init
from repro.sharding import ctx as shctx
from repro.sharding.ctx import constrain


def moe_init(key, cfg, dtype) -> Params:
    m = cfg.moe
    k_router, k_gate, k_up, k_down, k_shared = jax.random.split(key, 5)
    E, d, f = m.n_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": dense_init(k_router, d, E, jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(k_gate, (E, d, f), jnp.float32) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k_up, (E, d, f), jnp.float32) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k_down, (E, f, d), jnp.float32) * f ** -0.5).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = swiglu_init(k_shared, d, f * m.n_shared_experts, dtype)
    return p


def _n_groups(T: int) -> int:
    """Dispatch groups = data-parallel shards (1 when no mesh context)."""
    ctx = shctx.active()
    if ctx is None:
        return 1
    shape = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    g = int(np.prod([shape[a] for a in ctx.batch_axes]))
    while g > 1 and T % g:
        g //= 2
    return max(g, 1)


def _dispatch_indices(expert_idx: jnp.ndarray, n_experts: int, capacity: int):
    """expert_idx: (Tk,) local expert assignment. Returns (order, dest, keep)."""
    order = jnp.argsort(expert_idx, stable=True)
    e_sorted = expert_idx[order]
    first = jnp.searchsorted(e_sorted, e_sorted, side="left")
    pos = jnp.arange(e_sorted.shape[0]) - first
    keep = pos < capacity
    dest = jnp.where(keep, e_sorted * capacity + pos, n_experts * capacity)  # OOB -> drop
    return order, dest, keep


def moe_apply(p: Params, x: jnp.ndarray, cfg, *, capacity_factor: float = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (y, aux_loss)."""
    m = cfg.moe
    capacity_factor = m.capacity_factor if capacity_factor is None else capacity_factor
    B, S, d = x.shape
    T = B * S
    k, E = m.top_k, m.n_experts
    G = _n_groups(T)
    Tg = T // G
    cap_g = int(max(k, capacity_factor * Tg * k / E))

    xt = x.reshape(G, Tg, d)
    xt = constrain(xt, ("batch", None, None))

    logits = (xt.astype(jnp.float32) @ p["router"])  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style), over all tokens.
    assign = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=2)  # (G, Tg, E)
    frac_tokens = jnp.mean(assign, axis=(0, 1)) / k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.load_balance_coef

    def route_group(xg, idxg, gateg):
        # All local to one data shard group: the sort never crosses shards.
        order, dest, keep = _dispatch_indices(idxg.reshape(-1), E, cap_g)
        tok_sorted = (jnp.arange(Tg * k) // k)[order]
        gate_sorted = gateg.reshape(-1)[order]
        xs = jnp.where(keep[:, None], xg[tok_sorted], 0).astype(x.dtype)
        buf = jnp.zeros((E * cap_g, d), x.dtype).at[dest].set(xs, mode="drop")
        return buf.reshape(E, cap_g, d), (order, dest, keep, tok_sorted, gate_sorted)

    buf_g, route_state = jax.vmap(route_group)(xt, idx, gates)  # (G, E, cap_g, d)
    # Group-major buffers stay FULLY local to their data shard (no model
    # sharding here): the scatter that builds them and the gather that unroutes
    # are then shard-local; ALL cross-device movement happens in the single
    # group-major <-> expert-major regroup below (the all-to-all).
    buf_g = constrain(buf_g, ("batch", None, None, None))

    # Regroup to expert-major: THE expert-parallel all-to-all. The slot dim is
    # G-major, so sharding it over the data axes keeps each (expert, group) tile
    # on one device row — expert compute is split over data x model, never
    # replicated.
    buf = buf_g.transpose(1, 0, 2, 3).reshape(E, G * cap_g, d)
    buf = constrain(buf, ("model", "batch", None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = constrain(out, ("model", "batch", None))

    # Reverse all-to-all back to group-major, then un-route (shard-local).
    out_g = out.reshape(E, G, cap_g, d).transpose(1, 0, 2, 3)
    out_g = constrain(out_g, ("batch", None, None, None)).reshape(G, E * cap_g, d)

    def unroute_group(out_flat, state):
        order, dest, keep, tok_sorted, gate_sorted = state
        y_sorted = out_flat.at[dest].get(mode="fill", fill_value=0) * (
            gate_sorted[:, None].astype(x.dtype) * keep[:, None]
        )
        return jnp.zeros((Tg, d), x.dtype).at[tok_sorted].add(y_sorted)

    y = jax.vmap(unroute_group)(out_g, route_state)  # (G, Tg, d)
    y = constrain(y, ("batch", None, None)).reshape(B, S, d)

    if "shared" in p:
        y = y + swiglu(p["shared"], x.reshape(T, d)).reshape(B, S, d)
    return y, aux

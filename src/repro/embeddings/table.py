"""Sharded embedding tables with Hogwild-style sparse Adagrad updates.

All categorical tables are packed into ONE (total_rows, dim) array so the whole
collection shards over the ``model`` mesh axis with a single PartitionSpec — the
TPU-native analogue of the paper's embedding parameter servers. Adagrad
accumulators are co-located with the rows (paper §3.2). Updates are immediate
scatter-adds per trainer with no cross-replica gradient averaging: the preserved
Hogwild property (see DESIGN.md §2).

Forward (``lookup``) and backward (``sparse_adagrad_update_fused``) dispatch to
the fused Pallas kernels by default (``kernels/embedding_bag`` lookup+pool,
``kernels/sparse_adagrad`` scatter-Adagrad; compiled on TPU, interpreter
elsewhere — DESIGN.md §7). ``lookup_ref`` / ``sparse_adagrad_update`` are the
pure-jnp oracles the kernels are tested against.

The greedy LPT bin-packing planner mirrors the paper's load-balancing of tables
across embedding PSs; the SPMD path uses uniform row sharding, while
``embeddings/shards.py`` consumes the plan directly: ``ThreadedShadowRunner``
splits the packed table into per-PS shards with genuinely independent Hogwild
state and routes lookups by the plan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.sparse_adagrad.ops import sparse_adagrad_op
from repro.models.layers import Params


@dataclass(frozen=True)
class TableSpec:
    sizes: Tuple[int, ...]
    dim: int
    multi_hot: int

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.sizes)[:-1]]).astype(np.int32)

    @property
    def total_rows(self) -> int:
        return int(sum(self.sizes))


def spec_from_config(cfg) -> TableSpec:
    return TableSpec(tuple(cfg.table_sizes), cfg.embedding_dim, cfg.multi_hot)


def init_tables(spec: TableSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    table = (
        jax.random.normal(key, (spec.total_rows, spec.dim), jnp.float32)
        * spec.dim ** -0.5
    ).astype(dtype)
    return {"table": table, "acc": jnp.zeros((spec.total_rows, spec.dim), jnp.float32)}


def global_row_ids(spec: TableSpec, idx: jnp.ndarray) -> jnp.ndarray:
    """idx: (B, F, m) per-feature local row ids -> global packed row ids."""
    offsets = jnp.asarray(spec.offsets)
    return idx + offsets[None, :, None]


def lookup_ref(state: Params, spec: TableSpec, idx: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle for ``lookup``: dense take + sum-pool (materializes the
    (B, F, m, d) gathered vectors the fused kernel never forms)."""
    rows = global_row_ids(spec, idx)
    vecs = jnp.take(state["table"], rows, axis=0)  # (B, F, m, d)
    return jnp.sum(vecs, axis=2)


def lookup(
    state: Params,
    spec: TableSpec,
    idx: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Sum-pooled lookup. idx: (B, F, m) -> (B, F, dim). One fused
    lookup+pool kernel launch by default; ``use_pallas=False`` is the oracle."""
    if not use_pallas:
        return lookup_ref(state, spec, idx)
    rows = global_row_ids(spec, idx)
    return embedding_bag_op(state["table"], rows, interpret=interpret)


def sparse_adagrad_update(
    state: Params,
    spec: TableSpec,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    lr: float,
    eps: float = 1e-8,
) -> Params:
    """Row-sparse Adagrad. g_pooled: (B, F, d) — with sum pooling each of the
    multi-hot rows receives the pooled gradient unchanged. Duplicate rows in a
    batch scatter-add, which matches Hogwild's unsynchronized-accumulate."""
    B, F, m = idx.shape
    rows = global_row_ids(spec, idx).reshape(-1)  # (B*F*m,)
    g = jnp.broadcast_to(g_pooled[:, :, None, :], (B, F, m, g_pooled.shape[-1]))
    g = g.reshape(-1, g_pooled.shape[-1]).astype(jnp.float32)
    acc = state["acc"].at[rows].add(g * g)
    scale = lr * jax.lax.rsqrt(acc.at[rows].get() + eps)
    table = state["table"].at[rows].add((-scale * g).astype(state["table"].dtype))
    return {"table": table, "acc": acc}


def sparse_adagrad_update_fused(
    state: Params,
    spec: TableSpec,
    idx: jnp.ndarray,
    g_pooled: jnp.ndarray,
    lr: float,
    eps: float = 1e-8,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> Params:
    """``sparse_adagrad_update`` through the fused scatter kernel: acc update +
    rsqrt-scaled row add in one launch, duplicate-row accumulate semantics
    identical to the oracle (tested in tests/test_embedding_substrate.py)."""
    if not use_pallas:
        return sparse_adagrad_update(state, spec, idx, g_pooled, lr, eps)
    bags = global_row_ids(spec, idx).reshape(-1, idx.shape[-1])  # (B*F, m)
    g = g_pooled.reshape(-1, g_pooled.shape[-1])
    table, acc = sparse_adagrad_op(
        state["table"], state["acc"], bags, g, lr=lr, eps=eps,
        interpret=interpret)
    return {"table": table, "acc": acc}


def bin_pack(costs: Sequence[float], n_bins: int) -> List[List[int]]:
    """Greedy LPT (longest-processing-time) bin packing: the paper's strategy for
    distributing embedding lookup cost across embedding PSs (§3.1)."""
    order = np.argsort(costs)[::-1]
    loads = np.zeros(n_bins)
    bins: List[List[int]] = [[] for _ in range(n_bins)]
    for i in order:
        b = int(np.argmin(loads))
        bins[b].append(int(i))
        loads[b] += costs[i]
    return bins


def lookup_costs(spec: TableSpec, batch_size: int) -> np.ndarray:
    """Profiled-cost model: lookups dominate; cost ~ batch * multi_hot * dim,
    identical per feature here, plus a memory-residency term ~ rows."""
    per_lookup = batch_size * spec.multi_hot * spec.dim
    return np.array([per_lookup + 1e-3 * s * spec.dim for s in spec.sizes])
